//! Measures raw PJRT per-call cost at the artifact's row count.
use hermes::perfmodel::pjrt::PjrtPerfModel;
use hermes::perfmodel::{PerfModel, StepFeatures};
use hermes::runtime::ArtifactBundle;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut m = PjrtPerfModel::load(&ArtifactBundle::default_dir(), "llama3-70b@h100/tp8")?;
    for _ in 0..50 { m.predict(StepFeatures::decode(1, 100.0)); }
    let n = 2000;
    let t0 = Instant::now();
    for i in 0..n {
        m.predict(StepFeatures::decode(1 + i % 32, (1000 + i * 7) as f64));
    }
    let el = t0.elapsed().as_secs_f64();
    println!("single-plan PJRT predict: {:.1} us/call ({} calls, rows {})", el / n as f64 * 1e6, m.calls, m.rows());
    Ok(())
}
