//! Remote KV-cache storage study (the Fig 15 scenario at laptop scale):
//! chat requests whose past 4K/24K-token context is fetched from one of
//! the Fig 14 storage tiers — or recomputed.
//!
//!     cargo run --release --example kv_cache_study

use hermes::config::slo::SloLadder;
use hermes::hardware::npu::H100;
use hermes::memory::storage::{KvScenario, StorageConfig};
use hermes::metrics::RunMetrics;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{KvRetrievalSpec, NetSpec, PerfBackend, PoolSpec, ServingSpec};
use hermes::util::stats;
use hermes::workload::request::KvParams;
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let slo = SloLadder::retrieval();
    for cache_tokens in [4096usize, 24576] {
        println!("\n=== past-context size: {}K tokens (private scenario) ===", cache_tokens / 1024);
        println!("{:<14} {:>9} {:>9} {:>9} {:>11}", "storage", "e2e_p50", "e2e_p90", "e2e_p99", "recomputes");
        for cfg in StorageConfig::all() {
            // tier replica counts, scaled down from Fig 14: dedicated = one
            // store per client; platform = one per 4; rack = one for all 8
            let stores = match cfg {
                StorageConfig::DedicatedPerClient => 8,
                StorageConfig::PlatformShared => 2,
                _ => 1,
            };
            let spec = ServingSpec::new(
                "llama3-70b",
                H100,
                2,
                PoolSpec::Combined { kind: BatchingKind::Continuous, n: 8 },
            )
            .with_perf(PerfBackend::Poly)
            .with_net(NetSpec::Hierarchy { per_platform: 4, per_rack: 20 })
            .with_kv_retrieval(KvRetrievalSpec {
                count: stores,
                storage: cfg,
                scenario: KvScenario::Private,
                max_batch: 0,
                ports: 4,
            });
            let workload = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 300, 8.0)
                .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: cache_tokens }))
                .with_seed(14);
            let mut coord = spec.build()?;
            coord.inject(workload.generate(0));
            coord.run();
            let m = RunMetrics::collect(&coord, &slo);
            println!(
                "{:<14} {:>8.2}s {:>8.2}s {:>8.2}s {:>11}",
                cfg.name(),
                m.e2e.p50,
                m.e2e.p90,
                m.e2e.p99,
                m.recomputes
            );
            if cfg == StorageConfig::PlatformShared {
                // show a CDF slice for the plotting-minded
                let cdf = stats::cdf(&m.e2e_samples, 5);
                let pts: Vec<String> =
                    cdf.iter().map(|(x, q)| format!("{:.0}%≤{:.2}s", q * 100.0, x)).collect();
                println!("               cdf: {}", pts.join("  "));
            }
        }
    }
    println!("\nshape: recompute competitive at 4K, prohibitive at 24K; the");
    println!("platform tier balances speed and capacity for private KV (paper Fig 15).");
    Ok(())
}
