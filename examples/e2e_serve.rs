//! End-to-end driver proving the three layers compose:
//!
//!   L1 Pallas predictor kernel ──(jax.jit → HLO text, `make artifacts`)──►
//!   L2 JAX graph per TP variant ──(PJRT CPU client)──►
//!   L3 rust coordinator pricing every engine step through the compiled
//!      executable on the request path (memoized), running a realistic
//!      disaggregated deployment on a synthetic Azure-style workload.
//!
//!     make artifacts && cargo run --release --example e2e_serve
//!
//! Reports latency/throughput (recorded in EXPERIMENTS.md §E2E).

use hermes::config::slo::SloLadder;
use hermes::hardware::npu::H100;
use hermes::metrics::RunMetrics;
use hermes::runtime::{ArtifactBundle, Runtime};
use hermes::sim::builder::{NetSpec, PerfBackend, PoolSpec, ServingSpec};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    // prove the PJRT runtime is live and the artifacts load
    let rt = Runtime::cpu()?;
    let bundle = ArtifactBundle::open(&ArtifactBundle::default_dir())?;
    println!("PJRT platform: {}", rt.platform());
    println!("AOT predictor variants: {:?}", bundle.variant_keys());

    // a rack: 12 prefill + 8 decode clients of H100 TP2 + post-processing
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        2,
        PoolSpec::Disaggregated { prefill: 12, decode: 8, local: false },
    )
    .with_perf(PerfBackend::PjrtMemo) // the AOT artifact on the hot path
    .with_net(NetSpec::Hierarchy { per_platform: 4, per_rack: 20 });

    let n_requests = 800;
    let workload = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n_requests, 40.0)
        .with_pipeline(Pipeline::Regular)
        .with_seed(2026);

    println!("\nserving {n_requests} conversational requests on 20 disaggregated clients…");
    let mut coord = spec.build()?;
    coord.inject(workload.generate(0));
    let t0 = std::time::Instant::now();
    coord.run();
    let wall = t0.elapsed().as_secs_f64();

    let slo = SloLadder::standard();
    let m = RunMetrics::collect(&coord, &slo);
    assert_eq!(m.n_serviced, n_requests, "every request must complete");

    println!("─ results ────────────────────────────────────────────");
    println!("simulated horizon      {:>10.2} s", m.makespan);
    println!("wall-clock             {:>10.2} s  ({:.0} events/s, {:.0}x realtime)",
             wall, m.events as f64 / wall, m.makespan / wall);
    println!("TTFT   p50/p90/p99     {:>6.0} / {:.0} / {:.0} ms",
             m.ttft.p50 * 1e3, m.ttft.p90 * 1e3, m.ttft.p99 * 1e3);
    println!("TPOT   p50/p90/p99     {:>6.1} / {:.1} / {:.1} ms",
             m.tpot.p50 * 1e3, m.tpot.p90 * 1e3, m.tpot.p99 * 1e3);
    println!("E2E    p50/p99         {:>6.2} / {:.2} s", m.e2e.p50, m.e2e.p99);
    println!("throughput             {:>10.0} tok/s", m.throughput_tok_s);
    println!("goodput (per-req SLO)  {:>10.1} %", m.goodput_frac * 100.0);
    println!("energy                 {:>10.1} kJ   ({:.2} tok/J)",
             m.energy_joules / 1e3, m.tok_per_joule);
    println!("KV transfers           {:>10}   ({:.1} GB over the fabric)",
             m.transfers, m.transfer_bytes / 1e9);
    println!("all-six SLO            {:>10}", if m.slo_satisfied(&slo) { "SATISFIED" } else { "violated" });
    Ok(())
}
