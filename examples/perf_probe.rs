//! Perf-pass probe: record the feature stream from a fast (poly) run,
//! then replay it against the memoized PJRT backend to count calls.
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use hermes::perfmodel::memo::Memoized;
use hermes::perfmodel::pjrt::PjrtPerfModel;
use hermes::perfmodel::poly::PolyPerfModel;
use hermes::perfmodel::{PerfModel, StepFeatures, StepPrediction};
use hermes::runtime::ArtifactBundle;

struct Recorder {
    inner: PolyPerfModel,
    log: Rc<RefCell<Vec<Vec<StepFeatures>>>>,
}
impl PerfModel for Recorder {
    fn name(&self) -> &str { "recorder" }
    fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
        self.log.borrow_mut().push(feats.to_vec());
        self.inner.predict_batch(feats)
    }
}

fn main() -> anyhow::Result<()> {
    use hermes::client::{Client, LlmClient};
    use hermes::coordinator::{Coordinator, RoutePolicy, Router};
    use hermes::hardware::models::LLAMA3_70B;
    use hermes::hardware::npu::H100;
    use hermes::hardware::roofline::LlmCluster;
    use hermes::network::Network;
    use hermes::scheduler::{BatchingKind, LlmSched, Packing, SchedConfig};
    use hermes::workload::trace::{TraceKind, WorkloadSpec};

    let dir = ArtifactBundle::default_dir();
    let key = "llama3-70b@h100/tp8";
    let bundle = ArtifactBundle::open(&dir)?;
    let log = Rc::new(RefCell::new(Vec::new()));

    let mut clients: Vec<Box<dyn Client>> = Vec::new();
    for i in 0..4 {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        clients.push(Box::new(LlmClient::new(
            i,
            cluster,
            LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
            Box::new(Recorder {
                inner: PolyPerfModel::from_coefficients(&bundle.coefficients, key)?,
                log: log.clone(),
            }),
        )));
    }
    let mut coord = Coordinator::new(
        clients,
        Router::new(RoutePolicy::LoadBased(hermes::coordinator::LoadMetric::TokensLeft)),
        Network::single_platform(4),
    );
    coord.inject(WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 200, 8.0).with_seed(1).generate(0));
    coord.run();

    let stream = log.borrow();
    let batches = stream.len();
    let rows: usize = stream.iter().map(|b| b.len()).sum();
    println!("perf-model invocations: {batches} (total rows {rows})");

    let mut memo = Memoized::new(PjrtPerfModel::load(&dir, key)?);
    let t0 = Instant::now();
    for b in stream.iter() {
        memo.inner_calls_probe(b);
    }
    let el = t0.elapsed();
    println!(
        "replay vs memoized PJRT: {:?}  hits {}  misses {}  hit-rate {:.1}%  pjrt-calls {}",
        el, memo.hits, memo.misses, memo.hit_rate() * 100.0, memo.inner.calls
    );
    println!("avg {:.1} us/invocation", el.as_secs_f64() / batches as f64 * 1e6);
    Ok(())
}
