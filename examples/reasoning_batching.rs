//! Multi-path reasoning scenario (the Fig 8 workload at laptop scale):
//! each request spawns 8 parallel thought branches sharing the prefill
//! KV, decoding ~2K tokens per branch. Compares batching strategies as
//! memory pressure explodes.
//!
//!     cargo run --release --example reasoning_batching

use hermes::config::slo::SloLadder;
use hermes::hardware::npu::H100;
use hermes::metrics::RunMetrics;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use hermes::workload::trace::{Reasoning, TraceKind, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let slo = SloLadder::standard();
    let pools = [
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 4 },
        PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 512 }, n: 4 },
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    ];
    println!("llama3-70b on 4×(H100 TP8); 60 requests, 8 branches × ~2K tokens each");
    println!("{:<16} {:>9} {:>9} {:>10} {:>10} {:>9}", "strategy", "ttft_p50", "tpot_p50", "thr tok/s", "goodput", "makespan");
    for pool in pools {
        let spec = ServingSpec::new("llama3-70b", H100, 8, pool).with_perf(PerfBackend::Poly);
        let workload = WorkloadSpec::new(
            "llama3-70b",
            TraceKind::Synthetic { in_mean: 1020.0, in_std: 450.0, out_mean: 2000.0, out_std: 600.0 },
            60,
            0.6,
        )
        .with_reasoning(Reasoning::MultiPath { scale: 1.0, branches: 8 })
        .with_seed(8);
        let mut coord = spec.build()?;
        coord.inject(workload.generate(0));
        coord.run();
        let m = RunMetrics::collect(&coord, &slo);
        println!(
            "{:<16} {:>7.0}ms {:>7.1}ms {:>10.0} {:>9.0}% {:>8.1}s",
            spec.pool.label(),
            m.ttft.p50 * 1e3,
            m.tpot.p50 * 1e3,
            m.throughput_tok_s,
            m.goodput_frac * 100.0,
            m.makespan
        );
    }
    println!("\nshape: reasoning multiplies KV demand 8x — batch sizes shrink and");
    println!("decode-heavy disaggregation or continuous batching keep TTFT in check (paper §IV-A).");
    Ok(())
}
