//! RAG pipeline scenario (the Fig 9 / Fig 11 workload): conversational
//! queries that embed + retrieve 10K tokens of context before prefill,
//! comparing embedding-model placements end to end *through the full
//! simulator* (not just the analytical breakdown).
//!
//!     cargo run --release --example rag_pipeline

use hermes::config::slo::SloLadder;
use hermes::hardware::models;
use hermes::hardware::npu::{A100, GRACE_CPU, H100, SPR_CPU};
use hermes::metrics::RunMetrics;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{PerfBackend, PoolSpec, RagSpec, ServingSpec};
use hermes::workload::request::RagParams;
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let slo = SloLadder::retrieval();
    let rag_params = RagParams::default(); // 20 docs × 512 tok = +10K ctx

    println!("RAG pipeline: 2×H100(TP1, llama3.1-8b) + 1 RAG client, 150 queries @ 2/s");
    println!("{:<26} {:>10} {:>10} {:>10} {:>12}", "embedder placement", "ttft_p50", "ttft_p99", "e2e_p50", "goodput");
    for (label, embed_model, embed_npu, retr_npu) in [
        ("e5-base @ grace", "e5-base", GRACE_CPU, GRACE_CPU),
        ("e5-base @ spr", "e5-base", SPR_CPU, SPR_CPU),
        ("mistral-7b @ grace", "mistral-7b", GRACE_CPU, GRACE_CPU),
        ("mistral-7b @ spr", "mistral-7b", SPR_CPU, SPR_CPU),
        ("mistral-7b @ a100", "mistral-7b", A100, GRACE_CPU),
    ] {
        let spec = ServingSpec::new(
            "llama3.1-8b",
            H100,
            1,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        )
        .with_perf(PerfBackend::Roofline) // 8B@TP1 has no fitted artifact
        .with_rag(RagSpec {
            count: 1,
            embed_model: models::model(embed_model).unwrap(),
            embed_npu,
            retrieval_npu: retr_npu,
            ivf: Default::default(),
            max_batch: 0,
        });
        let workload = WorkloadSpec::new("llama3.1-8b", TraceKind::AzureConv, 150, 2.0)
            .with_pipeline(Pipeline::Rag(rag_params))
            .with_seed(9);
        let mut coord = spec.build()?;
        coord.inject(workload.generate(0));
        coord.run();
        let m = RunMetrics::collect(&coord, &slo);
        println!(
            "{label:<26} {:>8.0}ms {:>8.0}ms {:>9.2}s {:>11.0}%",
            m.ttft.p50 * 1e3,
            m.ttft.p99 * 1e3,
            m.e2e.p50,
            m.goodput_frac * 100.0
        );
    }
    println!("\nshape: large embedder on the small CPU wrecks TTFT; offloading");
    println!("embedding to the A100 restores it (paper Fig 9).");
    Ok(())
}
