//! Quickstart: simulate a 4-client continuous-batching deployment of
//! Llama-3-70B on H100 (TP2) serving a conversational trace, and print
//! the paper's metric set.
//!
//!     cargo run --release --example quickstart

use hermes::config::slo::SloLadder;
use hermes::hardware::npu::H100;
use hermes::metrics::RunMetrics;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use hermes::workload::trace::{TraceKind, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    // 1. describe the serving system
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        2, // tensor parallelism per client
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 4 },
    )
    .with_perf(PerfBackend::Poly); // fitted predictor from `make artifacts`

    // 2. describe the workload: 400 chat requests at 2 req/s/client
    let workload = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 400, 8.0).with_seed(7);

    // 3. build, inject, run
    let mut coord = spec.build()?;
    coord.inject(workload.generate(0));
    let t0 = std::time::Instant::now();
    coord.run();
    let wall = t0.elapsed();

    // 4. collect the paper's metrics
    let slo = SloLadder::standard();
    let m = RunMetrics::collect(&coord, &slo);
    println!("simulated {:.1}s of serving in {:?} ({} events)", m.makespan, wall, m.events);
    println!("TTFT  p50 {:.0}ms  p90 {:.0}ms  p99 {:.0}ms", m.ttft.p50 * 1e3, m.ttft.p90 * 1e3, m.ttft.p99 * 1e3);
    println!("TPOT  p50 {:.1}ms  p99 {:.1}ms", m.tpot.p50 * 1e3, m.tpot.p99 * 1e3);
    println!("throughput {:.0} tok/s   energy {:.1} kJ   {:.2} tok/J",
             m.throughput_tok_s, m.energy_joules / 1e3, m.tok_per_joule);
    println!("all-six SLO: {}", if m.slo_satisfied(&slo) { "SATISFIED" } else { "violated" });
    Ok(())
}
