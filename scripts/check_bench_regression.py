#!/usr/bin/env python3
"""Warn-only events/s and memory diff between a fresh bench run and the
committed baseline (docs/performance.md).

Usage:
    python3 scripts/check_bench_regression.py FRESH.json [BASELINE.json]
        [--threshold NAME=RATIO ...] [--default-threshold RATIO]
        [--mem-threshold NAME=RATIO ...] [--default-mem-threshold RATIO]

The baseline must come from runs at the SAME scale as the fresh
document: CI diffs its --fast smoke (BENCH_smoke.json) against the
committed fast-scale baseline BENCH_ci_fast.json (produced once in a
toolchain env via `hermes bench bench_llm_50k --fast --baseline on
--out BENCH_ci_fast.json`); the full-scale BENCH_core.json trajectory
is for humans and would be skipped row-by-row here as a scale
mismatch.

Two comparisons run per scenario present in both documents *at the
same scale* (rows whose `n_requests` differ — e.g. a --fast smoke vs a
committed full-scale run — are skipped, since that ratio measures
scale, not regression):

* **speed**: WARN when fresh `incremental.events_per_s` falls below
  the scenario's threshold x baseline (default 60% — generous, CI
  hardware is heterogeneous). Thresholds resolve CLI `--threshold
  NAME=RATIO` first, then the built-in SCENARIO_THRESHOLDS table, then
  `--default-threshold`.
* **memory**: WARN when fresh `incremental.peak_resident_slots`,
  `incremental.resident_bytes_est` or `incremental.metrics_bytes_est`
  *grows* beyond the scenario's memory threshold x baseline (default
  1.25x). Deterministic simulations make these counters
  machine-independent, so growth here is a real regression of the
  O(in-flight) guarantee — e.g. a leak of retired slots, or sketch
  metrics state scaling with request count at the 100M tier — not
  noise. `--mem-threshold NAME=RATIO` overrides per scenario (rows
  without the fields, i.e. baselines predating a column, are skipped).

* **failure recovery** (docs/robustness.md): WARN when fresh
  `incremental.goodput` falls below 95% of baseline, or when fresh
  `retries` / `timeouts` grow beyond 1.5x a nonzero baseline. These
  come from deterministic fault schedules, so movement is a behavior
  change — but an intentional fault-plan tweak legitimately moves
  them, hence warn-only with generous slack. Rows without the columns
  (baselines predating them) are skipped, like the memory fields.

Rows also carry a `metrics` column ("exact" or "sketch",
`--metrics` / `extras.metrics`); it is echoed in the log line but, like
`shards`, not part of the match key.

Rows from `hermes bench --shards K` carry a `shards` column and a
`sharded` sub-object; both are ignored when matching baseline rows (the
compared `incremental` row is the serial trajectory either way), with
the shard count echoed in the log line for context.

Always exits 0: this is a tripwire for humans reading the log, not a
gate. (A missing baseline — e.g. before the first release-mode
`hermes bench` run is committed — is reported and tolerated.)
"""

import json
import sys

# fresh events/s below 60% of the committed baseline triggers a warning;
# generous because CI hardware is heterogeneous and the committed
# baseline comes from a release-mode run on a developer machine
DEFAULT_THRESHOLD = 0.60

# built-in per-scenario speed thresholds, consulted after CLI
# --threshold overrides and before --default-threshold: scenarios whose
# fast-scale smoke is intrinsically noisier than the steady single-pool
# rows carry their looser tripwire here instead of in every CI
# invocation
SCENARIO_THRESHOLDS = {
    # small fast scale + cascade-escalation randomness
    "bench_multimodel_100k": 0.50,
    # migration-heavy: every request crosses the interconnect, so the
    # event mix is transfer-dominated and more timer-sensitive
    "bench_disagg_100k": 0.50,
    # the robustness tier rides the same transfer-dominated disagg
    # shape, with fault-triggered retries/re-routes on top
    "bench_faults_100k": 0.50,
}

# same idea for the memory-growth tripwire: the 100M tier exists to
# prove bounded resident memory (peak_resident_slots <= 5% of trace,
# metrics_bytes_est O(1) in request count), so its growth tripwire is
# tighter than the default
SCENARIO_MEM_THRESHOLDS = {
    "bench_llm_100m": 1.10,
}

# peak_resident_slots / resident_bytes_est above 125% of the committed
# baseline triggers a warning; these are deterministic counters, so the
# slack only covers intentional workload-shape tweaks
DEFAULT_MEM_THRESHOLD = 1.25

MEM_FIELDS = ("peak_resident_slots", "resident_bytes_est", "metrics_bytes_est")

# failure-aware columns (docs/robustness.md): goodput warns on a DROP
# below 95% of baseline, the counters warn on GROWTH beyond 1.5x a
# nonzero baseline. Deterministic fault schedules make these
# machine-independent, but an intentional fault-plan tweak moves them
# legitimately — hence warn-only with generous slack.
FAULT_COUNT_FIELDS = ("retries", "timeouts")
GOODPUT_THRESHOLD = 0.95
FAULT_GROWTH_THRESHOLD = 1.5


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        print(f"bench-diff: cannot parse {path}: {e}")
        return None


def rows_by_name(doc):
    # rows are keyed by scenario name ONLY: the `shards` column (and the
    # optional `sharded` sub-object) added by `hermes bench --shards K`
    # is deliberately NOT part of the match key, so a sharded smoke still
    # diffs its serial `incremental` row against a shards=1 baseline.
    # The shard count is carried along purely for display.
    if not isinstance(doc, list):
        return {}
    out = {}
    for row in doc:
        name = row.get("name")
        inc = row.get("incremental", {})
        eps = inc.get("events_per_s")
        if name and isinstance(eps, (int, float)):
            mem = {
                k: inc[k]
                for k in MEM_FIELDS
                if isinstance(inc.get(k), (int, float))
            }
            fault = {
                k: inc[k]
                for k in ("goodput",) + FAULT_COUNT_FIELDS
                if isinstance(inc.get(k), (int, float))
            }
            out[name] = (
                eps,
                inc.get("n_requests"),
                mem,
                row.get("shards"),
                row.get("metrics"),
                fault,
            )
    return out


def parse_kv(flag, arg, store):
    if "=" not in arg:
        raise ValueError(f"{flag} needs NAME=RATIO")
    name, ratio = arg.split("=", 1)
    store[name] = float(ratio)


def parse_args(argv):
    """Returns (fresh, base, default_thr, per_scenario, default_mem,
    per_scenario_mem)."""
    positional = []
    per_scenario = {}
    per_scenario_mem = {}
    default_threshold = DEFAULT_THRESHOLD
    default_mem = DEFAULT_MEM_THRESHOLD
    i = 1
    while i < len(argv):
        arg = argv[i]
        if arg == "--threshold":
            i += 1
            if i >= len(argv):
                raise ValueError("--threshold needs NAME=RATIO")
            parse_kv("--threshold", argv[i], per_scenario)
        elif arg.startswith("--threshold="):
            parse_kv("--threshold", arg[len("--threshold="):], per_scenario)
        elif arg == "--mem-threshold":
            i += 1
            if i >= len(argv):
                raise ValueError("--mem-threshold needs NAME=RATIO")
            parse_kv("--mem-threshold", argv[i], per_scenario_mem)
        elif arg.startswith("--mem-threshold="):
            parse_kv("--mem-threshold", arg[len("--mem-threshold="):], per_scenario_mem)
        elif arg == "--default-threshold":
            i += 1
            if i >= len(argv):
                raise ValueError("--default-threshold needs a RATIO")
            default_threshold = float(argv[i])
        elif arg.startswith("--default-threshold="):
            default_threshold = float(arg[len("--default-threshold="):])
        elif arg == "--default-mem-threshold":
            i += 1
            if i >= len(argv):
                raise ValueError("--default-mem-threshold needs a RATIO")
            default_mem = float(argv[i])
        elif arg.startswith("--default-mem-threshold="):
            default_mem = float(arg[len("--default-mem-threshold="):])
        elif arg.startswith("--"):
            raise ValueError(f"unknown flag {arg}")
        else:
            positional.append(arg)
        i += 1
    if not positional:
        raise ValueError("FRESH.json required")
    fresh = positional[0]
    base = positional[1] if len(positional) > 1 else "BENCH_ci_fast.json"
    return fresh, base, default_threshold, per_scenario, default_mem, per_scenario_mem


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 0
    try:
        (
            fresh_path,
            base_path,
            default_threshold,
            per_scenario,
            default_mem,
            per_scenario_mem,
        ) = parse_args(argv)
    except ValueError as e:
        print(f"bench-diff: {e}")
        print(__doc__)
        return 0

    fresh = rows_by_name(load(fresh_path) or [])
    base_doc = load(base_path)
    if base_doc is None:
        print(
            f"bench-diff: no committed baseline at {base_path} — nothing to "
            "compare (commit one from a release-mode `hermes bench` run)"
        )
        return 0
    base = rows_by_name(base_doc)

    if not fresh:
        print(f"bench-diff: no comparable rows in {fresh_path}")
        return 0

    warned = False
    for name, (eps, n, mem, shards, metrics, fault) in sorted(fresh.items()):
        ref_entry = base.get(name)
        if ref_entry is None or ref_entry[0] <= 0:
            print(f"bench-diff: {name}: no baseline entry — skipped")
            continue
        ref, ref_n, ref_mem, _ref_shards, _ref_metrics, ref_fault = ref_entry
        if n != ref_n:
            # a fast-scale smoke vs a full-scale committed run measures
            # scale, not regression — only same-sized runs are comparable
            print(
                f"bench-diff: {name}: scale mismatch ({n} vs baseline "
                f"{ref_n} requests) — skipped"
            )
            continue
        threshold = per_scenario.get(
            name, SCENARIO_THRESHOLDS.get(name, default_threshold)
        )
        ratio = eps / ref
        # the shard/metrics tags are informational: the compared
        # `incremental` row is the serial trajectory even in a --shards
        # run, and the metrics mode only changes the metrics columns
        tag = f" [shards={shards:.0f}]" if isinstance(shards, (int, float)) and shards > 1 else ""
        if metrics == "sketch":
            tag += " [metrics=sketch]"
        line = f"bench-diff: {name}{tag}: {eps:,.0f} events/s vs baseline {ref:,.0f} ({ratio:.2f}x)"
        if ratio < threshold:
            print(f"WARNING {line} — below the {threshold:.0%} warn threshold")
            warned = True
        else:
            print(line)
        # memory growth: only rows that carry the retirement-era fields
        # on both sides are comparable
        mem_threshold = per_scenario_mem.get(
            name, SCENARIO_MEM_THRESHOLDS.get(name, default_mem)
        )
        for field in MEM_FIELDS:
            if field not in mem or ref_mem.get(field, 0) <= 0:
                continue
            mratio = mem[field] / ref_mem[field]
            mline = (
                f"bench-diff: {name}: {field} {mem[field]:,.0f} vs baseline "
                f"{ref_mem[field]:,.0f} ({mratio:.2f}x)"
            )
            if mratio > mem_threshold:
                print(
                    f"WARNING {mline} — above the {mem_threshold:.2f}x growth "
                    "threshold (O(in-flight) regression?)"
                )
                warned = True
            else:
                print(mline)
        # failure-aware columns: like the memory fields, only rows that
        # carry them on both sides are comparable (older baselines skip)
        if "goodput" in fault and ref_fault.get("goodput", 0) > 0:
            gratio = fault["goodput"] / ref_fault["goodput"]
            gline = (
                f"bench-diff: {name}: goodput {fault['goodput']:.4f} vs "
                f"baseline {ref_fault['goodput']:.4f} ({gratio:.2f}x)"
            )
            if gratio < GOODPUT_THRESHOLD:
                print(
                    f"WARNING {gline} — below the {GOODPUT_THRESHOLD:.0%} warn "
                    "threshold (failure-recovery regression? docs/robustness.md)"
                )
                warned = True
            else:
                print(gline)
        for field in FAULT_COUNT_FIELDS:
            if field not in fault or ref_fault.get(field, 0) <= 0:
                continue
            fratio = fault[field] / ref_fault[field]
            fline = (
                f"bench-diff: {name}: {field} {fault[field]:,.0f} vs baseline "
                f"{ref_fault[field]:,.0f} ({fratio:.2f}x)"
            )
            if fratio > FAULT_GROWTH_THRESHOLD:
                print(
                    f"WARNING {fline} — above the {FAULT_GROWTH_THRESHOLD:.1f}x "
                    "growth threshold (docs/robustness.md)"
                )
                warned = True
            else:
                print(fline)
    if warned:
        print("bench-diff: WARN-ONLY — not failing the build (see docs/performance.md)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
