"""AOT path smoke tests: lowering produces loadable HLO text and the
jitted L2 graph agrees with the oracle end-to-end."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, fit as fitmod, model as modelmod
from compile.kernels import ref


@pytest.fixture(scope="module")
def res():
    return fitmod.fit("llama3-70b", "h100", 8, n_points=2_000, seed=2)


def test_build_predict_fn_matches_ref(res):
    fn, spec = modelmod.build_predict_fn(res, rows=64)
    rng = np.random.default_rng(0)
    x = np.zeros((64, 5), dtype=np.float32)
    x[:, 3] = rng.integers(1, 64, 64)
    x[:, 4] = x[:, 3] * 1000.0
    (got,) = jax.jit(fn)(jnp.asarray(x))
    want = ref.predict(jnp.asarray(x), jnp.asarray(res.w_pf),
                       jnp.asarray(res.w_dec),
                       (res.c_dec_b, res.c_dec_kv, res.m_pf_tok))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


def test_lower_to_hlo_text_structure(res):
    hlo = modelmod.lower_to_hlo_text(res, rows=32, block_r=16)
    assert "HloModule" in hlo
    assert "f32[32,5]" in hlo       # input shape
    assert "f32[32,3]" in hlo       # output shape
    assert len(hlo) > 1_000


def test_build_bundle(tmp_path, res):
    out = str(tmp_path / "artifacts")
    aot.build(out, variants=[("llama3-70b", "h100", 8)], rows=32, n_points=2_000)
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    coeffs = json.load(open(os.path.join(out, "coefficients.json")))
    key = "llama3-70b@h100/tp8"
    assert key in manifest["variants"]
    assert manifest["rows"] == 32
    assert os.path.exists(os.path.join(out, manifest["variants"][key]["file"]))
    c = coeffs[key]
    assert len(c["w_pf"]) == ref.N_FEATURES
    assert len(c["w_dec"]) == ref.N_FEATURES
    assert c["scales"] == list(ref.SCALES)
    assert c["mse_dec"] < 5e-6


def test_variant_stem_format():
    assert aot.variant_stem("llama3-70b", "h100", 4) == "runtime_llama3-70b_h100_tp4"
