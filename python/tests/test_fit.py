"""Fit-quality tests: the polynomial regression must reach MSEs in the
paper's ballpark (§III-E.1: decode MSE 4.09e-07, prefill MSE 6.49e-05)
and the fitted predictor must track the roofline generator closely.
"""

import numpy as np
import pytest

from compile import fit as fitmod
from compile import hwspec
from compile.kernels.ref import N_FEATURES

# Smaller trace than the production 58K to keep pytest fast; MSE bounds
# hold at either size (lstsq is sample-efficient for 6 features).
N = 6_000


@pytest.fixture(scope="module")
def res():
    return fitmod.fit("llama3-70b", "h100", 8, n_points=N, seed=1)


def test_shapes_and_metadata(res):
    assert res.w_pf.shape == (N_FEATURES,)
    assert res.w_dec.shape == (N_FEATURES,)
    assert res.n_dec > res.n_pf  # decode ≈ 96% of the dataset (paper)
    assert res.n_dec + res.n_pf == N
    assert res.c_dec_b > 0.0 and res.c_dec_kv > 0.0 and res.m_pf_tok > 0.0


def test_decode_mse_ballpark(res):
    # paper: 4.09e-07 s² on real hardware; our synthetic trace carries 1%
    # noise, so demand the same order of magnitude.
    assert res.mse_dec < 5e-6, f"decode MSE too high: {res.mse_dec}"


def test_prefill_mse_ballpark(res):
    # paper: 6.49e-05 s²
    assert res.mse_pf < 5e-4, f"prefill MSE too high: {res.mse_pf}"


def test_decode_predictions_track_generator(res):
    model = hwspec.MODELS["llama3-70b"]
    npu = hwspec.NPUS["h100"]
    for b, ctx in [(1, 512.0), (16, 1024.0), (64, 2048.0), (256, 4096.0)]:
        true = hwspec.step_time(model, npu, 8, 0, 0, 0, b, b * ctx)
        x = np.zeros((1, 5))
        x[0, 3], x[0, 4] = b, b * ctx
        pred = (fitmod._decode_features_np(x) @ res.w_dec).item()
        assert abs(pred - true) / true < 0.15, f"b={b} ctx={ctx}: {pred} vs {true}"


def test_prefill_predictions_track_generator(res):
    model = hwspec.MODELS["llama3-70b"]
    npu = hwspec.NPUS["h100"]
    for new, past in [(512.0, 0.0), (2048.0, 0.0), (4096.0, 4096.0), (8192.0, 0.0)]:
        true = hwspec.step_time(model, npu, 8, new, past, 1, 0, 0.0)
        x = np.zeros((1, 5))
        x[0, 0], x[0, 1], x[0, 2] = new, past, 1
        pred = (fitmod._prefill_features_np(x) @ res.w_pf).item()
        assert abs(pred - true) / true < 0.15, f"new={new} past={past}: {pred} vs {true}"


def test_fit_is_deterministic():
    a = fitmod.fit("llama3-70b", "h100", 2, n_points=2_000, seed=3)
    b = fitmod.fit("llama3-70b", "h100", 2, n_points=2_000, seed=3)
    np.testing.assert_array_equal(a.w_dec, b.w_dec)
    np.testing.assert_array_equal(a.w_pf, b.w_pf)


def test_tp_scaling_visible_in_coefficients():
    # More TP → faster steps → smaller decode kv-slope
    lo = fitmod.fit("llama3-70b", "h100", 2, n_points=2_000, seed=0)
    hi = fitmod.fit("llama3-70b", "h100", 8, n_points=2_000, seed=0)
    x = np.zeros((1, 5))
    x[0, 3], x[0, 4] = 32, 32 * 2048.0
    p_lo = (fitmod._decode_features_np(x) @ lo.w_dec).item()
    p_hi = (fitmod._decode_features_np(x) @ hi.w_dec).item()
    assert p_lo > 2.0 * p_hi


def test_roofline_generator_sanity():
    model = hwspec.MODELS["llama3-70b"]
    npu = hwspec.NPUS["h100"]
    # decode step TP8 in single-digit milliseconds
    t = hwspec.step_time(model, npu, 8, 0, 0, 0, 1, 1000.0)
    assert 4e-3 < t < 15e-3
    # 2k prefill in tens of milliseconds
    t = hwspec.step_time(model, npu, 8, 2048.0, 0.0, 1, 0, 0.0)
    assert 30e-3 < t < 150e-3
