"""Pallas predictor kernel vs pure-jnp oracle — the core L1 correctness
signal. Hypothesis sweeps shapes, block sizes, dtypes and feature ranges.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import predictor, ref


def _rand_inputs(rng, rows):
    """Raw feature rows spanning the real dynamic range, incl. zero heads."""
    x = np.zeros((rows, ref.N_RAW), dtype=np.float32)
    has_pf = rng.random(rows) < 0.6
    has_dec = rng.random(rows) < 0.8
    x[:, 0] = np.where(has_pf, rng.uniform(1.0, 8192.0, rows), 0.0)
    x[:, 1] = np.where(has_pf, rng.uniform(0.0, 16384.0, rows), 0.0)
    x[:, 2] = np.where(has_pf, rng.integers(1, 9, rows), 0.0)
    x[:, 3] = np.where(has_dec, rng.integers(1, 257, rows), 0.0)
    x[:, 4] = x[:, 3] * rng.uniform(64.0, 8192.0, rows)
    return x


def _rand_weights(rng):
    w_pf = rng.normal(0.0, 0.05, ref.N_FEATURES).astype(np.float32)
    w_dec = rng.normal(0.0, 0.05, ref.N_FEATURES).astype(np.float32)
    mix = (abs(rng.normal(1e-4, 5e-5)), abs(rng.normal(1e-8, 5e-9)),
           abs(rng.normal(1e-6, 5e-7)))
    return w_pf, w_dec, mix


@pytest.mark.parametrize("rows", [16, 32, 64, 128])
def test_kernel_matches_ref(rows):
    rng = np.random.default_rng(rows)
    x = _rand_inputs(rng, rows)
    w_pf, w_dec, mix = _rand_weights(rng)
    got = predictor.predict(jnp.asarray(x), w_pf, w_dec, mix)
    want = ref.predict(jnp.asarray(x), jnp.asarray(w_pf), jnp.asarray(w_dec), mix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 6),
    block_r=st.sampled_from([8, 16, 32]),
)
def test_kernel_matches_ref_hypothesis(seed, blocks, block_r):
    rng = np.random.default_rng(seed)
    rows = blocks * block_r
    x = _rand_inputs(rng, rows)
    w_pf, w_dec, mix = _rand_weights(rng)
    got = predictor.predict(jnp.asarray(x), w_pf, w_dec, mix, block_r=block_r)
    want = ref.predict(jnp.asarray(x), jnp.asarray(w_pf), jnp.asarray(w_dec), mix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


def test_kernel_rejects_ragged_rows():
    with pytest.raises(ValueError):
        predictor.predict(jnp.zeros((17, ref.N_RAW)), np.zeros(6), np.zeros(6), (0.0, 0.0, 0.0))


def test_zero_rows_zero_output():
    x = np.zeros((16, ref.N_RAW), dtype=np.float32)
    rng = np.random.default_rng(0)
    w_pf, w_dec, mix = _rand_weights(rng)
    out = np.asarray(predictor.predict(jnp.asarray(x), w_pf, w_dec, mix))
    # no prefill and no decode work -> all heads exactly 0 (padding rows)
    np.testing.assert_array_equal(out, np.zeros((16, 3), dtype=np.float32))


def test_combined_never_below_max_head():
    rng = np.random.default_rng(7)
    x = _rand_inputs(rng, 64)
    w_pf, w_dec, mix = _rand_weights(rng)
    out = np.asarray(predictor.predict(jnp.asarray(x), w_pf, w_dec, mix))
    assert (out[:, 2] >= np.maximum(out[:, 0], out[:, 1]) - 1e-7).all()


def test_int_input_dtype_promoted():
    rng = np.random.default_rng(3)
    x = _rand_inputs(rng, 16).astype(np.int32).astype(np.float64)
    w_pf, w_dec, mix = _rand_weights(rng)
    got = predictor.predict(jnp.asarray(x), w_pf, w_dec, mix)
    assert np.asarray(got).dtype == np.float32
