"""L2 — the JAX compute graph the rust coordinator executes via PJRT.

For each (model, npu, tp) variant this builds ``predict_step_times``:
a jitted function over a fixed-shape candidate batch (MAX_ROWS × 5 raw
step features) that calls the L1 Pallas predictor kernel with that
variant's regression coefficients baked in as constants. One HLO module
per variant — "one compiled executable per model variant".
"""

import jax
import jax.numpy as jnp
import numpy as np

from .fit import FitResult
from .kernels import predictor
from .kernels.ref import N_RAW

# Fixed candidate-batch size of the AOT artifact. The rust scheduler pads
# its candidate step plans up to this many rows per PJRT call. 16 is the
# measured sweet spot between per-call PJRT overhead (dominates small
# rows) and padding waste (dominates large rows) — EXPERIMENTS.md §Perf.
MAX_ROWS = 16


def build_predict_fn(res: FitResult, rows: int = MAX_ROWS, block_r: int = predictor.BLOCK_R):
    """Returns f(x: f32[rows, N_RAW]) -> f32[rows, 3] with coefficients
    baked as HLO constants (no weight inputs at runtime)."""
    w_pf = np.asarray(res.w_pf, dtype=np.float32)
    w_dec = np.asarray(res.w_dec, dtype=np.float32)
    mix = (res.c_dec_b, res.c_dec_kv, res.m_pf_tok)

    def predict_step_times(x):
        x = x.astype(jnp.float32)
        return (predictor.predict(x, w_pf, w_dec, mix, block_r=block_r),)

    return predict_step_times, jax.ShapeDtypeStruct((rows, N_RAW), jnp.float32)


def lower_to_hlo_text(res: FitResult, rows: int = MAX_ROWS,
                      block_r: int = predictor.BLOCK_R) -> str:
    """AOT-lower a variant to HLO *text* (the interchange format — the
    image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos with
    64-bit instruction ids; the text parser reassigns ids)."""
    from jax._src.lib import xla_client as xc

    fn, spec = build_predict_fn(res, rows, block_r)
    lowered = jax.jit(fn).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
