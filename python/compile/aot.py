"""AOT entry point: fit every configured variant, lower to HLO text, and
emit the artifact bundle the rust simulator loads.

    artifacts/
      runtime_<model>_<npu>_tp<k>.hlo.txt   # one PJRT executable per variant
      coefficients.json                     # same coefficients for the
                                            #   native rust PolyPerfModel
      manifest.json                         # variant -> file map + shapes

Run via ``make artifacts`` (idempotent: the Makefile only re-runs this
when the python sources change). Python never runs at simulation time.
"""

import argparse
import json
import os
import time

from . import fit as fitmod
from . import model as modelmod
from .kernels import predictor
from .kernels.ref import N_RAW

# (model, npu, tp) variants fitted by default: the Fig 6/10–13 serving
# configs (Llama-3-70B on H100 at TP2/4/8) plus the Fig 5 validation
# models at TP8. Everything else falls back to the rust roofline model.
DEFAULT_VARIANTS = [
    ("llama3-70b", "h100", 2),
    ("llama3-70b", "h100", 4),
    ("llama3-70b", "h100", 8),
    ("llama2-70b", "h100", 8),
    ("bloom-176b", "h100", 8),
]


def variant_stem(model: str, npu: str, tp: int) -> str:
    return f"runtime_{model}_{npu}_tp{tp}"


def build(out_dir: str, variants=None, rows: int = modelmod.MAX_ROWS,
          block_r: int = predictor.BLOCK_R, n_points: int = fitmod.N_POINTS):
    os.makedirs(out_dir, exist_ok=True)
    variants = variants or DEFAULT_VARIANTS
    manifest = {"rows": rows, "n_raw": N_RAW, "block_r": block_r, "variants": {}}
    coeffs = {}
    for model_name, npu_name, tp in variants:
        t0 = time.time()
        res = fitmod.fit(model_name, npu_name, tp, n_points=n_points)
        hlo = modelmod.lower_to_hlo_text(res, rows=rows, block_r=block_r)
        stem = variant_stem(model_name, npu_name, tp)
        path = os.path.join(out_dir, stem + ".hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        key = f"{model_name}@{npu_name}/tp{tp}"
        manifest["variants"][key] = {
            "file": stem + ".hlo.txt",
            "model": model_name,
            "npu": npu_name,
            "tp": tp,
        }
        coeffs[key] = res.to_json_dict()
        print(
            f"[aot] {key}: mse_pf={res.mse_pf:.3e} mse_dec={res.mse_dec:.3e} "
            f"hlo={len(hlo) / 1024:.0f}KiB "
            f"({time.time() - t0:.1f}s)"
        )
    with open(os.path.join(out_dir, "coefficients.json"), "w") as f:
        json.dump(coeffs, f, indent=2, sort_keys=True)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {len(coeffs)} variants to {out_dir}/")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--rows", type=int, default=modelmod.MAX_ROWS)
    ap.add_argument("--block-r", type=int, default=predictor.BLOCK_R)
    ap.add_argument("--n-points", type=int, default=fitmod.N_POINTS,
                    help="synthetic trace size (58K mirrors the paper)")
    args = ap.parse_args()
    build(args.out_dir, rows=args.rows, block_r=args.block_r,
          n_points=args.n_points)


if __name__ == "__main__":
    main()
