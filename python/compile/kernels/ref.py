"""Pure-jnp oracle for the runtime-predictor kernel.

The contract both implementations honor (and hypothesis sweeps):

  predict(x, w_pf, w_dec, mix) -> (R, 3) float32

  x      : (R, 5) raw step features
           [pf_new_tokens, pf_past_tokens, pf_items, dec_batch, dec_kv_tokens]
  w_pf   : (F,) prefill-head coefficients (scaled feature space)
  w_dec  : (F,) decode-head coefficients
  mix    : (c_dec_b, c_dec_kv, m_pf_tok) — analytic cross terms for
           mixed steps (seconds per raw unit; see fit.FitResult)

  out[:, 0] = t_prefill   (0 where pf_new == 0)
  out[:, 1] = t_decode    (0 where dec_batch == 0)
  out[:, 2] = t_step      (combined; see below)

Combination rule (roofline-aware): a mixed step is either compute-bound
— the prefill-led path, which the riding decode batch only lengthens by
its GEMM/attention FLOPs — or memory-bound — the decode-led path, which
the prefill chunk only lengthens by its KV traffic:

  t_step = max( t_pf + c_dec_b·B + c_dec_kv·KV,    # compute-bound path
                t_dec + m_pf_tok·(new + past),      # memory-bound path
                t_pf, t_dec )

when both heads are active; the sum of heads otherwise.
"""

import jax.numpy as jnp

# Feature scales — raw features are divided by these before polynomial
# expansion so the lstsq fit stays well-conditioned. MUST match fit.py,
# the Pallas kernel, and rust perfmodel/poly.rs.
SCALES = (4096.0, 4096.0, 8.0, 64.0, 262144.0)

N_RAW = 5
N_FEATURES = 6


def prefill_features(x):
    """(R, 5) raw -> (R, 6) prefill polynomial features.

    Paper §III-E.1: "Prefill runtime is modeled using past token count,
    prefill token count, batch size, and token²."
    """
    s = x / jnp.array(SCALES, dtype=x.dtype)
    new, past, items = s[:, 0], s[:, 1], s[:, 2]
    ones = jnp.ones_like(new)
    return jnp.stack([ones, past, new, items, new * new, new * past], axis=1)


def decode_features(x):
    """(R, 5) raw -> (R, 6) decode polynomial features (batch, kv tokens)."""
    s = x / jnp.array(SCALES, dtype=x.dtype)
    b, kv = s[:, 3], s[:, 4]
    ones = jnp.ones_like(b)
    return jnp.stack([ones, b, kv, b * kv, b * b, kv * kv], axis=1)


def predict(x, w_pf, w_dec, mix):
    c_dec_b, c_dec_kv, m_pf_tok = (float(v) for v in mix)
    x = x.astype(jnp.float32)
    t_pf = prefill_features(x) @ w_pf.astype(jnp.float32)
    t_dec = decode_features(x) @ w_dec.astype(jnp.float32)
    has_pf = x[:, 0] > 0
    has_dec = x[:, 3] > 0
    t_pf = jnp.where(has_pf, jnp.maximum(t_pf, 0.0), 0.0)
    t_dec = jnp.where(has_dec, jnp.maximum(t_dec, 0.0), 0.0)
    both = jnp.logical_and(has_pf, has_dec)
    compute_path = t_pf + jnp.float32(c_dec_b) * x[:, 3] + jnp.float32(c_dec_kv) * x[:, 4]
    memory_path = t_dec + jnp.float32(m_pf_tok) * (x[:, 0] + x[:, 1])
    combined = jnp.where(
        both,
        jnp.maximum(jnp.maximum(compute_path, memory_path), jnp.maximum(t_pf, t_dec)),
        t_pf + t_dec,
    )
    return jnp.stack([t_pf, t_dec, combined], axis=1)
