"""L1 — Pallas kernel for the ML-assisted runtime predictor.

This is the compute hot-spot on the rust simulator's request path: every
engine step the scheduler prices a batch of candidate step plans (padded
to ``MAX_ROWS``), and this kernel expands each row's polynomial features
and evaluates both regression heads plus the combined mixed-step time.

Tiling: the candidate batch is tiled over rows with ``BlockSpec
((BLOCK_R, N_RAW), ...)`` — the HBM→VMEM schedule. Per block the kernel
touches BLOCK_R·(5 raw + 2·6 features + 3 outputs)·4 B ≈ 6 KiB ≪ 16 MiB
VMEM, so the kernel is trivially latency-bound; see DESIGN.md
§Hardware-Adaptation for why the heads stay on the f32 VPU path rather
than the bf16 MXU.

``interpret=True`` always: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO so
the AOT artifact runs anywhere (including the rust PJRT CPU client).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import N_FEATURES, N_RAW, SCALES

# Rows processed per grid step. 16 divides every MAX_ROWS we emit and
# keeps the interpret-mode overhead per call small; the block-size
# ablation lives in aot.py --block-sweep (EXPERIMENTS.md §Perf).
BLOCK_R = 16


def _kernel(x_ref, w_pf_ref, w_dec_ref, o_ref, *, mix):
    c_dec_b, c_dec_kv, m_pf_tok = mix
    x = x_ref[...].astype(jnp.float32)  # (BLOCK_R, N_RAW)
    # Per-column scaling with python-float scalars (pallas kernels may not
    # capture array constants, so no jnp.asarray(SCALES) here).
    new = x[:, 0] * (1.0 / SCALES[0])
    past = x[:, 1] * (1.0 / SCALES[1])
    items = x[:, 2] * (1.0 / SCALES[2])
    b = x[:, 3] * (1.0 / SCALES[3])
    kv = x[:, 4] * (1.0 / SCALES[4])
    ones = jnp.ones_like(new)

    # Polynomial feature expansion, in-register (matches ref.py).
    phi_pf = jnp.stack([ones, past, new, items, new * new, new * past], axis=1)
    phi_dec = jnp.stack([ones, b, kv, b * kv, b * b, kv * kv], axis=1)

    t_pf = phi_pf @ w_pf_ref[...]
    t_dec = phi_dec @ w_dec_ref[...]

    has_pf = x[:, 0] > 0
    has_dec = x[:, 3] > 0
    t_pf = jnp.where(has_pf, jnp.maximum(t_pf, 0.0), 0.0)
    t_dec = jnp.where(has_dec, jnp.maximum(t_dec, 0.0), 0.0)
    both = jnp.logical_and(has_pf, has_dec)
    # roofline-aware mixed-step combination (see ref.py docstring)
    compute_path = t_pf + c_dec_b * x[:, 3] + c_dec_kv * x[:, 4]
    memory_path = t_dec + m_pf_tok * (x[:, 0] + x[:, 1])
    combined = jnp.where(
        both,
        jnp.maximum(jnp.maximum(compute_path, memory_path), jnp.maximum(t_pf, t_dec)),
        t_pf + t_dec,
    )
    o_ref[...] = jnp.stack([t_pf, t_dec, combined], axis=1)


def predict(x, w_pf, w_dec, mix, block_r: int = BLOCK_R):
    """Pallas twin of ref.predict. x: (R, 5) with R % block_r == 0."""
    rows = x.shape[0]
    if rows % block_r != 0:
        raise ValueError(f"rows ({rows}) must be a multiple of block_r ({block_r})")
    kern = functools.partial(_kernel, mix=tuple(float(v) for v in mix))
    return pl.pallas_call(
        kern,
        grid=(rows // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, N_RAW), lambda i: (i, 0)),
            pl.BlockSpec((N_FEATURES,), lambda i: (0,)),
            pl.BlockSpec((N_FEATURES,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 3), jnp.float32),
        interpret=True,
    )(
        x,
        jnp.asarray(w_pf, dtype=jnp.float32),
        jnp.asarray(w_dec, dtype=jnp.float32),
    )
