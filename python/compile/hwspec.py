"""Hardware + model spec sheets and the GenZ-like roofline, mirrored from
``rust/src/hardware/`` (models.rs / npu.rs / roofline.rs).

This module is the *data generator* for the ML-assisted runtime predictor:
the paper collects 58K datapoints from a DGX-H100 running vLLM; we have no
DGX, so we synthesize the trace from the same analytical roofline the rust
simulator uses as its ground-truth hardware model (DESIGN.md §3,
substitution table). Keep the constants in lock-step with the rust side —
`rust/tests/pjrt_parity.rs` and the Fig 6 fidelity bench both fail loudly
if they drift.
"""

from dataclasses import dataclass

EFF_COMPUTE = 0.55
EFF_MEM = 0.75
STEP_OVERHEAD = 350e-6


@dataclass(frozen=True)
class ModelSpec:
    name: str
    params: float
    layers: int
    hidden: int
    heads: int
    kv_heads: int
    d_head: int
    # served decoder LLMs: fp8 weights (1 B/param); KV cache stays fp16
    bytes_per_param: float = 1.0

    @property
    def kv_bytes_per_token(self) -> float:
        return 2.0 * self.layers * self.kv_heads * self.d_head * 2.0

    @property
    def weight_bytes(self) -> float:
        return self.params * self.bytes_per_param

    @property
    def flops_per_token(self) -> float:
        return 2.0 * self.params

    def attn_flops(self, ctx: float) -> float:
        return 4.0 * self.layers * (self.heads * self.d_head) * ctx


@dataclass(frozen=True)
class NpuSpec:
    name: str
    peak_flops: float
    mem_bw: float
    mem_capacity: float
    tdp_w: float
    idle_w: float
    link_bw: float
    link_lat: float
    pcie_bw: float


LLAMA2_70B = ModelSpec("llama2-70b", 70e9, 80, 8192, 64, 8, 128)
LLAMA3_70B = ModelSpec("llama3-70b", 70.6e9, 80, 8192, 64, 8, 128)
LLAMA3_8B = ModelSpec("llama3.1-8b", 8.03e9, 32, 4096, 32, 8, 128)
BLOOM_176B = ModelSpec("bloom-176b", 176e9, 70, 14336, 112, 112, 128)
MISTRAL_7B = ModelSpec("mistral-7b", 7.24e9, 32, 4096, 32, 8, 128)
E5_BASE = ModelSpec("e5-base", 0.11e9, 12, 768, 12, 12, 64, bytes_per_param=2.0)

H100 = NpuSpec("h100", 989e12, 3.35e12, 80e9, 700.0, 90.0, 900e9, 2.0e-6, 64e9)
A100 = NpuSpec("a100", 312e12, 2.04e12, 80e9, 400.0, 60.0, 600e9, 2.5e-6, 32e9)

MODELS = {m.name: m for m in [LLAMA2_70B, LLAMA3_70B, LLAMA3_8B, BLOOM_176B, MISTRAL_7B, E5_BASE]}
NPUS = {n.name: n for n in [H100, A100]}


def tp_comm_time(model: ModelSpec, npu: NpuSpec, tp: int, tokens: float) -> float:
    """Ring allreduce, twice per layer (mirrors LlmCluster::tp_comm_time)."""
    if tp <= 1 or tokens <= 0.0:
        return 0.0
    msg = tokens * model.hidden * 2.0
    per_ar = 2.0 * (tp - 1) / tp * msg / npu.link_bw + 2.0 * (tp - 1) * npu.link_lat
    return 2.0 * model.layers * per_ar


def step_time(
    model: ModelSpec,
    npu: NpuSpec,
    tp: int,
    pf_new: float,
    pf_past: float,
    pf_items: int,
    dec_batch: int,
    dec_kv: float,
) -> float:
    """Latency of one engine step (mirrors LlmCluster::mixed_time).

    Prefill work is summarized by aggregate (new, past) spread evenly over
    `pf_items` items — the same aggregation the predictor features use.
    """
    if pf_new <= 0 and dec_batch <= 0:
        return 0.0
    flops = 0.0
    byts = 0.0
    comm_tokens = 0.0
    if pf_new > 0:
        n_items = max(pf_items, 1)
        new_i = pf_new / n_items
        past_i = pf_past / n_items
        flops += model.flops_per_token * pf_new
        flops += n_items * new_i * model.attn_flops(past_i + new_i / 2.0)
        byts += model.kv_bytes_per_token * (pf_past + pf_new)
        comm_tokens += pf_new
    if dec_batch > 0:
        b = float(dec_batch)
        flops += model.flops_per_token * b
        flops += b * model.attn_flops(dec_kv / max(b, 1.0))
        byts += model.kv_bytes_per_token * (dec_kv + b)
        comm_tokens += b
    byts += model.weight_bytes
    t_compute = flops / (EFF_COMPUTE * npu.peak_flops * tp)
    t_memory = byts / (EFF_MEM * npu.mem_bw * tp)
    return max(t_compute, t_memory) + tp_comm_time(model, npu, tp, comm_tokens) + STEP_OVERHEAD


def weights_read_time(model: ModelSpec, npu: NpuSpec, tp: int) -> float:
    """Time to stream the weight shard once — the double-counted term when
    summing separately-predicted prefill + decode components of one step."""
    return model.weight_bytes / (EFF_MEM * npu.mem_bw * tp)
