"""Synthetic hardware-trace generation + polynomial regression fit.

Paper §III-E.1: "we used real hardware data collecting over 58K datapoints
on a DGX-H100 box running vLLM with LLaMA2-70B. We vary input size, batch
size, chunk size (for chunked batching), and tensor parallelism
(TP2/TP4/TP8). We observe that decode batches constitute ~96% of the
dataset. We use polynomial regression [...] decode runtime with MSE =
4.09e-07. Prefill runtime is modeled using past token count, prefill token
count, batch size, and token², with MSE = 6.49e-05."

We have no DGX-H100, so the trace is *synthesized* from the GenZ-like
roofline in hwspec.py (the same analytical model the rust simulator uses
for un-fitted configurations) with multiplicative log-normal measurement
noise. The fit itself — feature forms, scaled lstsq, MSE accounting — is
the paper's methodology verbatim.
"""

from dataclasses import dataclass, field

import numpy as np

from . import hwspec
from .kernels.ref import N_FEATURES, SCALES

# Dataset composition (paper: decode batches ≈ 96% of the 58K points).
N_POINTS = 58_000
DECODE_FRAC = 0.96
NOISE_SIGMA = 0.01  # 1% multiplicative measurement noise


@dataclass
class FitResult:
    model: str
    npu: str
    tp: int
    w_pf: np.ndarray
    w_dec: np.ndarray
    # Mixed-step cross terms (analytic, per variant), used by the
    # roofline-aware combination rule (see kernels/ref.py):
    #   c_dec_b  — compute seconds a decode sequence adds to a
    #              compute-bound (prefill-led) step
    #   c_dec_kv — compute seconds per decode KV token (attention flops)
    #   m_pf_tok — memory seconds a prefill token (incl. past) adds to a
    #              memory-bound (decode-led) step
    c_dec_b: float
    c_dec_kv: float
    m_pf_tok: float
    mse_pf: float
    mse_dec: float
    n_pf: int
    n_dec: int
    extras: dict = field(default_factory=dict)

    def to_json_dict(self) -> dict:
        return {
            "model": self.model,
            "npu": self.npu,
            "tp": self.tp,
            "scales": list(SCALES),
            "w_pf": [float(v) for v in self.w_pf],
            "w_dec": [float(v) for v in self.w_dec],
            "c_dec_b": float(self.c_dec_b),
            "c_dec_kv": float(self.c_dec_kv),
            "m_pf_tok": float(self.m_pf_tok),
            "mse_pf": float(self.mse_pf),
            "mse_dec": float(self.mse_dec),
            "n_pf": self.n_pf,
            "n_dec": self.n_dec,
        }


def _prefill_features_np(x: np.ndarray) -> np.ndarray:
    s = x / np.asarray(SCALES, dtype=np.float64)
    new, past, items = s[:, 0], s[:, 1], s[:, 2]
    ones = np.ones_like(new)
    return np.stack([ones, past, new, items, new * new, new * past], axis=1)


def _decode_features_np(x: np.ndarray) -> np.ndarray:
    s = x / np.asarray(SCALES, dtype=np.float64)
    b, kv = s[:, 3], s[:, 4]
    ones = np.ones_like(b)
    return np.stack([ones, b, kv, b * kv, b * b, kv * kv], axis=1)


def synth_trace(model: hwspec.ModelSpec, npu: hwspec.NpuSpec, tp: int,
                n_points: int = N_POINTS, seed: int = 0):
    """Sample (features, runtime) pairs over the vLLM-style sweep grid.

    Returns (x_pf, t_pf, x_dec, t_dec): raw 5-feature rows and noisy
    step times for the pure-prefill and pure-decode subsets.
    """
    rng = np.random.default_rng(seed)
    n_dec = int(n_points * DECODE_FRAC)
    n_pf = n_points - n_dec

    # --- decode points: batch size × context length grid ------------------
    b = rng.integers(1, 257, size=n_dec).astype(np.float64)
    ctx = np.exp(rng.uniform(np.log(64.0), np.log(8192.0), size=n_dec))
    kv = b * ctx
    x_dec = np.zeros((n_dec, 5))
    x_dec[:, 3] = b
    x_dec[:, 4] = kv
    t_dec = np.array(
        [hwspec.step_time(model, npu, tp, 0.0, 0.0, 0, int(bi), kvi)
         for bi, kvi in zip(b, kv)]
    )
    t_dec *= np.exp(rng.normal(0.0, NOISE_SIGMA, size=n_dec))

    # --- prefill points: input size × chunk size × batch grid -------------
    new = np.exp(rng.uniform(np.log(64.0), np.log(8192.0), size=n_pf))
    # chunked batching → some points carry past context
    past = np.where(rng.random(n_pf) < 0.5,
                    np.exp(rng.uniform(np.log(64.0), np.log(16384.0), size=n_pf)),
                    0.0)
    items = rng.integers(1, 9, size=n_pf).astype(np.float64)
    x_pf = np.zeros((n_pf, 5))
    x_pf[:, 0] = new
    x_pf[:, 1] = past
    x_pf[:, 2] = items
    t_pf = np.array(
        [hwspec.step_time(model, npu, tp, ni, pi, int(ii), 0, 0.0)
         for ni, pi, ii in zip(new, past, items)]
    )
    t_pf *= np.exp(rng.normal(0.0, NOISE_SIGMA, size=n_pf))

    return x_pf, t_pf, x_dec, t_dec


def fit(model_name: str, npu_name: str, tp: int,
        n_points: int = N_POINTS, seed: int = 0) -> FitResult:
    model = hwspec.MODELS[model_name]
    npu = hwspec.NPUS[npu_name]
    x_pf, t_pf, x_dec, t_dec = synth_trace(model, npu, tp, n_points, seed)

    phi_pf = _prefill_features_np(x_pf)
    phi_dec = _decode_features_np(x_dec)
    # Relative-error weighting: minimize ||(φw − t)/t||² so microsecond-
    # and second-scale steps carry equal weight — a latency predictor is
    # judged on relative error. (Plain MSE is still reported below, in
    # the units the paper uses.)
    w_pf, *_ = np.linalg.lstsq(phi_pf / t_pf[:, None], np.ones_like(t_pf), rcond=None)
    w_dec, *_ = np.linalg.lstsq(phi_dec / t_dec[:, None], np.ones_like(t_dec), rcond=None)
    assert w_pf.shape == (N_FEATURES,) and w_dec.shape == (N_FEATURES,)

    mse_pf = float(np.mean((phi_pf @ w_pf - t_pf) ** 2))
    mse_dec = float(np.mean((phi_dec @ w_dec - t_dec) ** 2))

    # analytic mixed-step cross terms (per raw unit, this variant)
    c_peak = hwspec.EFF_COMPUTE * npu.peak_flops * tp
    m_bw = hwspec.EFF_MEM * npu.mem_bw * tp
    c_dec_b = model.flops_per_token / c_peak
    c_dec_kv = 4.0 * model.layers * (model.heads * model.d_head) / c_peak
    m_pf_tok = model.kv_bytes_per_token / m_bw

    return FitResult(
        model=model_name, npu=npu_name, tp=tp,
        w_pf=w_pf.astype(np.float32), w_dec=w_dec.astype(np.float32),
        c_dec_b=c_dec_b, c_dec_kv=c_dec_kv, m_pf_tok=m_pf_tok,
        mse_pf=mse_pf, mse_dec=mse_dec,
        n_pf=len(t_pf), n_dec=len(t_dec),
    )
