//! Routing and load balancing (paper §III-B.1): Round-Robin, Load-based
//! and Heavy-Light-split policies, each parameterizable by a load metric
//! (input length / output length / KV size / tokens left) — the paper's
//! "up to nine distinct routing strategies". The router can also exploit
//! placement information to prefer low-transfer-cost destinations
//! (disaggregated local mode).

use crate::client::ClientLoad;
use crate::workload::request::Request;

/// Which request/client attribute quantifies "load".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMetric {
    InputLen,
    OutputLen,
    KvSize,
    TokensLeft,
}

impl LoadMetric {
    pub fn of(&self, l: &ClientLoad) -> f64 {
        match self {
            LoadMetric::InputLen => l.input_tokens,
            LoadMetric::OutputLen => l.output_tokens,
            LoadMetric::KvSize => l.kv_tokens,
            LoadMetric::TokensLeft => l.tokens_left,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoutePolicy {
    RoundRobin,
    /// least-loaded by metric
    LoadBased(LoadMetric),
    /// requests above `threshold_tokens` of prompt go to the heavy
    /// sub-pool (first `heavy_frac` of candidates), the rest to the
    /// light sub-pool; least-loaded within each (Intelligent-Router-like)
    HeavyLight {
        metric: LoadMetric,
        threshold_tokens: usize,
        heavy_frac: f64,
    },
}

/// A routing decision input: candidate client ids with their loads and
/// (optionally) the estimated transfer cost of moving this request there.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub client: usize,
    pub load: ClientLoad,
    /// seconds to move the request's state to this client (0 if local)
    pub transfer_cost: f64,
}

pub struct Router {
    pub policy: RoutePolicy,
    /// weight of transfer cost against load when ranking candidates
    /// (disaggregated KV locality, §III-B.1 last paragraph)
    pub transfer_weight: f64,
    rr_next: usize,
    pub decisions: u64,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router {
            policy,
            transfer_weight: 0.0,
            rr_next: 0,
            decisions: 0,
        }
    }

    pub fn with_transfer_weight(mut self, w: f64) -> Router {
        self.transfer_weight = w;
        self
    }

    /// Pick a client for `req` among `cands` (must be non-empty).
    pub fn pick(&mut self, req: &Request, cands: &[Candidate]) -> usize {
        assert!(!cands.is_empty(), "router: no capable client");
        self.decisions += 1;
        match self.policy {
            RoutePolicy::RoundRobin => {
                let c = cands[self.rr_next % cands.len()].client;
                self.rr_next += 1;
                c
            }
            RoutePolicy::LoadBased(metric) => self.least_loaded(cands, metric),
            RoutePolicy::HeavyLight {
                metric,
                threshold_tokens,
                heavy_frac,
            } => {
                let split = ((cands.len() as f64 * heavy_frac).round() as usize)
                    .clamp(1, cands.len().saturating_sub(1).max(1));
                let heavy = req.prompt_tokens >= threshold_tokens;
                let pool = if heavy {
                    &cands[..split]
                } else {
                    &cands[split.min(cands.len() - 1)..]
                };
                self.least_loaded(pool, metric)
            }
        }
    }

    fn least_loaded(&self, cands: &[Candidate], metric: LoadMetric) -> usize {
        cands
            .iter()
            .min_by(|a, b| {
                let ka = metric.of(&a.load) + self.transfer_weight * a.transfer_cost;
                let kb = metric.of(&b.load) + self.transfer_weight * b.transfer_cost;
                ka.partial_cmp(&kb)
                    .unwrap()
                    .then_with(|| a.client.cmp(&b.client))
            })
            .unwrap()
            .client
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::{Request, Stage};

    fn req(prompt: usize) -> Request {
        Request::new(
            1,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            10,
        )
    }

    fn cand(client: usize, tokens_left: f64) -> Candidate {
        Candidate {
            client,
            load: ClientLoad {
                tokens_left,
                input_tokens: tokens_left,
                ..Default::default()
            },
            transfer_cost: 0.0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let cands = vec![cand(0, 0.0), cand(1, 0.0), cand(2, 0.0)];
        let picks: Vec<usize> = (0..6).map(|_| r.pick(&req(100), &cands)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn load_based_picks_min() {
        let mut r = Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft));
        let cands = vec![cand(0, 500.0), cand(1, 100.0), cand(2, 900.0)];
        assert_eq!(r.pick(&req(100), &cands), 1);
    }

    #[test]
    fn heavy_light_splits_by_prompt_size() {
        let mut r = Router::new(RoutePolicy::HeavyLight {
            metric: LoadMetric::TokensLeft,
            threshold_tokens: 1000,
            heavy_frac: 0.5,
        });
        let cands = vec![cand(0, 9e9), cand(1, 9e9), cand(2, 0.0), cand(3, 0.0)];
        // heavy request → first half even though it is more loaded
        assert_eq!(r.pick(&req(4000), &cands), 0);
        // light request → second half
        assert_eq!(r.pick(&req(100), &cands), 2);
    }

    #[test]
    fn transfer_weight_biases_toward_local() {
        let mut r =
            Router::new(RoutePolicy::LoadBased(LoadMetric::KvSize)).with_transfer_weight(1e6);
        let cands = vec![
            Candidate {
                client: 0,
                load: ClientLoad { kv_tokens: 1000.0, ..Default::default() },
                transfer_cost: 0.0,
            },
            Candidate {
                client: 1,
                load: ClientLoad { kv_tokens: 0.0, ..Default::default() },
                transfer_cost: 0.5, // remote: 0.5s of KV movement
            },
        ];
        assert_eq!(r.pick(&req(100), &cands), 0, "locality should win");
        r.transfer_weight = 0.0;
        assert_eq!(r.pick(&req(100), &cands), 1, "pure load ignores locality");
    }

    #[test]
    fn deterministic_tie_break() {
        let mut r = Router::new(RoutePolicy::LoadBased(LoadMetric::InputLen));
        let cands = vec![cand(3, 5.0), cand(1, 5.0), cand(2, 5.0)];
        assert_eq!(r.pick(&req(100), &cands), 1);
    }

    #[test]
    #[should_panic(expected = "no capable client")]
    fn empty_candidates_panics() {
        Router::new(RoutePolicy::RoundRobin).pick(&req(1), &[]);
    }
}
