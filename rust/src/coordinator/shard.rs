//! Sharded event loop: conservative time-window parallel simulation.
//!
//! A serial [`Coordinator`] run is strictly single-threaded — `--jobs N`
//! only fans out *independent* sweep points. This module parallelizes a
//! **single** run: clients are partitioned (by rack, via
//! [`Network::rack_of`]) into K domains, each stepped by its own thread
//! as a full per-domain `Coordinator` (own [`EventQueue`], own
//! [`RequestPool`](crate::scheduler::RequestPool) slice — only this
//! domain's requests are ever inserted — and own filtered arrival
//! stream). Domains advance in lock-step windows of width
//! [`Network::lookahead`]: every cross-domain interaction rides the DCN
//! spine, whose one-way latency lower-bounds how fast one domain can
//! affect another, so events inside a window are causally independent
//! across domains — classic conservative (YAWNS-style) synchronization.
//!
//! # Why the result is bit-identical to the serial oracle
//!
//! Determinism needs more than a barrier; the serial run's *global*
//! event order must be reproduced wherever state is shared:
//!
//! * **Routing domains.** The *closure* maps every reachable
//!   `(stage kind, model)` pair to the set of clients that can serve it
//!   ([`Client::can_serve`]). Racks whose clients co-occur in any one
//!   closure set are unioned into a component, and components map to
//!   domains — so a routing decision's candidate set always lives
//!   entirely inside one domain, and the serial candidate scan (in
//!   client-id order) is reproduced locally.
//! * **Cross-domain hand-offs** ([`EgressOp::Handoff`]) leave the
//!   source pool at the hop instant and are exchanged at the window
//!   barrier. The orchestrator prices all deferred hops in global
//!   `(time, source domain, emission seq)` order on the *one* DCN
//!   [`Link`](crate::network::Link) it owns, so the spine's FIFO
//!   busy-until state mutates exactly as the serial run's would. The
//!   target domain routes the delivery against its [`LoadHistory`] "as
//!   of" the hop instant — the loads the serial router would have read.
//! * **Local hops that cross racks** ([`EgressOp::Priced`]) route
//!   immediately (loads are live and domain-local) but defer only the
//!   shared-spine pricing to the same global replay.
//! * **f64 accumulator order.** `transfer_bytes`/`transfer_seconds` and
//!   per-client energy are summed at merge time in the serial
//!   accumulation order (global transfer order; ascending client id),
//!   so even float rounding is reproduced bit for bit.
//!
//! Completion records merge by `(completion time, domain, emission
//! index)`. The one caveat: two events in *different* domains at the
//! exact same integer nanosecond are ordered by domain index here,
//! while the serial run orders them by queue insertion sequence.
//! Cross-domain same-nanosecond collisions do not occur in the physical
//! scenarios the equivalence suite pins (arrival and step durations are
//! full-precision f64 physics), but a pathological workload could
//! construct one — the differential tests are the guard.
//!
//! # Serial fallback
//!
//! Configurations whose semantics are inherently global fall back to
//! the serial loop (the run is still correct, just not parallel):
//! `RoundRobin` routing (one global cursor), `DummyLink` networks (one
//! global serializing link), `local_disagg` (group state crosses the
//! closure partition), any `model_policy` (a request's model — and so
//! its closure key — can change mid-flight), a closure set spanning
//! more than one domain after component grouping, or fewer than two
//! effective domains. `--shards 1` is the explicit oracle path.
//!
//! See docs/performance.md ("Sharded execution").

use std::collections::HashMap;
use std::mem::{discriminant, Discriminant};
use std::sync::mpsc;

use anyhow::Result;

use super::{ArrivalSource, Candidate, Coordinator, CoordStats, Event, RoutePolicy};
use crate::client::ClientLoad;
use crate::metrics::MetricsSink;
use crate::model::ModelId;
use crate::network::{Granularity, Network, NetworkKind};
use crate::scheduler::PoolOps;
use crate::sim::SimTime;
use crate::workload::request::{CompletionRecord, ReqId, Request, Stage};
use crate::workload::stream::StreamingMix;
use crate::workload::trace::WorkloadMix;

/// A routing-closure key: which *kind* of stage, for which model. Stage
/// parameters (RAG doc counts, KV cache sizes) never affect
/// [`Client::can_serve`] — the plan builder verifies this per key and
/// falls back to serial if a workload violates it.
type StageKey = (Discriminant<Stage>, ModelId);

/// Per-domain sharding context, attached to a domain's `Coordinator`
/// (`coord.shard`). `None` in the serial oracle.
pub(crate) struct ShardCtx {
    /// this domain's index
    pub(crate) domain: u32,
    /// `(stage kind, model)` → owning domain, for every reachable pair
    pub(crate) closure: HashMap<StageKey, u32>,
    /// client id → does this domain own it? Crash events arm only in
    /// the owning domain (`Coordinator::arm_fault_events`), so the
    /// union across domains reproduces the serial crash schedule
    pub(crate) owns_client: Vec<bool>,
    /// cross-domain operations emitted during the current window, in
    /// emission order (the `seq` of the global `(time, domain, seq)`
    /// pricing order)
    pub(crate) egress: Vec<EgressOp>,
    /// completion instant of `records[i]` — the cross-domain merge key
    pub(crate) record_keys: Vec<SimTime>,
    /// (instant, bytes, exposed seconds) of every *locally priced*
    /// transfer, in emission order — merged with the orchestrator's log
    /// to replay the serial f64 accumulation order
    pub(crate) transfer_log: Vec<(SimTime, f64, f64)>,
    /// per-(client, model) load snapshots over the current window
    pub(crate) history: LoadHistory,
}

/// Per-(client, model) load time series over one window: the target
/// domain routes barrier deliveries against the loads "as of" the hop
/// instant — exactly what the serial router would have read, because
/// routing itself never changes loads (its effect lands with the
/// delivery event, ≥ one lookahead later).
#[derive(Default)]
pub(crate) struct LoadHistory {
    /// model key: `Some(m)` per served model; `None` for model-agnostic
    /// clients (whose `load_for_model` is their aggregate load)
    series: HashMap<(usize, Option<ModelId>), Vec<(SimTime, ClientLoad)>>,
}

impl LoadHistory {
    pub(crate) fn record(
        &mut self,
        client: usize,
        model: Option<ModelId>,
        t: SimTime,
        load: ClientLoad,
    ) {
        let s = self.series.entry((client, model)).or_default();
        if let Some(last) = s.last_mut() {
            if last.0 == t {
                last.1 = load;
                return;
            }
        }
        s.push((t, load));
    }

    /// Last recorded load at or before `t` (idle-since-start clients
    /// read as `ClientLoad::default()`, which is what their live
    /// counters hold too).
    pub(crate) fn load_at(&self, client: usize, model: Option<ModelId>, t: SimTime) -> ClientLoad {
        self.series
            .get(&(client, model))
            .and_then(|s| s.iter().rev().find(|(ts, _)| *ts <= t))
            .map(|&(_, l)| l)
            .unwrap_or_default()
    }

    /// Drop everything but the latest snapshot per series. Called at
    /// the barrier *after* the window's deliveries routed (they need
    /// the previous window's history), so memory stays O(events per
    /// window), not O(run).
    pub(crate) fn prune(&mut self) {
        for s in self.series.values_mut() {
            if s.len() > 1 {
                s.drain(..s.len() - 1);
            }
        }
    }
}

/// A cross-domain operation deferred to the window barrier.
pub(crate) enum EgressOp {
    /// The request's next stage is served in another domain: the
    /// request itself leaves this domain's pool at instant `t`; the
    /// orchestrator prices the spine hop and the *target* domain routes
    /// and re-hosts it.
    Handoff {
        t: SimTime,
        req: Box<Request>,
        src: usize,
        bytes: f64,
        gran: Granularity,
        staging: f64,
        target: u32,
    },
    /// The hop was routed locally (`src` → `dst`, both in this domain)
    /// but crosses racks, so its pricing must replay on the shared DCN
    /// spine in global order. The request stays in the local pool; the
    /// arrival event is injected back at the barrier.
    Priced {
        t: SimTime,
        req: ReqId,
        src: usize,
        dst: usize,
        bytes: f64,
        gran: Granularity,
        staging: f64,
    },
}

impl EgressOp {
    fn time(&self) -> SimTime {
        match self {
            EgressOp::Handoff { t, .. } | EgressOp::Priced { t, .. } => *t,
        }
    }
}

/// A priced operation delivered to a domain at a window barrier.
pub(crate) enum Delivery {
    /// a hand-off from another domain: insert into the pool, route
    /// against the window history as of `t`, arrive at `avail`
    Route {
        t: SimTime,
        avail: SimTime,
        req: Box<Request>,
        src: usize,
        bytes: f64,
        gran: Granularity,
    },
    /// a locally routed hop whose spine pricing resolved to `avail`
    Push { avail: SimTime, req: ReqId, dst: usize },
}

enum Cmd {
    /// apply `deliveries` (in global order), then drain events strictly
    /// before `end`
    Window { deliveries: Vec<Delivery>, end: SimTime },
    Finish,
}

enum Rsp {
    Window {
        egress: Vec<EgressOp>,
        /// earliest pending local event/arrival, if any
        next: Option<SimTime>,
    },
    Done(Box<DomainResult>),
}

/// What a domain hands back at shutdown.
struct DomainResult {
    records: Vec<CompletionRecord>,
    record_keys: Vec<SimTime>,
    transfer_log: Vec<(SimTime, f64, f64)>,
    /// this domain's streaming metrics accumulator (`--metrics sketch`);
    /// `records`/`record_keys` stay empty when present
    sink: Option<MetricsSink>,
    stats: CoordStats,
    clock: SimTime,
    /// (client id, joules) for the clients this domain *owns* — foreign
    /// replicas sit idle at exactly 0 J and are skipped (adding their
    /// 0.0 terms in id order at merge keeps the serial f64 sum)
    energy: Vec<(usize, f64)>,
    decisions: u64,
    pool_ops: PoolOps,
}

/// Where a sharded run's requests come from — mirrors
/// [`Coordinator::inject`] / [`Coordinator::stream`].
pub enum Arrivals<'a> {
    Stream(&'a WorkloadMix),
    Inject(Vec<Request>),
}

/// Merged result of a sharded run — everything
/// [`RunMetrics`](crate::metrics::RunMetrics) and the differential
/// tests need, bit-identical to the serial coordinator's fields (peaks
/// excepted: `peak_queue` is a max, `peak_inflight`/pool peaks are sums
/// of per-domain peaks, so they bound rather than equal the serial
/// values).
pub struct ShardOutcome {
    /// requested shard count (`--shards N`)
    pub shards: usize,
    /// effective domain count (1 = the serial oracle path ran)
    pub domains: usize,
    pub records: Vec<CompletionRecord>,
    pub serviced: Vec<ReqId>,
    pub failed: Vec<ReqId>,
    /// merged streaming metrics sink (`--metrics sketch` runs): folded
    /// from the per-domain sinks in ascending domain order, so the one
    /// order-sensitive f64 (the mean's sum) is deterministic at any
    /// shard count; quantiles are bit-identical by construction (integer
    /// bins). `records`/`serviced`/`failed` are empty when present.
    pub sink: Option<MetricsSink>,
    pub clock: SimTime,
    pub stats: CoordStats,
    pub energy_joules: f64,
    pub decisions: u64,
    pub pool_ops: PoolOps,
    /// the run's compiled fault plan, if any — carried so metrics can
    /// derive per-client availability from the crash windows
    pub faults: Option<crate::fault::FaultPlan>,
}

impl ShardOutcome {
    /// Wrap a finished serial run (the fallback / `--shards 1` path).
    pub fn from_serial(mut coord: Coordinator, shards: usize) -> ShardOutcome {
        ShardOutcome {
            shards,
            domains: 1,
            records: std::mem::take(&mut coord.records),
            serviced: std::mem::take(&mut coord.serviced),
            failed: std::mem::take(&mut coord.failed),
            sink: coord.sink.take(),
            clock: coord.clock,
            stats: coord.stats.clone(),
            energy_joules: coord
                .clients
                .iter()
                .map(|c| c.stats().energy_joules)
                .sum(),
            decisions: coord.router.decisions,
            pool_ops: coord.pool.ops(),
            faults: coord.faults.clone(),
        }
    }

    /// Every injected request completed or failed. Counter-based so it
    /// holds in streaming-metrics mode, where the ID vecs stay empty.
    pub fn all_serviced(&self) -> bool {
        self.stats.serviced + self.stats.failed == self.stats.injected
    }
}

// ---------------------------------------------------------------------
// Coordinator hooks (called from the event loop in mod.rs)
// ---------------------------------------------------------------------

impl Coordinator {
    /// Snapshot client `c`'s per-model loads into the window history.
    /// Called after every load-changing point (accept, step finish) —
    /// one client per event, so this is O(models) per event.
    pub(crate) fn shard_note_load(&mut self, c: usize) {
        let Some(ctx) = self.shard.as_deref_mut() else {
            return;
        };
        let t = self.clock;
        let cl = &self.clients[c];
        let models = cl.served_models();
        if models.is_empty() {
            ctx.history.record(c, None, t, cl.load());
        } else {
            for &m in models {
                ctx.history.record(c, Some(m), t, cl.load_for_model(m));
            }
        }
    }

    /// Earliest pending local work: the next queued event or streaming
    /// arrival, whichever is earlier.
    fn shard_next_time(&self) -> Option<SimTime> {
        match (self.source.peek(), self.queue.peek_time()) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Handle a post-`advance` hop under sharding: ship it to its
    /// owning domain, defer its spine pricing, or handle it entirely
    /// locally. Always consumes the hop (returns `true`).
    pub(crate) fn shard_defer(
        &mut self,
        id: ReqId,
        src: usize,
        bytes: f64,
        gran: Granularity,
        staging: f64,
    ) -> bool {
        let (target, own) = {
            let r = &self.pool[&id];
            let key = (discriminant(&r.stage()), r.model);
            let ctx = self.shard.as_deref().expect("shard_defer without ctx");
            (ctx.closure.get(&key).copied(), ctx.domain)
        };
        if let Some(tgt) = target {
            if tgt != own {
                // the next stage's candidates live in another domain:
                // ship the request at the window barrier. Every hop
                // moves at least the prompt text, so the spine latency
                // (= the lookahead) genuinely separates the domains.
                debug_assert!(bytes > 0.0, "cross-domain hand-off with no payload");
                self.stats.inflight -= 1;
                let req = self.pool.remove(id);
                let t = self.clock;
                let ctx = self.shard.as_deref_mut().expect("shard ctx");
                ctx.egress.push(EgressOp::Handoff {
                    t,
                    req: Box::new(req),
                    src,
                    bytes,
                    gran,
                    staging,
                    target: tgt,
                });
                return true;
            }
        }
        // candidates (if any) are domain-local: route now, against live
        // local loads. Only a non-empty cross-rack hop touches the
        // shared DCN spine — defer just its pricing to the barrier. A
        // zero-byte or intra-rack hop prices on domain-local state
        // (NVLink / this domain's own rack switches), bit-identically
        // to the serial path.
        match self.route(id, Some(src), bytes, gran) {
            Some(dst)
                if bytes > 0.0 && self.network.rack_of(src) != self.network.rack_of(dst) =>
            {
                let t = self.clock;
                let ctx = self.shard.as_deref_mut().expect("shard ctx");
                ctx.egress.push(EgressOp::Priced {
                    t,
                    req: id,
                    src,
                    dst,
                    bytes,
                    gran,
                    staging,
                });
            }
            Some(dst) => self.dispatch(id, src, dst, bytes, gran, staging),
            None => self.fail(id),
        }
        true
    }

    /// Apply one barrier delivery. Deliveries arrive in global
    /// `(time, domain, seq)` order, so the pushes they enqueue tie-break
    /// deterministically at any shard count.
    fn shard_apply_delivery(&mut self, dlv: Delivery) {
        match dlv {
            Delivery::Push { avail, req, dst } => {
                self.queue
                    .push(avail, Event::RequestPush { req, dst: Some(dst) });
            }
            Delivery::Route {
                t,
                avail,
                req,
                src,
                bytes,
                gran,
            } => {
                let id = req.id;
                let model = req.model;
                let stage = req.stage();
                self.stats.inflight += 1;
                self.stats.peak_inflight = self.stats.peak_inflight.max(self.stats.inflight);
                self.pool.insert(id, *req);
                // mirror `route()` exactly: candidates in client-id
                // order (HeavyLight splits the slice by order), loads
                // read from the window history as of the hop instant
                let ctx = self.shard.as_deref().expect("shard ctx");
                let mut cands: Vec<Candidate> = Vec::new();
                for c in &self.clients {
                    if !c.can_serve(&stage, model) {
                        continue;
                    }
                    // health is evaluated at the hop instant `t` — the
                    // moment the serial router would have run — not at
                    // this domain's (earlier) barrier clock
                    if let Some(plan) = &self.faults {
                        if !plan.health_at(t, c.id()) {
                            continue;
                        }
                    }
                    let key_model = if c.served_models().is_empty() {
                        None
                    } else {
                        Some(model)
                    };
                    let load = ctx.history.load_at(c.id(), key_model, t);
                    let transfer_cost = self.network.estimate(src, c.id(), bytes, gran);
                    cands.push(Candidate {
                        client: c.id(),
                        load,
                        transfer_cost,
                    });
                }
                if cands.is_empty() {
                    // unreachable when the closure routed here (the
                    // target domain owns this stage's candidates — and
                    // under faults the source's `fault_gate` already
                    // verified a healthy candidate at instant `t`);
                    // kept defensive, with the merge key fixed to the
                    // hop instant when a terminal record was emitted
                    let records_before = self.records.len();
                    self.no_candidate(id);
                    if self.records.len() > records_before {
                        if let Some(ctx) = self.shard.as_deref_mut() {
                            if let Some(k) = ctx.record_keys.last_mut() {
                                *k = t;
                            }
                        }
                    }
                    return;
                }
                let dst = {
                    let r = &self.pool[&id];
                    self.router.pick(r, &cands)
                };
                self.queue
                    .push(avail, Event::RequestPush { req: id, dst: Some(dst) });
            }
        }
    }
}

// ---------------------------------------------------------------------
// Plan: closure enumeration + rack components → domains
// ---------------------------------------------------------------------

/// The static sharding plan computed from a probe build + the workload.
pub(crate) struct Plan {
    /// effective domain count (≥ 2)
    pub(crate) domains: usize,
    /// conservative window width (the DCN one-way latency)
    pub(crate) lookahead: SimTime,
    /// `(stage kind, model)` → owning domain
    pub(crate) closure: HashMap<StageKey, u32>,
    /// rack → owning domain (candidate-free racks → 0)
    domain_of_rack: Vec<u32>,
    /// ingress domain per workload class (Stream runs; empty for Inject)
    class_domain: Vec<u32>,
}

impl Plan {
    fn domain_of_client(&self, net: &Network, c: usize) -> u32 {
        self.domain_of_rack[net.rack_of(c)]
    }

    /// Domain that hosts a request's first routable stage. A request
    /// with no servable ingress stage fails identically everywhere —
    /// domain 0 hosts it so exactly one domain counts it.
    fn ingress_domain(&self, stages: &[Stage], model: ModelId) -> u32 {
        ingress_key(stages, model)
            .and_then(|k| self.closure.get(&k).copied())
            .unwrap_or(0)
    }

    fn partition(&self, reqs: Vec<Request>) -> Vec<Vec<Request>> {
        let mut parts: Vec<Vec<Request>> = (0..self.domains).map(|_| Vec::new()).collect();
        for r in reqs {
            let d = self.ingress_domain(&r.stages[r.stage_idx..], r.model);
            parts[d as usize].push(r);
        }
        parts
    }
}

/// Key of the first stage the ingress router will see: leading
/// `ModelRoute` stages resolve inline before routing (and `model` is
/// static without a policy — a sharding precondition).
fn ingress_key(stages: &[Stage], model: ModelId) -> Option<StageKey> {
    stages
        .iter()
        .find(|s| !matches!(s, Stage::ModelRoute))
        .map(|s| (discriminant(s), model))
}

#[derive(Default)]
struct ClosureBuilder {
    sets: HashMap<StageKey, Vec<usize>>,
    reps: HashMap<StageKey, Stage>,
    consistent: bool,
}

impl ClosureBuilder {
    fn new() -> ClosureBuilder {
        ClosureBuilder {
            consistent: true,
            ..Default::default()
        }
    }

    fn candidate_set(probe: &Coordinator, stage: &Stage, model: ModelId) -> Vec<usize> {
        probe
            .clients
            .iter()
            .filter(|c| c.can_serve(stage, model))
            .map(|c| c.id())
            .collect()
    }

    fn visit(&mut self, probe: &Coordinator, stage: Stage, model: ModelId) {
        // ModelRoute / KvMigration resolve inline and never route to a
        // client — no closure entry (an un-consumed leading KvMigration
        // fails at ingress in every domain alike)
        if matches!(stage, Stage::ModelRoute | Stage::KvMigration) {
            return;
        }
        let key = (discriminant(&stage), model);
        match self.reps.get(&key) {
            Some(rep) if *rep == stage => {}
            Some(_) => {
                // same stage kind, different parameters: the closure is
                // only sound if can_serve ignores the parameters —
                // verify, and fall back to serial if not
                let set = Self::candidate_set(probe, &stage, model);
                if self.sets.get(&key) != Some(&set) {
                    self.consistent = false;
                }
            }
            None => {
                self.sets
                    .insert(key, Self::candidate_set(probe, &stage, model));
                self.reps.insert(key, stage);
            }
        }
    }

    fn visit_arrivals(&mut self, probe: &Coordinator, arrivals: &Arrivals<'_>) {
        match arrivals {
            Arrivals::Stream(mix) => {
                for i in 0..mix.classes.len() {
                    let spec = mix.class_spec(i);
                    for &s in spec.pipeline.stages().as_slice() {
                        self.visit(probe, s, spec.model);
                    }
                }
            }
            Arrivals::Inject(reqs) => {
                for r in reqs {
                    for &s in &r.stages[r.stage_idx..] {
                        self.visit(probe, s, r.model);
                    }
                }
            }
        }
    }
}

fn uf_find(uf: &mut [usize], mut x: usize) -> usize {
    while uf[x] != x {
        uf[x] = uf[uf[x]];
        x = uf[x];
    }
    x
}

/// Compute the sharding plan, or `None` for the serial fallback.
pub(crate) fn shard_plan(
    probe: &Coordinator,
    arrivals: &Arrivals<'_>,
    shards: usize,
) -> Option<Plan> {
    if shards < 2
        || probe.model_policy.is_some()
        || probe.local_disagg
        || matches!(probe.network.kind, NetworkKind::DummyLink(_))
        || matches!(probe.router.policy, RoutePolicy::RoundRobin)
    {
        return None;
    }
    let mut b = ClosureBuilder::new();
    b.visit_arrivals(probe, arrivals);
    if !b.consistent {
        return None;
    }
    let n_racks = probe
        .network
        .locations
        .iter()
        .map(|l| l.rack)
        .max()
        .map_or(0, |m| m + 1);
    if n_racks < 2 {
        return None;
    }
    // union racks that co-occur in any candidate set: a routing
    // decision must never span domains
    let mut uf: Vec<usize> = (0..n_racks).collect();
    for set in b.sets.values() {
        let mut it = set.iter();
        if let Some(&first) = it.next() {
            let r0 = uf_find(&mut uf, probe.network.rack_of(first));
            for &c in it {
                let rc = uf_find(&mut uf, probe.network.rack_of(c));
                uf[rc] = r0;
            }
        }
    }
    // candidate-hosting racks only: idle racks would dilute the domain
    // mapping without contributing any work
    let mut is_candidate_rack = vec![false; n_racks];
    for set in b.sets.values() {
        for &c in set {
            is_candidate_rack[probe.network.rack_of(c)] = true;
        }
    }
    // components ordered by their smallest rack index
    let mut comp_of_root: HashMap<usize, usize> = HashMap::new();
    for r in 0..n_racks {
        if is_candidate_rack[r] {
            let root = uf_find(&mut uf, r);
            let next = comp_of_root.len();
            comp_of_root.entry(root).or_insert(next);
        }
    }
    let n_comp = comp_of_root.len();
    let eff = shards.min(n_comp);
    if eff < 2 {
        return None;
    }
    // component j of n → domain j·eff/n (contiguous blocks)
    let mut domain_of_rack = vec![0u32; n_racks];
    for r in 0..n_racks {
        if is_candidate_rack[r] {
            let j = comp_of_root[&uf_find(&mut uf, r)];
            domain_of_rack[r] = (j * eff / n_comp) as u32;
        }
    }
    let closure: HashMap<StageKey, u32> = b
        .sets
        .iter()
        .filter(|(_, s)| !s.is_empty())
        .map(|(k, s)| (*k, domain_of_rack[probe.network.rack_of(s[0])]))
        .collect();
    // all candidates landing in one domain means nothing to parallelize
    let mut used: Vec<u32> = closure.values().copied().collect();
    used.sort_unstable();
    used.dedup();
    if used.len() < 2 {
        return None;
    }
    let class_domain = match arrivals {
        Arrivals::Stream(mix) => (0..mix.classes.len())
            .map(|i| {
                let spec = mix.class_spec(i);
                ingress_key(spec.pipeline.stages().as_slice(), spec.model)
                    .and_then(|k| closure.get(&k).copied())
                    .unwrap_or(0)
            })
            .collect(),
        Arrivals::Inject(_) => Vec::new(),
    };
    Some(Plan {
        domains: eff,
        lookahead: probe.network.lookahead(),
        closure,
        domain_of_rack,
        class_domain,
    })
}

// ---------------------------------------------------------------------
// Orchestrator
// ---------------------------------------------------------------------

enum DomainFeed<'a> {
    Stream(&'a WorkloadMix),
    Inject(Vec<Vec<Request>>),
}

enum DomainArrivals<'a> {
    Stream(&'a WorkloadMix),
    Inject(Vec<Request>),
}

/// Run one simulation across `shards` conservative-window domains.
///
/// `build` constructs a fresh coordinator (all clients, fully
/// configured, no workload attached) — it runs once on the calling
/// thread to probe the plan, then once inside each domain thread
/// (clients are intentionally not `Send`; each domain's foreign client
/// replicas stay idle at zero load and zero energy). Falls back to the
/// serial loop — bit-identical by construction — when the configuration
/// cannot be sharded; `ShardOutcome::domains` reports what actually ran.
pub fn run_sharded<F>(build: F, arrivals: Arrivals<'_>, shards: usize) -> Result<ShardOutcome>
where
    F: Fn() -> Result<Coordinator> + Sync,
{
    let mut probe = build()?;
    let Some(plan) = shard_plan(&probe, &arrivals, shards) else {
        match arrivals {
            Arrivals::Stream(mix) => probe.stream(mix),
            Arrivals::Inject(reqs) => probe.inject(reqs),
        }
        probe.run();
        return Ok(ShardOutcome::from_serial(probe, shards));
    };
    // the orchestrator prices every deferred cross-rack hop on the
    // probe's network — the one shared DCN spine, mutated in global
    // order exactly as the serial run would
    let mut net = std::mem::replace(&mut probe.network, Network::single_platform(0));
    let fault_plan = probe.faults.clone();
    let mut feed = match arrivals {
        Arrivals::Stream(mix) => DomainFeed::Stream(mix),
        Arrivals::Inject(reqs) => DomainFeed::Inject(plan.partition(reqs)),
    };
    drop(probe);

    let n = plan.domains;
    let plan_ref = &plan;
    let build_ref: &(dyn Fn() -> Result<Coordinator> + Sync) = &build;
    std::thread::scope(|scope| {
        let mut cmds = Vec::with_capacity(n);
        let mut rsps = Vec::with_capacity(n);
        for d in 0..n {
            let (ctx, crx) = mpsc::channel::<Cmd>();
            let (rtx, rrx) = mpsc::channel::<Rsp>();
            cmds.push(ctx);
            rsps.push(rrx);
            let arr = match &mut feed {
                DomainFeed::Stream(mix) => DomainArrivals::Stream(*mix),
                DomainFeed::Inject(parts) => {
                    DomainArrivals::Inject(std::mem::take(&mut parts[d]))
                }
            };
            scope.spawn(move || domain_worker(build_ref, plan_ref, d as u32, arr, crx, rtx));
        }
        let mut pending: Vec<Vec<Delivery>> = (0..n).map(|_| Vec::new()).collect();
        let mut orch_log: Vec<(SimTime, f64, f64)> = Vec::new();
        let mut orch_transfers: u64 = 0;
        // bootstrap: an empty window ([?, 0)) collects every domain's
        // first pending instant without processing anything
        let mut end = SimTime::ZERO;
        loop {
            for d in 0..n {
                cmds[d]
                    .send(Cmd::Window {
                        deliveries: std::mem::take(&mut pending[d]),
                        end,
                    })
                    .expect("domain worker alive");
            }
            let mut ops: Vec<(u32, usize, EgressOp)> = Vec::new();
            let mut next: Option<SimTime> = None;
            for (d, rsp) in rsps.iter().enumerate() {
                match rsp.recv().expect("domain worker alive") {
                    Rsp::Window { egress, next: dn } => {
                        for (i, op) in egress.into_iter().enumerate() {
                            ops.push((d as u32, i, op));
                        }
                        next = opt_min(next, dn);
                    }
                    Rsp::Done(_) => unreachable!("no Finish sent yet"),
                }
            }
            // global pricing order: (instant, source domain, emission seq)
            ops.sort_by_key(|(d, i, op)| (op.time(), *d, *i));
            for (d, _, op) in ops {
                match op {
                    EgressOp::Handoff {
                        t,
                        req,
                        src,
                        bytes,
                        gran,
                        staging,
                        target,
                    } => {
                        let avail =
                            net.dcn_transfer(t, bytes, gran) + SimTime::from_secs(staging);
                        orch_transfers += 1;
                        orch_log.push((t, bytes, (avail - t).as_secs()));
                        next = opt_min(next, Some(avail));
                        pending[target as usize].push(Delivery::Route {
                            t,
                            avail,
                            req,
                            src,
                            bytes,
                            gran,
                        });
                    }
                    EgressOp::Priced {
                        t,
                        req,
                        src: _,
                        dst,
                        bytes,
                        gran,
                        staging,
                    } => {
                        let avail =
                            net.dcn_transfer(t, bytes, gran) + SimTime::from_secs(staging);
                        orch_transfers += 1;
                        orch_log.push((t, bytes, (avail - t).as_secs()));
                        next = opt_min(next, Some(avail));
                        pending[d as usize].push(Delivery::Push { avail, req, dst });
                    }
                }
            }
            match next {
                // no pending events, arrivals or deliveries anywhere
                None => break,
                Some(start) => {
                    debug_assert!(start >= end, "window start regressed");
                    end = start + plan_ref.lookahead;
                }
            }
        }
        for cmd in &cmds {
            cmd.send(Cmd::Finish).expect("domain worker alive");
        }
        let mut parts = Vec::with_capacity(n);
        for rsp in &rsps {
            match rsp.recv().expect("domain worker alive") {
                Rsp::Done(r) => parts.push(*r),
                Rsp::Window { .. } => unreachable!("Finish answered with a window"),
            }
        }
        let mut out = merge(parts, orch_log, orch_transfers, shards, n);
        out.faults = fault_plan;
        Ok(out)
    })
}

fn opt_min(a: Option<SimTime>, b: Option<SimTime>) -> Option<SimTime> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.min(y)),
        (x, y) => x.or(y),
    }
}

fn domain_worker(
    build: &(dyn Fn() -> Result<Coordinator> + Sync),
    plan: &Plan,
    domain: u32,
    feed: DomainArrivals<'_>,
    rx: mpsc::Receiver<Cmd>,
    tx: mpsc::Sender<Rsp>,
) {
    let mut coord = build().expect("domain build must succeed (the probe build already did)");
    let owns_client = (0..coord.clients.len())
        .map(|c| plan.domain_of_client(&coord.network, c) == domain)
        .collect();
    coord.shard = Some(Box::new(ShardCtx {
        domain,
        closure: plan.closure.clone(),
        owns_client,
        egress: Vec::new(),
        record_keys: Vec::new(),
        transfer_log: Vec::new(),
        history: LoadHistory::default(),
    }));
    match feed {
        DomainArrivals::Inject(reqs) => coord.inject(reqs),
        DomainArrivals::Stream(mix) => {
            coord.source = ArrivalSource::Streaming(StreamingMix::filtered(mix, |i| {
                plan.class_domain[i] == domain
            }));
        }
    }
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Window { deliveries, end } => {
                // deliveries route against the *previous* window's
                // history, so apply before pruning
                for dlv in deliveries {
                    coord.shard_apply_delivery(dlv);
                }
                coord.shard.as_deref_mut().expect("shard ctx").history.prune();
                while coord.step_bounded(Some(end)) {}
                // satellite fix: revalidate the whole-pool load
                // invariant at every window barrier, not only per event
                // — inbox replay is the one place drift could first
                // appear
                #[cfg(debug_assertions)]
                coord.assert_load_invariant();
                let ctx = coord.shard.as_deref_mut().expect("shard ctx");
                let egress = std::mem::take(&mut ctx.egress);
                let next = coord.shard_next_time();
                tx.send(Rsp::Window { egress, next })
                    .expect("orchestrator alive");
            }
            Cmd::Finish => {
                tx.send(Rsp::Done(Box::new(DomainResult::extract(coord, plan))))
                    .expect("orchestrator alive");
                return;
            }
        }
    }
}

impl DomainResult {
    fn extract(mut coord: Coordinator, plan: &Plan) -> DomainResult {
        let ctx = coord.shard.take().expect("shard ctx");
        debug_assert!(ctx.egress.is_empty(), "undelivered egress at shutdown");
        debug_assert_eq!(coord.records.len(), ctx.record_keys.len());
        let energy = coord
            .clients
            .iter()
            .filter(|c| plan.domain_of_client(&coord.network, c.id()) == ctx.domain)
            .map(|c| (c.id(), c.stats().energy_joules))
            .collect();
        DomainResult {
            records: std::mem::take(&mut coord.records),
            record_keys: ctx.record_keys,
            transfer_log: ctx.transfer_log,
            sink: coord.sink.take(),
            stats: coord.stats.clone(),
            clock: coord.clock,
            energy,
            decisions: coord.router.decisions,
            pool_ops: coord.pool.ops(),
        }
    }
}

fn merge(
    parts: Vec<DomainResult>,
    orch_log: Vec<(SimTime, f64, f64)>,
    orch_transfers: u64,
    shards: usize,
    domains: usize,
) -> ShardOutcome {
    // completion records in global (instant, domain, emission) order
    let mut order: Vec<(SimTime, usize, usize)> = Vec::new();
    for (d, p) in parts.iter().enumerate() {
        for (i, &t) in p.record_keys.iter().enumerate() {
            order.push((t, d, i));
        }
    }
    order.sort_unstable();
    let mut records = Vec::with_capacity(order.len());
    let mut serviced = Vec::new();
    let mut failed = Vec::new();
    for (_, d, i) in order {
        let rec = parts[d].records[i];
        if rec.failed {
            failed.push(rec.id);
        } else {
            serviced.push(rec.id);
        }
        records.push(rec);
    }
    let mut stats = CoordStats::default();
    for p in &parts {
        stats.events += p.stats.events;
        stats.recomputes += p.stats.recomputes;
        stats.failed += p.stats.failed;
        stats.serviced += p.stats.serviced;
        stats.injected += p.stats.injected;
        stats.inflight += p.stats.inflight;
        stats.peak_queue = stats.peak_queue.max(p.stats.peak_queue);
        stats.peak_inflight += p.stats.peak_inflight;
        stats.transfers += p.stats.transfers;
        stats.retries += p.stats.retries;
        stats.timeouts += p.stats.timeouts;
        stats.shed += p.stats.shed;
        stats.orphaned += p.stats.orphaned;
    }
    stats.transfers += orch_transfers;
    // counter-based: in streaming-metrics mode the ID vecs stay empty,
    // while in exact mode the counters equal the vec lengths
    assert_eq!(
        stats.serviced + stats.failed,
        stats.injected,
        "sharded run lost requests in transit"
    );
    // per-domain streaming sinks fold in ascending domain order — the
    // deterministic merge order the bounded-error contract documents
    // (quantiles are merge-order-independent anyway; this pins the mean)
    let mut sink: Option<MetricsSink> = None;
    for p in &parts {
        if let Some(s) = &p.sink {
            match &mut sink {
                None => sink = Some(s.clone()),
                Some(acc) => acc.merge(s),
            }
        }
    }
    // f64 transfer accumulators replayed in global pricing order (the
    // orchestrator's barrier pricing sorts after same-instant local
    // pricing, matching the serial event sequence for distinct instants)
    let mut log: Vec<(SimTime, usize, usize, f64, f64)> = Vec::new();
    for (d, p) in parts.iter().enumerate() {
        for (i, &(t, bytes, secs)) in p.transfer_log.iter().enumerate() {
            log.push((t, d, i, bytes, secs));
        }
    }
    for (i, &(t, bytes, secs)) in orch_log.iter().enumerate() {
        log.push((t, usize::MAX, i, bytes, secs));
    }
    log.sort_unstable_by(|a, b| (a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)));
    for &(_, _, _, bytes, secs) in &log {
        stats.transfer_bytes += bytes;
        stats.transfer_seconds += secs;
    }
    // per-client energy summed in ascending client id — the serial
    // iteration order (foreign replicas' 0.0 terms change no sum)
    let mut energies: Vec<(usize, f64)> = parts
        .iter()
        .flat_map(|p| p.energy.iter().copied())
        .collect();
    energies.sort_unstable_by_key(|&(id, _)| id);
    let energy_joules = energies.iter().map(|&(_, e)| e).sum();
    let mut pool_ops = PoolOps::default();
    for p in &parts {
        pool_ops.absorb(&p.pool_ops);
    }
    ShardOutcome {
        shards,
        domains,
        records,
        serviced,
        failed,
        sink,
        clock: parts.iter().map(|p| p.clock).max().unwrap_or(SimTime::ZERO),
        stats,
        energy_joules,
        decisions: parts.iter().map(|p| p.decisions).sum(),
        pool_ops,
        faults: None, // installed by `run_sharded` from the probe build
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, LlmClient};
    use crate::coordinator::{LoadMetric, Router};
    use crate::hardware::models::LLAMA3_70B;
    use crate::hardware::npu::H100;
    use crate::hardware::roofline::LlmCluster;
    use crate::perfmodel::RooflinePerfModel;
    use crate::scheduler::{BatchingKind, LlmSched, Packing, SchedConfig};
    use crate::workload::trace::{TraceKind, WorkloadSpec};

    fn llm_client(id: usize, kind: BatchingKind) -> Box<dyn Client> {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        Box::new(LlmClient::new(
            id,
            cluster.clone(),
            LlmSched::new(kind, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        ))
    }

    /// 2 racks: prefill pool in rack 0, decode pool in rack 1.
    fn disagg_coord() -> Result<Coordinator> {
        let clients = vec![
            llm_client(0, BatchingKind::PrefillOnly),
            llm_client(1, BatchingKind::DecodeOnly),
        ];
        Ok(Coordinator::new(
            clients,
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::hierarchy(2, 1, 1),
        ))
    }

    fn workload(n: usize, rate: f64) -> Vec<Request> {
        WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, rate)
            .with_seed(11)
            .generate(0)
    }

    #[test]
    fn load_history_snapshots_and_prunes() {
        let mut h = LoadHistory::default();
        let l1 = ClientLoad {
            tokens_left: 5.0,
            ..Default::default()
        };
        let l2 = ClientLoad {
            tokens_left: 9.0,
            ..Default::default()
        };
        h.record(0, None, SimTime::from_secs(1.0), l1);
        h.record(0, None, SimTime::from_secs(2.0), l2);
        // unknown series and pre-history instants read as default
        assert_eq!(h.load_at(1, None, SimTime::from_secs(5.0)), ClientLoad::default());
        assert_eq!(h.load_at(0, None, SimTime::from_secs(0.5)), ClientLoad::default());
        // "as of": latest snapshot at or before t
        assert_eq!(h.load_at(0, None, SimTime::from_secs(1.0)), l1);
        assert_eq!(h.load_at(0, None, SimTime::from_secs(1.5)), l1);
        assert_eq!(h.load_at(0, None, SimTime::from_secs(2.0)), l2);
        // same-instant re-record overwrites in place
        h.record(0, None, SimTime::from_secs(2.0), l1);
        assert_eq!(h.load_at(0, None, SimTime::from_secs(2.0)), l1);
        // prune keeps only the latest snapshot
        h.prune();
        assert_eq!(h.load_at(0, None, SimTime::from_secs(9.0)), l1);
        assert_eq!(h.series[&(0, None)].len(), 1);
    }

    #[test]
    fn plan_splits_disagg_pools_into_two_domains() {
        let probe = disagg_coord().unwrap();
        let reqs = workload(4, 4.0);
        let plan = shard_plan(&probe, &Arrivals::Inject(reqs.clone()), 2)
            .expect("cross-rack disagg must shard");
        assert_eq!(plan.domains, 2);
        let prefill_key = (discriminant(&Stage::Prefill), reqs[0].model);
        let decode_key = (discriminant(&Stage::Decode), reqs[0].model);
        assert_eq!(plan.closure[&prefill_key], 0);
        assert_eq!(plan.closure[&decode_key], 1);
        // all requests enter at the prefill domain
        let parts = plan.partition(reqs);
        assert_eq!(parts[0].len(), 4);
        assert_eq!(parts[1].len(), 0);
    }

    #[test]
    fn plan_falls_back_when_unshardable() {
        let probe = disagg_coord().unwrap();
        let arr = Arrivals::Inject(workload(2, 4.0));
        // shards < 2
        assert!(shard_plan(&probe, &arr, 1).is_none());
        // global round-robin cursor
        let mut rr = disagg_coord().unwrap();
        rr.router = Router::new(RoutePolicy::RoundRobin);
        assert!(shard_plan(&rr, &arr, 2).is_none());
        // local disaggregation groups span the closure partition
        let mut local = disagg_coord().unwrap();
        local.local_disagg = true;
        assert!(shard_plan(&local, &arr, 2).is_none());
        // single rack → single domain
        let single = Coordinator::new(
            vec![
                llm_client(0, BatchingKind::PrefillOnly),
                llm_client(1, BatchingKind::DecodeOnly),
            ],
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::single_platform(2),
        );
        assert!(shard_plan(&single, &arr, 2).is_none());
        // a load-balanced pool spanning both racks unions them into one
        // component → one domain → fallback
        let spanning = Coordinator::new(
            vec![
                llm_client(0, BatchingKind::Continuous),
                llm_client(1, BatchingKind::Continuous),
            ],
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::hierarchy(2, 1, 1),
        );
        assert!(shard_plan(&spanning, &arr, 2).is_none());
    }

    #[test]
    fn sharded_disagg_matches_serial_bitwise() {
        // the in-module smoke; the full matrix (scenarios × shard
        // counts × load modes × --jobs) lives in
        // rust/tests/shard_equivalence.rs
        let mut serial = disagg_coord().unwrap();
        serial.inject(workload(30, 6.0));
        serial.run();
        assert!(serial.all_serviced());

        let out = run_sharded(disagg_coord, Arrivals::Inject(workload(30, 6.0)), 2).unwrap();
        assert_eq!(out.domains, 2, "must actually shard");
        assert!(out.all_serviced());
        assert_eq!(out.serviced, serial.serviced, "completion order");
        assert_eq!(out.failed, serial.failed);
        assert_eq!(out.clock, serial.clock, "final clock");
        assert_eq!(out.stats.events, serial.stats.events);
        assert_eq!(out.stats.transfers, serial.stats.transfers);
        assert_eq!(out.stats.transfer_bytes, serial.stats.transfer_bytes);
        assert_eq!(out.stats.transfer_seconds, serial.stats.transfer_seconds);
        assert_eq!(out.decisions, serial.router.decisions);
        let serial_energy: f64 = serial.clients.iter().map(|c| c.stats().energy_joules).sum();
        assert_eq!(out.energy_joules, serial_energy);
        // per-request samples, bit for bit
        assert_eq!(out.records.len(), serial.records.len());
        for (a, b) in out.records.iter().zip(&serial.records) {
            assert_eq!(format!("{a:?}"), format!("{b:?}"));
        }
    }

    #[test]
    fn shards_one_reports_serial_oracle() {
        let out = run_sharded(disagg_coord, Arrivals::Inject(workload(10, 4.0)), 1).unwrap();
        assert_eq!(out.shards, 1);
        assert_eq!(out.domains, 1, "--shards 1 is the serial oracle");
        assert!(out.all_serviced());
    }
}
