//! The global event queue (paper §III-B): a deterministic min-heap over
//! (time, sequence). The paper's two primary event types plus transfer
//! completion from the global communication simulator.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::SimTime;
use crate::workload::request::ReqId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// a request enters the system (dst None → route it) or arrives at a
    /// client after routing/transfer
    RequestPush { req: ReqId, dst: Option<usize> },
    /// a client's in-flight engine step completed
    EngineStep { client: usize },
    /// a request's absolute deadline elapsed — if it is still live at
    /// this instant it times out and fails (docs/robustness.md)
    Deadline { req: ReqId },
    /// a fault-plan crash window opens; payload is the crash index in
    /// the compiled [`crate::fault::FaultPlan`]
    Fault { fault: usize },
}

/// Deterministic priority queue: ties broken by insertion sequence.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(SimTime, u64, EventSlot)>>,
    seq: u64,
    pub pushed: u64,
}

/// Event wrapped for total ordering inside the heap key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct EventSlot {
    tag: u8,
    a: u64,
    b: u64,
}

fn encode(e: Event) -> EventSlot {
    match e {
        Event::RequestPush { req, dst } => EventSlot {
            tag: 0,
            a: req,
            b: dst.map(|d| d as u64 + 1).unwrap_or(0),
        },
        Event::EngineStep { client } => EventSlot {
            tag: 1,
            a: client as u64,
            b: 0,
        },
        Event::Deadline { req } => EventSlot { tag: 2, a: req, b: 0 },
        Event::Fault { fault } => EventSlot {
            tag: 3,
            a: fault as u64,
            b: 0,
        },
    }
}

fn decode(s: EventSlot) -> Event {
    match s.tag {
        0 => Event::RequestPush {
            req: s.a,
            dst: if s.b == 0 { None } else { Some(s.b as usize - 1) },
        },
        1 => Event::EngineStep {
            client: s.a as usize,
        },
        2 => Event::Deadline { req: s.a },
        3 => Event::Fault {
            fault: s.a as usize,
        },
        _ => unreachable!(),
    }
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, t: SimTime, e: Event) {
        self.heap.push(Reverse((t, self.seq, encode(e))));
        self.seq += 1;
        self.pushed += 1;
    }

    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.heap.pop().map(|Reverse((t, _, s))| (t, decode(s)))
    }

    /// Fused peek+pop: pop the head only if it fires strictly before
    /// `bound`. One heap access per drained event instead of a
    /// peek-then-pop pair — both the serial loop (where `bound` is the
    /// pending arrival's timestamp, so an arrival at exactly the head's
    /// time still wins the tie) and the sharded window drain (where
    /// `bound` is the window end, exclusive) sit on this.
    pub fn pop_before(&mut self, bound: SimTime) -> Option<(SimTime, Event)> {
        match self.heap.peek() {
            Some(Reverse((t, _, _))) if *t < bound => self.pop(),
            _ => None,
        }
    }

    /// Timestamp of the next event without popping it — the coordinator
    /// arbitrates between the queue head and the lazy arrival source's
    /// pending request.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs(2.0), Event::EngineStep { client: 2 });
        q.push(SimTime::from_secs(1.0), Event::RequestPush { req: 7, dst: None });
        q.push(SimTime::from_secs(3.0), Event::EngineStep { client: 3 });
        let (t1, e1) = q.pop().unwrap();
        assert_eq!(t1, SimTime::from_secs(1.0));
        assert_eq!(e1, Event::RequestPush { req: 7, dst: None });
        assert_eq!(q.pop().unwrap().0, SimTime::from_secs(2.0));
        assert_eq!(q.pop().unwrap().0, SimTime::from_secs(3.0));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        let t = SimTime::from_secs(1.0);
        q.push(t, Event::EngineStep { client: 10 });
        q.push(t, Event::EngineStep { client: 20 });
        q.push(t, Event::EngineStep { client: 30 });
        let order: Vec<Event> = (0..3).map(|_| q.pop().unwrap().1).collect();
        assert_eq!(
            order,
            vec![
                Event::EngineStep { client: 10 },
                Event::EngineStep { client: 20 },
                Event::EngineStep { client: 30 }
            ]
        );
    }

    #[test]
    fn peek_reports_head_time_without_popping() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(SimTime::from_secs(2.0), Event::EngineStep { client: 1 });
        q.push(SimTime::from_secs(1.0), Event::EngineStep { client: 2 });
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(1.0)));
        assert_eq!(q.len(), 2, "peek must not consume");
        let _ = q.pop();
        assert_eq!(q.peek_time(), Some(SimTime::from_secs(2.0)));
    }

    #[test]
    fn pop_before_matches_separate_peek_then_pop() {
        // tie-order pin: a head at exactly `bound` must NOT pop — the
        // caller's same-time candidate (a streaming arrival, or the
        // next window's events) wins the tie, exactly as the old
        // peek-then-pop arbitration (`ta <= te` → arrival first) did.
        let t = SimTime::from_secs(1.0);
        let mut fused = EventQueue::new();
        let mut classic = EventQueue::new();
        for q in [&mut fused, &mut classic] {
            q.push(t, Event::EngineStep { client: 1 });
            q.push(t, Event::EngineStep { client: 2 });
            q.push(SimTime::from_secs(2.0), Event::EngineStep { client: 3 });
        }
        for bound in [SimTime::from_secs(0.5), t, SimTime::from_secs(1.5), SimTime::from_secs(9.0)]
        {
            loop {
                let expected = match classic.peek_time() {
                    Some(te) if te < bound => classic.pop(),
                    _ => None,
                };
                let got = fused.pop_before(bound);
                assert_eq!(got, expected, "bound {bound}");
                if got.is_none() {
                    break;
                }
            }
        }
        assert!(fused.is_empty(), "every event drained by the last bound");
    }

    #[test]
    fn deadline_and_fault_events_roundtrip() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs(1.0), Event::Deadline { req: 42 });
        q.push(SimTime::from_secs(2.0), Event::Fault { fault: 3 });
        assert_eq!(q.pop().unwrap().1, Event::Deadline { req: 42 });
        assert_eq!(q.pop().unwrap().1, Event::Fault { fault: 3 });
    }

    #[test]
    fn request_push_dst_roundtrip() {
        let mut q = EventQueue::new();
        q.push(SimTime::ZERO, Event::RequestPush { req: 5, dst: Some(0) });
        q.push(SimTime::ZERO, Event::RequestPush { req: 6, dst: None });
        assert_eq!(
            q.pop().unwrap().1,
            Event::RequestPush { req: 5, dst: Some(0) }
        );
        assert_eq!(q.pop().unwrap().1, Event::RequestPush { req: 6, dst: None });
    }
}
