//! The Global Coordinator (paper §III-B, Algorithm 1): owns the event
//! queue and the global clock, routes request stages to capable clients,
//! and drives the global communication simulator for inter-client
//! transfers (KV hand-offs in disaggregated serving, retrieved-context
//! movement, etc.).

pub mod event;
pub mod router;
pub mod shard;

use crate::client::{Client, StepOutcome};
use crate::fault::FaultPlan;
use crate::memory::hierarchy::Hierarchy;
use crate::metrics::MetricsSink;
use crate::model::policy::{ModelPolicy, RouteDecision};
use crate::network::{Granularity, Network};
use crate::scheduler::RequestPool;
use crate::sim::SimTime;
use crate::workload::request::{CompletionRecord, ReqId, Request, Stage};
use crate::workload::stream::StreamingMix;
use crate::workload::trace::WorkloadMix;

pub use event::{Event, EventQueue};
pub use router::{Candidate, LoadMetric, RoutePolicy, Router};

/// Coordinator-level counters (§III-F.2 global metrics).
#[derive(Debug, Clone, Default)]
pub struct CoordStats {
    pub events: u64,
    pub transfers: u64,
    pub transfer_bytes: f64,
    pub transfer_seconds: f64,
    pub recomputes: u64,
    pub failed: u64,
    /// requests that completed successfully — the counter twin of the
    /// `serviced` ID vec, maintained in every mode so streaming-metrics
    /// runs (which never grow the vec) can still prove conservation
    pub serviced: u64,
    /// requests that entered the system (eagerly injected or emitted by
    /// the streaming arrival source) — the run-total denominator now
    /// that the pool only holds live requests under retirement
    pub injected: u64,
    /// retried hand-offs / placements (docs/robustness.md): transient
    /// stage failures, link outages, crash orphans re-entering routing
    pub retries: u64,
    /// requests failed by an elapsed deadline (⊆ `failed`)
    pub timeouts: u64,
    /// requests shed for lack of a healthy candidate (⊆ `failed`)
    pub shed: u64,
    /// in-flight requests orphaned by a client crash or a hand-off to a
    /// crashed destination (each then retried or failed)
    pub orphaned: u64,
    /// largest event-queue length observed after any event
    pub peak_queue: usize,
    /// requests currently arrived but not yet finished/failed
    pub inflight: usize,
    /// high-water mark of `inflight` (the bench harness's "peak pool")
    pub peak_inflight: usize,
}

/// Where the coordinator's requests come from.
///
/// The eager path materializes the whole trace upfront
/// ([`Coordinator::inject`]): every request sits in the pool and every
/// arrival event sits in the queue at t=0 — O(total requests) memory
/// before the first event fires. [`ArrivalSource::Streaming`] instead
/// holds a lazy generator ([`StreamingMix`]) that keeps **at most one
/// pending arrival per workload-class stream**; the coordinator pulls
/// the next request at its arrival instant, so queue and pool stay
/// O(in-flight). The two paths are bit-identical
/// (`rust/tests/retirement_equivalence.rs`): the lazy source draws the
/// same PCG streams in the same order, and arrivals win ties against
/// same-time queued events exactly as the eager path's upfront pushes
/// (smallest sequence numbers) do.
pub enum ArrivalSource {
    /// all requests were injected eagerly (or none at all)
    Materialized,
    /// lazy deterministic generator; one pending request per class
    Streaming(StreamingMix),
}

impl ArrivalSource {
    /// Arrival time of the next pending request, if any.
    fn peek(&self) -> Option<SimTime> {
        match self {
            ArrivalSource::Materialized => None,
            ArrivalSource::Streaming(s) => s.peek_arrival(),
        }
    }

    /// No more arrivals will ever be emitted.
    pub fn drained(&self) -> bool {
        match self {
            ArrivalSource::Materialized => true,
            ArrivalSource::Streaming(s) => s.remaining() == 0,
        }
    }
}

/// How the router obtains candidate loads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LoadMode {
    /// O(1) incrementally maintained counters ([`Client::load`]) — the
    /// default and the only mode the hot path should use
    #[default]
    Incremental,
    /// recompute every candidate's load from the full request pool on
    /// every routing decision (O(total requests) per candidate, via
    /// [`Client::full_scan_load`]) — the pre-refactor behavior, kept
    /// verbatim as the `hermes bench` baseline and for differential
    /// testing
    FullScan,
}

pub struct Coordinator {
    pub clients: Vec<Box<dyn Client>>,
    pub router: Router,
    pub network: Network,
    pub pool: RequestPool,
    pub queue: EventQueue,
    pub clock: SimTime,
    /// where arrivals come from: eager injection (default) or the lazy
    /// streaming generator ([`Coordinator::stream`])
    pub source: ArrivalSource,
    /// retire finished/failed requests: fold each into a
    /// [`CompletionRecord`] and free its pool slot, so resident pool
    /// memory tracks peak in-flight instead of total injected. Off by
    /// default — the retained pool keeps post-run inspection
    /// (`coord.pool[id]`, trace export) working.
    pub retire: bool,
    /// one compact record per finished/failed request, in completion
    /// order — what `RunMetrics::collect` consumes (identical with
    /// retirement on or off). Stays empty when a streaming metrics
    /// `sink` is installed: records fold into the sink at retirement
    /// time instead, so metrics memory is O(1) in request count.
    pub records: Vec<CompletionRecord>,
    /// streaming metrics accumulator (`--metrics sketch`): when Some,
    /// `complete`/`fail` fold each completion record here instead of
    /// growing `records`/`serviced`/`failed`, which collapse to the
    /// `CoordStats` counters. None (default) keeps the exact
    /// retained-records oracle path bit-identical to every prior PR.
    pub sink: Option<MetricsSink>,
    /// completed requests, in completion order
    pub serviced: Vec<ReqId>,
    /// requests that can never be placed (exceed every client's memory)
    pub failed: Vec<ReqId>,
    /// KV hand-off granularity for disaggregated transfers
    pub granularity: Granularity,
    /// granularity override for explicit [`Stage::KvMigration`] hops
    /// (None = use `granularity`): `Full` models a blocking hand-off,
    /// `Layerwise` the overlapped migration (docs/disaggregation.md)
    pub migration_granularity: Option<Granularity>,
    /// tiered staging pool on the migration target (HBM → DRAM →
    /// NVMe/CXL): its Eq. 1 expected latency delays the decode-side
    /// arrival of every explicit migration. None = the KV streams
    /// straight into the decode client's HBM at zero extra cost
    pub migration_pool: Option<Hierarchy>,
    /// restrict prefill→decode hand-offs to the same placement group
    /// ("Local" disaggregation; default false = "Global", Splitwise-like)
    pub local_disagg: bool,
    /// incremental (default) vs full-scan candidate loads
    pub load_mode: LoadMode,
    /// dynamic model-selection policy behind `Stage::ModelRoute`
    /// (None = identity: routed pipelines keep their initial model)
    pub model_policy: Option<ModelPolicy>,
    /// seed for the policy's deterministic per-request decision streams
    pub model_seed: u64,
    /// compiled fault schedule (docs/robustness.md). None — the default
    /// and the `--faults off` override — keeps every fault/retry branch
    /// byte-for-byte on the pre-fault code path
    pub faults: Option<FaultPlan>,
    /// crash windows armed as `Event::Fault` entries (once per run, at
    /// the first `step_bounded` call so arrivals keep smaller sequence
    /// numbers than same-time crash events)
    fault_events_armed: bool,
    pub stats: CoordStats,
    /// hard stop against runaway simulations
    pub max_events: u64,
    /// reusable candidate buffer for `route` (cleared per decision —
    /// routing runs on every stage transition, so no allocations)
    route_buf: Vec<Candidate>,
    /// sharded-execution context (None in the serial oracle): this
    /// coordinator is one conservative-window domain of a
    /// [`shard::run_sharded`] run — cross-domain hops are deferred into
    /// its egress buffer instead of being priced inline
    pub(crate) shard: Option<Box<shard::ShardCtx>>,
}

impl Coordinator {
    pub fn new(clients: Vec<Box<dyn Client>>, router: Router, network: Network) -> Coordinator {
        assert_eq!(
            network.locations.len(),
            clients.len(),
            "network topology must cover every client"
        );
        Coordinator {
            clients,
            router,
            network,
            pool: RequestPool::new(),
            queue: EventQueue::new(),
            clock: SimTime::ZERO,
            source: ArrivalSource::Materialized,
            retire: false,
            records: Vec::new(),
            sink: None,
            serviced: Vec::new(),
            failed: Vec::new(),
            granularity: Granularity::Layerwise { layers: 80 },
            migration_granularity: None,
            migration_pool: None,
            local_disagg: false,
            load_mode: LoadMode::Incremental,
            model_policy: None,
            model_seed: 0,
            faults: None,
            fault_events_armed: false,
            stats: CoordStats::default(),
            max_events: 500_000_000,
            route_buf: Vec::new(),
            shard: None,
        }
    }

    /// Inject a workload eagerly (requests enter at their arrival
    /// timestamps; the pool and queue hold the whole trace upfront).
    /// Duplicate request ids are rejected by the pool — identically on
    /// both backends.
    pub fn inject(&mut self, requests: Vec<Request>) {
        for r in requests {
            self.queue.push(
                r.arrival,
                Event::RequestPush {
                    req: r.id,
                    dst: None,
                },
            );
            self.stats.injected += 1;
            self.pool.insert(r.id, r);
        }
        self.scale_event_budget();
    }

    /// Attach a lazy arrival source instead of eager injection: requests
    /// are generated at their arrival instants from the same PCG streams
    /// `mix.generate()` would consume, so the run is bit-identical to
    /// the materialized path while the event queue and pool stay
    /// O(in-flight). Combine with [`Coordinator::retire`] for whole-run
    /// O(peak in-flight) memory. Do not mix with [`Coordinator::inject`]
    /// in the same run unless the id ranges are disjoint.
    pub fn stream(&mut self, mix: &WorkloadMix) {
        let s = StreamingMix::new(mix);
        let remaining = s.remaining() as u64;
        self.source = ArrivalSource::Streaming(s);
        self.max_events = self.max_events.max(remaining.saturating_mul(200));
    }

    /// Keep the runaway-simulation tripwire proportional to the known
    /// request total: the fixed 500M default would fire spuriously at
    /// the 100M-request tier (~6 events/request), while 200×requests
    /// still catches a simulation that stops making progress.
    fn scale_event_budget(&mut self) {
        self.max_events = self
            .max_events
            .max(self.stats.injected.saturating_mul(200));
    }

    /// Algorithm 1: drain the arrival source and the event queue.
    pub fn run(&mut self) {
        while self.step_event() {}
    }

    /// Process a single event — the next pending arrival from the lazy
    /// source or the head of the event queue, whichever is earlier —
    /// and return `false` once both are drained. Exposed so tests can
    /// interleave per-event checks (the load-invariant differential
    /// test) with the event loop.
    ///
    /// Arrivals win ties against same-time queued events: in the eager
    /// path every arrival event is pushed before the run starts, so it
    /// carries a smaller sequence number than any event generated
    /// during the run — the streaming path must preserve that order to
    /// stay bit-identical. Ties among pending arrivals are broken by
    /// request id inside the source, matching the eager path's
    /// `(arrival, id)` injection order.
    pub fn step_event(&mut self) -> bool {
        self.step_bounded(None)
    }

    /// [`Coordinator::step_event`] with an optional exclusive time
    /// bound: process the next event/arrival only if it fires strictly
    /// before `limit`, else leave it pending and return `false`. The
    /// sharded loop ([`shard::run_sharded`]) drains each conservative
    /// window with `limit = window end`; the serial loop passes `None`.
    ///
    /// The arbitration is a single fused [`EventQueue::pop_before`]
    /// against the pending arrival's timestamp (or `limit`, whichever
    /// is smaller): the queue head pops only when it fires *strictly*
    /// before the arrival, which is exactly the old peek-then-pop
    /// `ta <= te` tie rule.
    pub fn step_bounded(&mut self, limit: Option<SimTime>) -> bool {
        if !self.fault_events_armed {
            self.arm_fault_events();
        }
        let arrival = self.source.peek();
        let bound = match (arrival, limit) {
            (Some(ta), Some(l)) => Some(ta.min(l)),
            (Some(ta), None) => Some(ta),
            (None, Some(l)) => Some(l),
            (None, None) => None,
        };
        let popped = match bound {
            Some(b) => self.queue.pop_before(b),
            None => self.queue.pop(),
        };
        let (t, e) = match popped {
            Some(te) => te,
            None => match arrival {
                Some(ta) if limit.is_none_or(|l| ta < l) => {
                    let ArrivalSource::Streaming(s) = &mut self.source else {
                        unreachable!("a pending arrival implies a streaming source")
                    };
                    let r = s.next().expect("peeked arrival must exist");
                    let id = r.id;
                    self.stats.injected += 1;
                    self.pool.insert(id, r);
                    (ta, Event::RequestPush { req: id, dst: None })
                }
                _ => return false,
            },
        };
        // deadline copies are armed at every stage accept; all copies of
        // one request share its absolute deadline, and only the first
        // live one may fire. A stale copy — the request completed,
        // failed, or left this shard domain — is consumed for free
        // BEFORE the clock/event-count commit, so stale copies never
        // drag the clock or perturb any counter (identically in the
        // serial and sharded loops, which is what keeps them bit-exact)
        if let Event::Deadline { req } = e {
            let live = self
                .pool
                .get(&req)
                .is_some_and(|r| r.finished.is_none() && !r.failed);
            if !live {
                return true;
            }
        }
        debug_assert!(t >= self.clock, "time went backwards");
        self.clock = t;
        self.stats.events += 1;
        assert!(
            self.stats.events < self.max_events,
            "event budget exceeded — runaway simulation?"
        );
        match e {
            Event::RequestPush { req, dst } => self.on_push(req, dst),
            Event::EngineStep { client } => self.on_step(client),
            Event::Deadline { req } => self.on_deadline(req),
            Event::Fault { fault } => self.on_fault(fault),
        }
        self.stats.peak_queue = self.stats.peak_queue.max(self.queue.len());
        // drift invariant: the incremental per-client loads must equal a
        // fresh full-pool recomputation after every event (debug builds)
        #[cfg(debug_assertions)]
        self.assert_load_invariant();
        true
    }

    /// Assert that every client's incremental [`Client::load`] matches
    /// a fresh per-client [`Client::recompute_load`] AND the
    /// pre-refactor full-pool [`Client::full_scan_load`]. All load
    /// deltas are integer-valued, so the comparisons are exact (no
    /// epsilon). Also validates the pool's resident index against every
    /// request's `client` field (O(pool)), so `recompute_load`'s
    /// membership source is itself checked against ground truth.
    pub fn assert_load_invariant(&self) {
        self.pool.validate_residency();
        for c in &self.clients {
            let incremental = c.load();
            let recomputed = c.recompute_load(&self.pool);
            assert_eq!(
                incremental,
                recomputed,
                "client {} ({}) load drifted at {}: incremental vs recomputed",
                c.id(),
                c.kind_name(),
                self.clock
            );
            let scanned = c.full_scan_load(&self.pool);
            assert_eq!(
                incremental,
                scanned,
                "client {} ({}) load drifted at {}: incremental vs full scan",
                c.id(),
                c.kind_name(),
                self.clock
            );
            // per-(client, model) counters: the router's candidate loads
            // must match a fresh per-model recomputation and the
            // per-model whole-pool scan (multi-model clients)
            for &m in c.served_models() {
                let inc = c.load_for_model(m);
                assert_eq!(
                    inc,
                    c.recompute_load_for_model(m, &self.pool),
                    "client {} model {m} load drifted at {}: incremental vs recomputed",
                    c.id(),
                    self.clock
                );
                assert_eq!(
                    inc,
                    c.full_scan_load_for_model(m, &self.pool),
                    "client {} model {m} load drifted at {}: incremental vs full scan",
                    c.id(),
                    self.clock
                );
            }
        }
    }

    /// Bytes that move when `req` leaves `from` for its next stage.
    /// Evaluated on the request's state *while still in* `from` — the
    /// pre-advance state — so pricing cannot depend on the order in
    /// which `advance_stage()` side effects (RAG context folding) are
    /// applied.
    fn transfer_bytes(req: &Request, from: Option<Stage>) -> f64 {
        // O(1) registry index — the old per-transfer name lookup+clone
        // is gone with the interned ModelId
        let kv_per_tok = req.model.spec().kv_bytes_per_token();
        match from {
            // disaggregated hand-off: the prefix KV moves
            Some(Stage::Prefill) => (req.past_tokens + req.prompt_tokens) as f64 * kv_per_tok,
            // explicit cluster-level migration: the full prefix KV
            // moves, wherever the stage sits in the pipeline
            Some(Stage::KvMigration) => {
                (req.past_tokens + req.prompt_tokens) as f64 * kv_per_tok
            }
            // retrieved past-context KV moves to the prefill client
            Some(Stage::KvRetrieval(_)) => req.past_tokens as f64 * kv_per_tok,
            // the prompt plus the retrieved documents move as text
            // (~4 B/token); pre-advance, `prompt_tokens` does not yet
            // include the retrieved context, so add it from the stage
            // parameters rather than relying on the mutation
            Some(Stage::Rag(p)) => (req.prompt_tokens + p.context_tokens()) as f64 * 4.0,
            // fresh arrivals / pre-post hops move prompt text
            _ => req.prompt_tokens as f64 * 4.0,
        }
    }

    fn on_push(&mut self, req: ReqId, dst: Option<usize>) {
        match dst {
            Some(c) => {
                // stale-delivery guard: the request may have timed out
                // (and retired) while this hand-off was in the air.
                // Unreachable without deadlines/faults — transfers
                // cannot outlive a live request otherwise
                let Some(r) = self.pool.get(&req) else { return };
                if r.failed {
                    return;
                }
                if let Some(plan) = &self.faults {
                    // destination crashed mid-transfer: the request is
                    // orphaned — re-route it with backoff
                    if !plan.health_at(self.clock, c) {
                        self.stats.orphaned += 1;
                        self.retry_or_fail(req);
                        return;
                    }
                }
                self.pool.get_mut(&req).unwrap().stage_accept = self.clock;
                self.clients[c].accept(self.clock, req, &mut self.pool);
                self.activate(c);
                self.shard_note_load(c);
                self.arm_deadline(req);
            }
            None => {
                // stale retry guard (a stale None-push implies a prior
                // retry, which implies faults — the branch is never
                // taken in fault-free runs)
                if self.faults.is_some()
                    && !self
                        .pool
                        .get(&req)
                        .is_some_and(|r| r.finished.is_none() && !r.failed)
                {
                    return;
                }
                // fresh arrival or retry re-entry: route (ingress pays
                // no inter-client link). A retried request (attempt > 0
                // — `retry_or_fail` bumps it before pushing) stayed
                // in-flight across its backoff, so only fresh arrivals
                // enter the in-flight count here
                if !(self.faults.is_some() && self.pool[&req].attempt > 0) {
                    self.stats.inflight += 1;
                    self.stats.peak_inflight = self.stats.peak_inflight.max(self.stats.inflight);
                }
                // dynamic model selection happens before any client sees
                // the request (a leading ModelRoute stage, if present)
                if self.resolve_model_route(req) {
                    return;
                }
                if let Some(c) = self.route(req, None, 0.0, self.granularity) {
                    self.pool.get_mut(&req).unwrap().stage_accept = self.clock;
                    self.clients[c].accept(self.clock, req, &mut self.pool);
                    self.activate(c);
                    self.shard_note_load(c);
                    self.arm_deadline(req);
                } else {
                    self.no_candidate(req);
                }
            }
        }
    }

    fn on_step(&mut self, client: usize) {
        let outcome: StepOutcome = self.clients[client].finish_step(self.clock, &mut self.pool);
        self.stats.recomputes += outcome.recomputed.len() as u64;
        for id in outcome.stage_done {
            self.advance(id, client);
        }
        // the client may have more queued work
        self.activate(client);
        self.shard_note_load(client);
    }

    /// Request finished its stage on `src`: advance the pipeline, route
    /// the next stage, simulate the transfer.
    fn advance(&mut self, id: ReqId, src: usize) {
        let (done, bytes) = {
            let r = self.pool.get_mut(&id).expect("advance: unknown request");
            // the client released pool residency in its finish_step —
            // stage completion and ownership release are one event
            debug_assert!(r.client.is_none(), "advance: request still resident");
            let from = r.stage();
            // price the outbound transfer on the pre-advance state:
            // `advance_stage()` folds retrieved RAG context into
            // `prompt_tokens`, and pricing must not see that mutation
            let bytes = Self::transfer_bytes(r, Some(from));
            r.records.push(crate::workload::request::StageRecord {
                stage_idx: r.stage_idx,
                client: src,
                start: r.stage_accept,
                end: self.clock,
            });
            let more = r.advance_stage();
            (!more, bytes)
        };
        if done {
            self.complete(id);
            return;
        }
        // consume any ModelRoute stage reached here: the cascade's
        // escalation point (finish with the small model's answer, or
        // re-run prefill+decode on the large one)
        if self.resolve_model_route(id) {
            return;
        }
        // consume any KvMigration stage reached here: the explicit
        // prefill→decode hand-off of cluster disaggregation. Re-prices
        // the hop as the full prefix KV and may switch its granularity
        // and add a staging-pool delay.
        let Some((bytes, gran, staging)) = self.resolve_kv_migration(id, src, bytes) else {
            return;
        };
        // fault gate: transient hand-off failures and rack-egress link
        // faults are resolved here — before pricing and before the
        // sharded defer — so retries ride the hop as extra staging and
        // the serial/sharded paths price the identical (bytes, staging)
        let Some((bytes, staging)) = self.fault_gate(id, src, bytes, staging) else {
            return;
        };
        // sharded execution: a hop whose candidates live in another
        // domain — or one that would serialize on the shared DCN spine —
        // is deferred into the window-barrier egress buffer instead of
        // being routed/priced inline (coordinator/shard.rs)
        if self.shard.is_some() && self.shard_defer(id, src, bytes, gran, staging) {
            return;
        }
        match self.route(id, Some(src), bytes, gran) {
            Some(dst) => self.dispatch(id, src, dst, bytes, gran, staging),
            None => self.no_candidate(id),
        }
    }

    /// Resolve transient hand-off failures and link faults for the hop
    /// `id` is about to take out of `src` (docs/robustness.md). Returns
    /// the adjusted `(bytes, staging_seconds)` to dispatch, or `None`
    /// when the hop was consumed here (no healthy candidate, or retries
    /// exhausted → the request was shed/retried/failed).
    ///
    /// Retries never re-enter the event loop on this path: each failed
    /// try adds its backoff to the hop's staging delay, so the decision
    /// is made once, at the same pre-pricing point the sharded replay
    /// uses — which is what keeps fault schedules bit-identical across
    /// `--shards`. Every draw is a pure function of
    /// `(fault_seed, request, stage, attempt)` and of simulated time.
    fn fault_gate(
        &mut self,
        id: ReqId,
        src: usize,
        bytes: f64,
        staging: f64,
    ) -> Option<(f64, f64)> {
        if self.faults.is_none() {
            return Some((bytes, staging));
        }
        if !self.any_healthy_candidate(id) {
            // every candidate for the next stage is dark: shed or
            // backoff-retry instead of burning hand-off attempts
            self.no_candidate(id);
            return None;
        }
        let base_attempt = self.pool[&id].attempt;
        let (attempt, extra, exhausted, degrade) = {
            let plan = self.faults.as_ref().unwrap();
            let rack = self.network.rack_of(src);
            let stage_idx = self.pool[&id].stage_idx;
            let mut attempt = base_attempt;
            let mut extra = 0.0;
            let mut exhausted = false;
            loop {
                let t_send = self.clock + SimTime::from_secs(extra);
                if !plan.link_outage_at(t_send, rack) && !plan.stage_fails(id, stage_idx, attempt)
                {
                    break;
                }
                if attempt + 1 >= plan.retry.max_attempts {
                    exhausted = true;
                    break;
                }
                attempt += 1;
                extra += plan.backoff_delay(id, attempt);
            }
            let degrade = plan.link_degrade_at(self.clock + SimTime::from_secs(extra), rack);
            (attempt, extra, exhausted, degrade)
        };
        self.stats.retries += (attempt - base_attempt) as u64;
        self.pool.get_mut(&id).unwrap().attempt = attempt;
        if exhausted {
            self.fail(id);
            return None;
        }
        // a degraded (browned-out) egress link inflates the effective
        // bytes; factor ≥ 1 keeps them positive for the DCN pricer
        Some((bytes * degrade, staging + extra))
    }

    /// Any up client that can serve `id`'s current stage? (The
    /// local-disaggregation group filter is deliberately not applied —
    /// a group-constrained miss still reaches [`Coordinator::route`]
    /// and fails through [`Coordinator::no_candidate`] there.)
    fn any_healthy_candidate(&self, id: ReqId) -> bool {
        let Some(plan) = &self.faults else { return true };
        let r = &self.pool[&id];
        let stage = r.stage();
        self.clients
            .iter()
            .any(|c| c.can_serve(&stage, r.model) && plan.health_at(self.clock, c.id()))
    }

    /// No candidate could take the request's next stage. Without faults
    /// this is today's terminal failure; under faults the request is
    /// shed (when the plan says so) or backoff-retried — outages are
    /// usually transient.
    fn no_candidate(&mut self, id: ReqId) {
        match &self.faults {
            None => self.fail(id),
            Some(plan) if plan.shed => {
                self.stats.shed += 1;
                self.pool.get_mut(&id).unwrap().shed = true;
                self.fail(id);
            }
            Some(_) => self.retry_or_fail(id),
        }
    }

    /// Re-enter routing after a backoff, or fail terminally once the
    /// attempt budget is spent. The request stays in the in-flight
    /// count across its backoff (the re-push recognizes `attempt > 0`
    /// and does not re-increment).
    fn retry_or_fail(&mut self, id: ReqId) {
        let (max, delay) = match &self.faults {
            Some(p) => (
                p.retry.max_attempts,
                p.backoff_delay(id, self.pool[&id].attempt + 1),
            ),
            None => {
                self.fail(id);
                return;
            }
        };
        let r = self.pool.get_mut(&id).unwrap();
        if r.attempt + 1 >= max {
            self.fail(id);
            return;
        }
        r.attempt += 1;
        self.stats.retries += 1;
        self.queue.push(
            self.clock + SimTime::from_secs(delay),
            Event::RequestPush { req: id, dst: None },
        );
    }

    /// Arm the request's absolute deadline (if its workload class set
    /// one). Called at every stage accept; all copies share the same
    /// fire time and only the first live one acts — the rest are
    /// consumed for free by `step_bounded`'s staleness pre-check.
    fn arm_deadline(&mut self, id: ReqId) {
        let Some(d) = self.pool[&id].deadline else { return };
        self.queue.push(d.max(self.clock), Event::Deadline { req: id });
    }

    /// A live request's deadline elapsed: it times out and fails
    /// (hard — timeouts are not retried; the SLO is already blown).
    fn on_deadline(&mut self, id: ReqId) {
        self.stats.timeouts += 1;
        self.pool.get_mut(&id).unwrap().timed_out = true;
        self.fail(id);
    }

    /// A crash window opened: drain the client. Every resident request
    /// is evicted — releasing scheduler slots, KV reservations and
    /// load-account counters through the same invariant-checked path as
    /// a normal stage completion — and re-enters routing with backoff
    /// (or fails/sheds). Recovery needs no event: health is a pure
    /// window query, and a drained client holds no queued work.
    fn on_fault(&mut self, fault: usize) {
        let client = self
            .faults
            .as_ref()
            .expect("Event::Fault without a fault plan")
            .crash_client(fault);
        let victims: Vec<ReqId> = self.pool.iter_client(client).map(|r| r.id).collect();
        for id in victims {
            self.clients[client].evict(id, &mut self.pool);
            self.stats.orphaned += 1;
            self.retry_or_fail(id);
        }
        self.shard_note_load(client);
    }

    /// Push `Event::Fault` entries for the plan's crash windows. Runs
    /// once, lazily from the first `step_bounded` call: after eager
    /// injection (so same-time arrivals keep smaller sequence numbers,
    /// in both the eager and streaming arbitration) and after a sharded
    /// domain's context is installed (each domain arms only the crashes
    /// of clients it owns; the union across domains is exactly the
    /// serial schedule).
    fn arm_fault_events(&mut self) {
        self.fault_events_armed = true;
        let Some(plan) = &self.faults else { return };
        let crashes: Vec<(SimTime, usize)> = plan
            .crash_events()
            .filter(|&(_, i)| match &self.shard {
                Some(ctx) => ctx.owns_client[plan.crash_client(i)],
                None => true,
            })
            .collect();
        for (t, i) in crashes {
            self.queue.push(t, Event::Fault { fault: i });
        }
    }

    /// Price the routed hop on the network and enqueue the arrival at
    /// the destination — the tail of [`Coordinator::advance`], shared
    /// with the sharded loop's domain-local dispatch path.
    fn dispatch(
        &mut self,
        id: ReqId,
        src: usize,
        dst: usize,
        bytes: f64,
        gran: Granularity,
        staging: f64,
    ) {
        let arrive = self.network.transfer(self.clock, src, dst, bytes, gran)
            + SimTime::from_secs(staging);
        self.stats.transfers += 1;
        self.stats.transfer_bytes += bytes;
        self.stats.transfer_seconds += (arrive - self.clock).as_secs();
        if let Some(ctx) = &mut self.shard {
            ctx.transfer_log
                .push((self.clock, bytes, (arrive - self.clock).as_secs()));
        }
        self.queue
            .push(arrive, Event::RequestPush { req: id, dst: Some(dst) });
    }

    /// The request completed its final stage (or a model policy ended
    /// its pipeline early): stamp it, fold it into a
    /// [`CompletionRecord`], and — under retirement — free its pool
    /// slot for reuse.
    fn complete(&mut self, id: ReqId) {
        let r = self.pool.get_mut(&id).unwrap();
        r.finished = Some(self.clock);
        let rec = CompletionRecord::of(r, false);
        self.stats.serviced += 1;
        self.stats.inflight -= 1;
        if let Some(sink) = &mut self.sink {
            // streaming metrics: fold at retirement time, retain nothing
            sink.fold(&rec);
        } else {
            self.records.push(rec);
            self.serviced.push(id);
            if let Some(ctx) = &mut self.shard {
                // merge key for cross-domain record interleaving: completion
                // instant (records are pushed in clock order within a domain)
                ctx.record_keys.push(self.clock);
            }
        }
        if self.retire {
            self.pool.remove(id);
        }
    }

    /// Consume `ModelRoute` stages at the request's current position.
    /// Resolution is inline and free: the stage never routes to a
    /// client, adds no events and records no stage span. With no
    /// configured policy the stage is the identity (the request keeps
    /// its initial model), so routed pipelines degrade gracefully to
    /// their plain equivalents. A later route that re-assigns a
    /// *different* model is an escalation: prefill/decode progress is
    /// reset and the following stages re-run on the new model. TTFT
    /// keeps the first pass's first-token timestamp (the user already
    /// saw the small model's answer begin — `first_response_time`);
    /// TPOT measures only the pass that produced the final answer (the
    /// per-pass token timestamps reset); and the superseded pass's
    /// tokens move to `prior_decoded`, so throughput/energy still
    /// count the work performed. Returns true when the request
    /// finished here.
    fn resolve_model_route(&mut self, id: ReqId) -> bool {
        loop {
            let policy = &self.model_policy;
            let r = self.pool.get_mut(&id).unwrap();
            if r.stage() != Stage::ModelRoute {
                return false;
            }
            let ordinal = r.model_route_ordinal();
            let decision = match policy {
                Some(p) => p.decide(r, ordinal, self.model_seed),
                None => RouteDecision::Assign(r.model),
            };
            match decision {
                RouteDecision::Finish => {
                    self.complete(id);
                    return true;
                }
                RouteDecision::Assign(m) => {
                    if ordinal > 0 {
                        if m == r.model {
                            // re-assigning the same model is a no-op
                            // escalation: the pipeline ends here
                            self.complete(id);
                            return true;
                        }
                        // graceful degradation: when every client
                        // hosting the escalation target is down, finish
                        // with the current pass's answer instead of
                        // stranding the request in a dark lane
                        let lane_dark = match &self.faults {
                            Some(plan) => !self.clients.iter().any(|c| {
                                c.served_models().contains(&m)
                                    && plan.health_at(self.clock, c.id())
                            }),
                            None => false,
                        };
                        if lane_dark {
                            self.complete(id);
                            return true;
                        }
                        // escalation: bank the superseded pass's work
                        // and restart progress + per-pass latency marks
                        r.prior_decoded += r.decoded * r.branches;
                        if r.first_response_time.is_none() {
                            r.first_response_time = r.first_token_time;
                        }
                        r.first_token_time = None;
                        r.last_token_time = None;
                        r.prefilled = 0;
                        r.decoded = 0;
                    }
                    r.model = m;
                    if !r.advance_stage() {
                        // a trailing ModelRoute (no stages after it)
                        self.complete(id);
                        return true;
                    }
                }
            }
        }
    }

    /// Consume `KvMigration` stages at the request's current position
    /// (cluster-level disaggregation, docs/disaggregation.md). Like
    /// `ModelRoute` the stage never occupies a client, but the hand-off
    /// is real work: the outbound hop is re-priced as the full prefix
    /// KV, switched to the migration granularity override (a `Full`
    /// override models a blocking hand-off; `Layerwise` overlaps the
    /// per-layer slices on the link), and — when a tiered staging pool
    /// is configured — delayed by the pool's deterministic Eq. 1
    /// expected latency (a full miss streams straight into HBM, so the
    /// network hop itself is the only remaining cost). The stage span
    /// is recorded so trace exports show the hand-off. Returns the
    /// re-priced hop `(bytes, granularity, staging_seconds)`, or `None`
    /// when the request completed here (a trailing migration stage).
    fn resolve_kv_migration(
        &mut self,
        id: ReqId,
        src: usize,
        bytes: f64,
    ) -> Option<(f64, Granularity, f64)> {
        let mut bytes = bytes;
        let mut gran = self.granularity;
        let mut staging = 0.0;
        loop {
            let r = self.pool.get_mut(&id).unwrap();
            if r.stage() != Stage::KvMigration {
                return Some((bytes, gran, staging));
            }
            bytes = Self::transfer_bytes(r, Some(Stage::KvMigration));
            gran = self.migration_granularity.unwrap_or(self.granularity);
            let lat = match &self.migration_pool {
                Some(pool) => pool.expected(bytes).0,
                None => 0.0,
            };
            staging += lat;
            r.records.push(crate::workload::request::StageRecord {
                stage_idx: r.stage_idx,
                client: src,
                start: self.clock,
                end: self.clock + SimTime::from_secs(lat),
            });
            if !r.advance_stage() {
                self.complete(id);
                return None;
            }
        }
    }

    /// Candidates = clients that can serve the request's current stage;
    /// `bytes` is the outbound transfer size the caller priced on the
    /// pre-advance request state (0 for ingress, where no inter-client
    /// link is paid) and `gran` the granularity its hop will use.
    /// Cost: O(clients) — each candidate contributes an O(1) cached
    /// load plus an O(1) transfer estimate.
    fn route(
        &mut self,
        id: ReqId,
        src: Option<usize>,
        bytes: f64,
        gran: Granularity,
    ) -> Option<usize> {
        let r = &self.pool[&id];
        let stage = r.stage();
        let src_group = src.map(|s| self.clients[s].group());
        self.route_buf.clear();
        for c in &self.clients {
            if !c.can_serve(&stage, r.model) {
                continue;
            }
            // graceful degradation: a down client is no candidate
            if let Some(plan) = &self.faults {
                if !plan.health_at(self.clock, c.id()) {
                    continue;
                }
            }
            // local disaggregation: prefill→decode stays within the group
            if self.local_disagg
                && stage == Stage::Decode
                && src_group.is_some_and(|g| g != c.group())
            {
                continue;
            }
            let transfer_cost = src
                .map(|s| self.network.estimate(s, c.id(), bytes, gran))
                .unwrap_or(0.0);
            // candidate load *for this request's model*: on a
            // co-resident client a drained lane looks idle even while
            // another model's lane is saturated (single-model clients:
            // identical to the aggregate load)
            let load = match self.load_mode {
                LoadMode::Incremental => c.load_for_model(r.model),
                LoadMode::FullScan => c.full_scan_load_for_model(r.model, &self.pool),
            };
            self.route_buf.push(Candidate {
                client: c.id(),
                load,
                transfer_cost,
            });
        }
        if self.route_buf.is_empty() {
            return None;
        }
        Some(self.router.pick(r, &self.route_buf))
    }

    fn fail(&mut self, id: ReqId) {
        // unwind an assigned in-flight request before retiring it: the
        // owning client must release its scheduler slot, KV reservation
        // and load-account counters, or `assert_load_invariant` trips on
        // the very next event (regression: a bare fail() used to leak
        // all three). Pre-admission failures carry no owner — for them
        // this block is dead code and the path is byte-identical.
        if let Some(c) = self.pool[&id].client {
            self.clients[c].evict(id, &mut self.pool);
            self.activate(c);
            self.shard_note_load(c);
        }
        self.stats.failed += 1;
        self.stats.inflight -= 1;
        let r = self.pool.get_mut(&id).unwrap();
        r.finished = None;
        r.failed = true;
        let rec = CompletionRecord::of(r, true);
        if let Some(sink) = &mut self.sink {
            sink.fold(&rec);
        } else {
            self.failed.push(id);
            self.records.push(rec);
            if let Some(ctx) = &mut self.shard {
                ctx.record_keys.push(self.clock);
            }
        }
        if self.retire {
            self.pool.remove(id);
        }
    }

    fn activate(&mut self, c: usize) {
        if let Some(plan) = &self.faults {
            // a down client starts no new work: its residents drain at
            // the crash event, and a recovered client resumes at the
            // next delivery or completion that touches it
            if !plan.health_at(self.clock, c) {
                return;
            }
        }
        if let Some(fin) = self.clients[c].maybe_start_step(self.clock, &mut self.pool) {
            // a slowdown window (degraded/brown-out client) stretches
            // the step's duration; the `f > 1.0` guard keeps runs with
            // no slowdown windows on the exact pre-fault arithmetic
            let fin = match &self.faults {
                Some(plan) => {
                    let f = plan.slowdown_at(self.clock, c);
                    if f > 1.0 {
                        self.clock + SimTime::from_secs((fin - self.clock).as_secs() * f)
                    } else {
                        fin
                    }
                }
                None => fin,
            };
            self.queue.push(fin, Event::EngineStep { client: c });
        }
    }

    /// Every request that entered (or will enter) the system completed
    /// or failed. Counter-based — the pool only holds *live* requests
    /// under retirement, and the `serviced`/`failed` ID vecs are empty
    /// under streaming metrics, so only the counters are the run total
    /// in every mode (in exact mode they equal the vec lengths).
    pub fn all_serviced(&self) -> bool {
        self.source.drained() && self.stats.serviced + self.stats.failed == self.stats.injected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::LlmClient;
    use crate::hardware::models::LLAMA3_70B;
    use crate::hardware::npu::H100;
    use crate::hardware::roofline::LlmCluster;
    use crate::perfmodel::RooflinePerfModel;
    use crate::scheduler::{BatchingKind, LlmSched, Packing, SchedConfig};
    use crate::workload::trace::{TraceKind, WorkloadSpec};

    fn llm_client(id: usize, kind: BatchingKind) -> Box<dyn Client> {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        Box::new(
            LlmClient::new(
                id,
                cluster.clone(),
                LlmSched::new(kind, Packing::Fcfs, SchedConfig::default()),
                Box::new(RooflinePerfModel::new(cluster)),
            )
            .with_group(id),
        )
    }

    fn workload(n: usize, rate: f64) -> Vec<crate::workload::request::Request> {
        WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, rate)
            .with_seed(11)
            .generate(0)
    }

    #[test]
    fn end_to_end_continuous_two_clients() {
        let clients = vec![
            llm_client(0, BatchingKind::Continuous),
            llm_client(1, BatchingKind::Continuous),
        ];
        let net = Network::single_platform(2);
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            net,
        );
        coord.inject(workload(40, 4.0));
        coord.run();
        assert!(coord.all_serviced(), "serviced {}", coord.serviced.len());
        assert_eq!(coord.serviced.len(), 40);
        assert_eq!(coord.failed.len(), 0);
        // every request has full latency metrics
        for id in &coord.serviced {
            let r = &coord.pool[id];
            assert!(r.ttft().unwrap() > 0.0);
            assert!(r.e2e_latency().unwrap() >= r.ttft().unwrap());
            assert!(r.decode_complete());
        }
        // both clients did work (load balancing)
        assert!(coord.clients[0].stats().steps > 0);
        assert!(coord.clients[1].stats().steps > 0);
    }

    #[test]
    fn disaggregated_prefill_decode_handoff() {
        let clients = vec![
            llm_client(0, BatchingKind::PrefillOnly),
            llm_client(1, BatchingKind::DecodeOnly),
        ];
        let net = Network::single_platform(2);
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            net,
        );
        coord.inject(workload(20, 4.0));
        coord.run();
        assert!(coord.all_serviced());
        assert_eq!(coord.serviced.len(), 20);
        // every request moved prefill→decode → 20 KV transfers
        assert_eq!(coord.stats.transfers, 20);
        assert!(coord.stats.transfer_bytes > 0.0);
        // decode client generated all the tokens beyond the first
        assert!(coord.clients[1].stats().decode_tokens > 0);
        assert_eq!(coord.clients[0].stats().decode_tokens as usize, 20);
    }

    #[test]
    fn disagg_pipeline_prices_explicit_migration() {
        use crate::workload::trace::Pipeline;
        let mk = || {
            vec![
                llm_client(0, BatchingKind::PrefillOnly),
                llm_client(1, BatchingKind::DecodeOnly),
            ]
        };
        let gen = || {
            WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 12, 4.0)
                .with_seed(29)
                .with_pipeline(Pipeline::Disagg)
                .generate(0)
        };
        let mut coord = Coordinator::new(
            mk(),
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::single_platform(2),
        );
        coord.inject(gen());
        coord.run();
        assert!(coord.all_serviced());
        assert_eq!(coord.serviced.len(), 12);
        assert_eq!(coord.stats.transfers, 12, "one migration hop per request");
        // the hop moves the full prefix KV of every request
        let kv_per_tok = crate::model::ModelId::named("llama3-70b")
            .spec()
            .kv_bytes_per_token();
        let expected: f64 = coord
            .serviced
            .iter()
            .map(|id| {
                let r = &coord.pool[id];
                (r.past_tokens + r.prompt_tokens) as f64 * kv_per_tok
            })
            .sum();
        assert!(
            (coord.stats.transfer_bytes - expected).abs() < 1e-6 * expected,
            "migrated {} vs expected {expected}",
            coord.stats.transfer_bytes
        );
        // every request carries a kv_migration stage span
        for id in &coord.serviced {
            let r = &coord.pool[id];
            assert!(r
                .records
                .iter()
                .any(|rec| r.stages[rec.stage_idx] == Stage::KvMigration));
        }

        // a tiered staging pool delays completion deterministically
        let mut staged = Coordinator::new(
            mk(),
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::single_platform(2),
        );
        staged.migration_pool = Some(Hierarchy::new(vec![
            crate::memory::hierarchy::TIER_DRAM,
            crate::memory::hierarchy::TIER_NVME,
        ]));
        staged.migration_granularity = Some(Granularity::Full);
        staged.inject(gen());
        staged.run();
        assert!(staged.all_serviced());
        assert!(
            staged.clock > coord.clock,
            "staging latency must delay completion: {} vs {}",
            staged.clock,
            coord.clock
        );
    }

    #[test]
    fn colocated_disagg_pipeline_matches_regular() {
        // the serial oracle, client-level: on a colocated pool the
        // KvMigration stage is consumed in place at zero cost, so the
        // Disagg pipeline is bit-identical to Pipeline::Regular
        use crate::workload::trace::Pipeline;
        let run = |p: Pipeline| {
            let clients = vec![
                llm_client(0, BatchingKind::Continuous),
                llm_client(1, BatchingKind::Continuous),
            ];
            let mut coord = Coordinator::new(
                clients,
                Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
                Network::single_platform(2),
            );
            let reqs = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 25, 5.0)
                .with_seed(31)
                .with_pipeline(p)
                .generate(0);
            coord.inject(reqs);
            coord.run();
            assert!(coord.all_serviced());
            (
                coord.serviced.clone(),
                coord.clock,
                coord.stats.events,
                coord.stats.transfers,
            )
        };
        assert_eq!(run(Pipeline::Disagg), run(Pipeline::Regular));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let clients = vec![
                llm_client(0, BatchingKind::Chunked { chunk: 512 }),
                llm_client(1, BatchingKind::Chunked { chunk: 512 }),
            ];
            let mut coord = Coordinator::new(
                clients,
                Router::new(RoutePolicy::RoundRobin),
                Network::single_platform(2),
            );
            coord.inject(workload(30, 6.0));
            coord.run();
            (
                coord.clock,
                coord.stats.events,
                coord
                    .serviced
                    .iter()
                    .map(|id| coord.pool[id].e2e_latency().unwrap())
                    .sum::<f64>(),
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn rag_handoff_priced_on_pre_advance_state() {
        // regression: the post-RAG text transfer must be priced from the
        // pre-advance request state (prompt + retrieved docs from the
        // stage params), not from whatever `advance_stage()` left in
        // `prompt_tokens`
        use crate::hardware::models::E5_BASE;
        use crate::hardware::npu::GRACE_CPU;
        use crate::rag::ivfpq::IvfPq;
        use crate::rag::RagEngine;
        use crate::workload::request::{RagParams, Request};

        let clients: Vec<Box<dyn Client>> = vec![
            llm_client(0, BatchingKind::Continuous),
            Box::new(crate::client::RagClient::new(
                1,
                RagEngine::new(
                    LlmCluster::new(E5_BASE, GRACE_CPU, 1),
                    IvfPq::new(GRACE_CPU, Default::default()),
                ),
                0,
            )),
        ];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(2),
        );
        let params = RagParams::default();
        let prompt = 1000usize;
        let req = Request::new(
            1,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Rag(params), Stage::Prefill, Stage::Decode],
            prompt,
            8,
        );
        coord.inject(vec![req]);
        coord.run();
        assert!(coord.all_serviced());
        // exactly one inter-client hop: RAG → LLM, moving the prompt
        // plus the retrieved documents as text at 4 B/token
        assert_eq!(coord.stats.transfers, 1);
        let expected = (prompt + params.context_tokens()) as f64 * 4.0;
        assert_eq!(
            coord.stats.transfer_bytes, expected,
            "post-RAG transfer must move prompt + retrieved context"
        );
    }

    #[test]
    fn oversized_request_fails_cleanly() {
        let clients = vec![llm_client(0, BatchingKind::Continuous)];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(1),
        );
        // a 8-branch 60k-output monster exceeds TP8 KV capacity, but the
        // router still places it; the scheduler simply never admits it.
        // Instead test the un-servable stage: wrong model.
        let mut reqs = workload(1, 1.0);
        reqs[0].model = "mistral-7b".into();
        coord.inject(reqs);
        coord.run();
        assert_eq!(coord.failed.len(), 1);
        assert!(coord.all_serviced());
    }

    #[test]
    fn routed_pipeline_without_policy_keeps_model() {
        // a ModelRoute stage with no policy is the identity: same
        // serviced set, no client ever sees the stage
        let clients = vec![llm_client(0, BatchingKind::Continuous)];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(1),
        );
        let reqs = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 10, 4.0)
            .with_seed(13)
            .with_pipeline(crate::workload::trace::Pipeline::Routed)
            .generate(0);
        coord.inject(reqs);
        coord.run();
        assert!(coord.all_serviced());
        assert_eq!(coord.serviced.len(), 10);
        for id in &coord.serviced {
            assert_eq!(coord.pool[id].model, crate::model::ModelId::named("llama3-70b"));
            assert!(coord.pool[id].decode_complete());
        }
    }

    #[test]
    fn cascade_escalation_reruns_on_large_model() {
        use crate::model::ModelId;
        use crate::model::policy::ModelPolicy;

        // two single-model pools: one 8B client, one 70B client; the
        // cascade sends everything through 8B and escalates a fraction
        let mk = |id: usize, spec: crate::hardware::ModelSpec| -> Box<dyn Client> {
            let cluster = LlmCluster::new(spec, H100, 8);
            Box::new(LlmClient::new(
                id,
                cluster.clone(),
                LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
                Box::new(RooflinePerfModel::new(cluster)),
            ))
        };
        let clients = vec![
            mk(0, crate::hardware::models::LLAMA3_8B),
            mk(1, LLAMA3_70B),
        ];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::single_platform(2),
        );
        let small = ModelId::named("llama3-8b");
        let large = ModelId::named("llama3-70b");
        coord.model_policy = Some(ModelPolicy::Cascade { small, large, escalate: 0.5 });
        coord.model_seed = 17;
        let n = 30;
        let reqs = WorkloadSpec::new("llama3-8b", TraceKind::AzureConv, n, 4.0)
            .with_seed(19)
            .with_pipeline(crate::workload::trace::Pipeline::Cascade)
            .generate(0);
        coord.inject(reqs);
        coord.run();
        assert!(coord.all_serviced(), "serviced {}", coord.serviced.len());
        assert_eq!(coord.serviced.len(), n);
        let escalated = coord
            .serviced
            .iter()
            .filter(|id| coord.pool[*id].model == large)
            .count();
        assert!(
            escalated > 0 && escalated < n,
            "escalation fraction 0.5 must split the population, got {escalated}/{n}"
        );
        // both pools did real work
        assert!(coord.clients[0].stats().decode_tokens > 0, "small model decodes");
        assert!(coord.clients[1].stats().decode_tokens > 0, "large model decodes");
        // escalated requests re-ran: their decode completed on the large
        // model and the finish stamp is after the first token
        for id in &coord.serviced {
            let r = &coord.pool[id];
            assert!(r.decode_complete());
            assert!(r.finished.unwrap() >= r.first_token_time.unwrap());
            if r.model == large {
                // the superseded small-model pass is banked for
                // throughput, TTFT is frozen at its first token, and
                // TPOT spans only the final pass
                assert!(r.prior_decoded > 0, "escalation banks draft tokens");
                let first_seen = r.first_response_time.expect("frozen TTFT mark");
                assert!(first_seen <= r.first_token_time.unwrap());
                assert_eq!(r.ttft().unwrap(), (first_seen - r.arrival).as_secs());
                assert_eq!(
                    r.generated_tokens(),
                    r.prior_decoded + r.decoded * r.branches
                );
            } else {
                assert_eq!(r.prior_decoded, 0);
                assert!(r.first_response_time.is_none());
            }
        }
    }

    #[test]
    fn static_policy_splits_traffic_across_model_pools() {
        use crate::model::ModelId;
        use crate::model::policy::ModelPolicy;

        let mk = |id: usize, spec: crate::hardware::ModelSpec| -> Box<dyn Client> {
            let cluster = LlmCluster::new(spec, H100, 8);
            Box::new(LlmClient::new(
                id,
                cluster.clone(),
                LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
                Box::new(RooflinePerfModel::new(cluster)),
            ))
        };
        let clients = vec![
            mk(0, crate::hardware::models::LLAMA3_8B),
            mk(1, LLAMA3_70B),
        ];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::single_platform(2),
        );
        coord.model_policy = Some(ModelPolicy::Static {
            choices: vec![
                (ModelId::named("llama3-8b"), 0.5),
                (ModelId::named("llama3-70b"), 0.5),
            ],
        });
        let reqs = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 40, 4.0)
            .with_seed(23)
            .with_pipeline(crate::workload::trace::Pipeline::Routed)
            .generate(0);
        coord.inject(reqs);
        coord.run();
        assert!(coord.all_serviced());
        assert!(coord.clients[0].stats().requests_served > 0);
        assert!(coord.clients[1].stats().requests_served > 0);
    }

    #[test]
    fn inject_rejects_duplicate_ids_on_both_backends() {
        // both pool backends must reject a duplicate id with the same
        // error — the arena would corrupt its resident index and the
        // map would silently overwrite
        for backend in [
            crate::scheduler::PoolBackend::Arena,
            crate::scheduler::PoolBackend::Map,
        ] {
            let mut coord = Coordinator::new(
                vec![llm_client(0, BatchingKind::Continuous)],
                Router::new(RoutePolicy::RoundRobin),
                Network::single_platform(1),
            );
            coord.pool = RequestPool::with_backend(backend);
            let mut reqs = workload(2, 4.0);
            reqs[1].id = reqs[0].id;
            let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                coord.inject(reqs);
            }))
            .expect_err("duplicate id must be rejected");
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(
                msg.contains("duplicate request id"),
                "{backend:?}: unexpected panic message: {msg}"
            );
        }
    }

    #[test]
    fn streaming_source_with_retirement_drains_and_bounds_pool() {
        use crate::workload::trace::WorkloadMix;

        let mk = || {
            let clients = vec![
                llm_client(0, BatchingKind::Continuous),
                llm_client(1, BatchingKind::Continuous),
            ];
            Coordinator::new(
                clients,
                Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
                Network::single_platform(2),
            )
        };
        let mix = WorkloadMix::single(
            WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 40, 4.0).with_seed(11),
        );
        // baseline: eager + retained
        let mut eager = mk();
        eager.inject(mix.generate());
        eager.run();
        // streaming + retirement
        let mut lazy = mk();
        lazy.stream(&mix);
        lazy.retire = true;
        lazy.run();
        assert!(lazy.all_serviced(), "serviced {}", lazy.serviced.len());
        assert_eq!(lazy.serviced, eager.serviced, "completion order diverged");
        assert_eq!(lazy.clock, eager.clock);
        assert_eq!(lazy.stats.events, eager.stats.events);
        assert_eq!(lazy.stats.injected, 40);
        // every slot was freed; the pool never held the whole trace
        let ops = lazy.pool.ops();
        assert_eq!(ops.len, 0, "all requests retired");
        assert_eq!(ops.retired, 40);
        assert!(
            ops.peak_live < 40,
            "peak live {} must stay below the trace length",
            ops.peak_live
        );
        assert_eq!(
            ops.peak_live, lazy.stats.peak_inflight,
            "pool occupancy must track in-flight exactly"
        );
        // records survive retirement, in completion order
        assert_eq!(lazy.records.len(), 40);
        for (rec, id) in lazy.records.iter().zip(&lazy.serviced) {
            assert_eq!(rec.id, *id);
            assert!(!rec.failed);
        }
    }

    #[test]
    fn local_disagg_restricts_groups() {
        // groups: (0:P,1:D) and (2:P,3:D) — local mode must keep hand-offs
        // within the group
        let clients = vec![
            llm_client(0, BatchingKind::PrefillOnly),
            llm_client(1, BatchingKind::DecodeOnly),
            llm_client(2, BatchingKind::PrefillOnly),
            llm_client(3, BatchingKind::DecodeOnly),
        ];
        // group assignment: with_group(id) gives ids 0..3; rebuild pairs
        let clients: Vec<Box<dyn Client>> = clients
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                let _ = c;
                let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
                let kind = if i % 2 == 0 {
                    BatchingKind::PrefillOnly
                } else {
                    BatchingKind::DecodeOnly
                };
                Box::new(
                    LlmClient::new(
                        i,
                        cluster.clone(),
                        LlmSched::new(kind, Packing::Fcfs, SchedConfig::default()),
                        Box::new(RooflinePerfModel::new(cluster)),
                    )
                    .with_group(i / 2),
                ) as Box<dyn Client>
            })
            .collect();
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::hierarchy(4, 2, 4),
        );
        coord.local_disagg = true;
        coord.inject(workload(16, 8.0));
        coord.run();
        assert!(coord.all_serviced());
        // all transfers stayed on-platform (NVLink): nothing on the DCN
        // and nothing on rack switches
        assert_eq!(coord.network.bytes_on_dcn(), 0.0);
        assert!(coord.network.bytes_intra_platform > 0.0);
    }

    #[test]
    fn failing_an_assigned_request_releases_residency() {
        // regression (robustness PR bugfix): failing an *assigned*
        // in-flight request — here via an elapsed deadline mid-decode —
        // must unwind its scheduler slot, KV reservation and load
        // accounting. `assert_load_invariant` runs after every event in
        // debug builds, so a leak aborts the run immediately.
        let clients = vec![llm_client(0, BatchingKind::Continuous)];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(1),
        );
        let mut reqs = workload(4, 50.0);
        for r in &mut reqs {
            // elapses mid-decode: the request is resident and mid-step
            // when the Deadline event fires
            r.deadline = Some(r.arrival + SimTime::from_secs(0.05));
        }
        coord.inject(reqs);
        coord.run();
        assert!(coord.all_serviced());
        assert!(coord.stats.timeouts > 0, "deadlines must fire mid-run");
        assert_eq!(coord.stats.failed, coord.stats.timeouts);
        coord.assert_load_invariant();
        for id in &coord.failed {
            let r = &coord.pool[id];
            assert!(r.client.is_none(), "failed request still resident");
            assert!(r.timed_out && r.failed);
        }
        assert_eq!(
            coord.stats.serviced + coord.stats.failed,
            coord.stats.injected
        );
    }

    #[test]
    fn crash_orphans_reroute_and_conserve_requests() {
        use crate::fault::{CrashSpec, FaultPlan, FaultSpec};
        let clients = vec![
            llm_client(0, BatchingKind::Continuous),
            llm_client(1, BatchingKind::Continuous),
        ];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
            Network::single_platform(2),
        );
        let mut spec = FaultSpec::new(7);
        spec.crashes.push(CrashSpec {
            client: 0,
            at: 0.2,
            down_for: 3.0,
        });
        coord.faults = Some(FaultPlan::compile(&spec, 2, 1).unwrap());
        coord.inject(workload(20, 20.0));
        coord.run();
        assert!(coord.all_serviced());
        assert!(
            coord.stats.orphaned > 0,
            "a crash at t=0.2s must orphan in-flight work"
        );
        assert!(coord.stats.retries > 0, "orphans re-enter with backoff");
        assert_eq!(
            coord.stats.serviced + coord.stats.failed,
            coord.stats.injected,
            "crash must conserve requests"
        );
        // the surviving lane absorbed the re-routed work
        assert!(coord.clients[1].stats().requests_served > 0);
        // nothing is left resident anywhere
        coord.assert_load_invariant();
    }
}
