//! HERMES — Heterogeneous Multi-stage LLM inference Execution Simulator.
//!
//! Rust + JAX + Pallas reproduction of "Understanding and Optimizing
//! Multi-Stage AI Inference Pipelines" (Bambhaniya et al., 2025).
//!
//! The dataflow follows the paper's architecture (§III):
//!
//! ```text
//! scenarios/*.json ──► scenario ──► config ──► sim::builder ──► Coordinator
//!                                                                   │ events
//!                                           clients (LLM/RAG/KV/prepost)
//!                                                │ step plans       │
//!                                 scheduler (BatchPolicy) ── perfmodel
//!                                                                   │
//!                                                  metrics ◄── requests
//! ```
//!
//! * [`coordinator`] — global event loop, routing, inter-client transfers
//!   (§III-B, Algorithm 1).
//! * [`client`] — LLM / RAG / KV-retrieval / pre-post serving clients
//!   (§III-C).
//! * [`scheduler`] — pluggable batching policies + packing + admission
//!   (§III-D).
//! * [`model`] — interned `ModelId` registry and dynamic model-routing
//!   policies (static mix / length threshold / cascade) behind the
//!   `Stage::ModelRoute` pipeline stage (docs/models.md).
//! * [`perfmodel`] / [`hardware`] — step-time prediction: roofline
//!   analytical model, fitted polynomial, AOT Pallas via PJRT (§III-E).
//! * [`workload`] / [`rag`] / [`memory`] / [`network`] — request
//!   pipelines, retrieval and communication modeling (§III-E/F).
//! * [`scenario`] / [`config`] — declarative front-end: data-driven
//!   scenario registry and the JSON config schema (§III-A).
//! * [`fault`] — deterministic fault injection and recovery: client
//!   crash/slowdown windows, link outages, stage-failure coin flips,
//!   deadlines, retries with backoff (docs/robustness.md).
//! * [`experiments`] — paper figure/table regenerators (§IV–V).
//! * [`bench`] — the `hermes bench` core-speed harness
//!   (`BENCH_core.json`, docs/performance.md).
//!
//! See README.md for the quickstart and the bench → paper-figure map.

pub mod util;
pub mod hardware;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sim;
pub mod workload;
pub mod memory;
pub mod network;
pub mod rag;
pub mod scheduler;
pub mod client;
pub mod coordinator;
pub mod config;
pub mod fault;
pub mod scenario;
pub mod metrics;
pub mod experiments;
pub mod bench;
