//! HERMES — Heterogeneous Multi-stage LLM inference Execution Simulator.
//!
//! Rust + JAX + Pallas reproduction of "Understanding and Optimizing
//! Multi-Stage AI Inference Pipelines" (Bambhaniya et al., 2025).
//!
//! See DESIGN.md for the module map and the per-experiment index.

pub mod util;
pub mod hardware;
pub mod perfmodel;
pub mod runtime;
pub mod sim;
pub mod workload;
pub mod memory;
pub mod network;
pub mod rag;
pub mod scheduler;
pub mod client;
pub mod coordinator;
pub mod config;
pub mod metrics;
pub mod experiments;
