//! LLM inference client (paper §III-C.4): an `LlmSched` with a pluggable
//! [`BatchPolicy`](crate::scheduler::BatchPolicy) in front of a hardware
//! cluster, with step latency priced by the `PerfModel` (AOT Pallas
//! predictor / native poly / roofline).
//!
//! A *combined* client serves both prefill and decode (continuous /
//! chunked / static / mixed batching). Disaggregated serving instantiates
//! prefill-role and decode-role clients; the roles are derived from the
//! policy's `serves_prefill`/`serves_decode` answers and the coordinator
//! moves the KV cache between them.

use crate::client::{Client, ClientLoad, ClientStats, LoadAccount, StepOutcome};
use crate::hardware::power;
use crate::hardware::roofline::LlmCluster;
use crate::memory::hierarchy::KvManager;
use crate::perfmodel::PerfModel;
use crate::scheduler::{LlmSched, RequestPool, StepPlan};
use crate::sim::SimTime;
use crate::workload::request::{ReqId, Stage};

pub struct LlmClient {
    id: usize,
    pub cluster: LlmCluster,
    pub sched: LlmSched,
    pub kv: KvManager,
    pub perf: Box<dyn PerfModel>,
    group: usize,
    /// the in-flight step, if any: (start, duration)
    current: Option<(SimTime, f64)>,
    /// reusable step-plan buffer: filled by `maybe_start_step`, drained
    /// by `finish_step`, capacity kept across steps (no allocations on
    /// the steady-state hot path)
    plan: StepPlan,
    /// incremental token counters behind the O(1) `load()`
    acct: LoadAccount,
    stats: ClientStats,
    /// queue-length / memory samples for scheduler-level metrics
    pub queue_samples: Vec<(SimTime, usize, f64)>,
    sample_queue: bool,
}

impl LlmClient {
    pub fn new(
        id: usize,
        cluster: LlmCluster,
        sched: LlmSched,
        perf: Box<dyn PerfModel>,
    ) -> LlmClient {
        let kv = KvManager::new(cluster.kv_capacity_tokens());
        LlmClient {
            id,
            cluster,
            sched,
            kv,
            perf,
            group: 0,
            current: None,
            plan: StepPlan::default(),
            acct: LoadAccount::default(),
            stats: ClientStats::default(),
            queue_samples: Vec::new(),
            sample_queue: false,
        }
    }

    pub fn with_group(mut self, group: usize) -> LlmClient {
        self.group = group;
        self
    }

    /// Record scheduler-level metrics every step (off by default: hot path).
    pub fn with_queue_sampling(mut self) -> LlmClient {
        self.sample_queue = true;
        self
    }

    pub fn is_busy(&self) -> bool {
        self.current.is_some()
    }
}

impl Client for LlmClient {
    fn id(&self) -> usize {
        self.id
    }

    fn kind_name(&self) -> &'static str {
        match (self.sched.serves_prefill(), self.sched.serves_decode()) {
            (true, false) => "llm-prefill",
            (false, true) => "llm-decode",
            _ => "llm",
        }
    }

    fn group(&self) -> usize {
        self.group
    }

    fn can_serve(&self, stage: &Stage, model: &str) -> bool {
        if model != self.cluster.model.name {
            return false;
        }
        match stage {
            Stage::Prefill => self.sched.serves_prefill(),
            Stage::Decode => self.sched.serves_decode(),
            _ => false,
        }
    }

    fn accept(&mut self, _now: SimTime, id: ReqId, pool: &mut RequestPool) {
        pool.assign(id, self.id);
        self.acct.accept(&pool[&id]);
        self.sched.enqueue(id);
    }

    fn maybe_start_step(&mut self, now: SimTime, pool: &mut RequestPool) -> Option<SimTime> {
        if self.current.is_some() {
            return None;
        }
        if !self.sched.plan_into(pool, &mut self.kv, &mut self.plan) {
            return None;
        }
        let feats = self.plan.features(pool);
        // Decode-only steps evolve predictably (same batch, KV grows by
        // one token per sequence per step), so price the next LOOKAHEAD
        // steps in one predict_batch call: behind the memoized PJRT
        // backend this turns ~16 executable invocations into one
        // (EXPERIMENTS.md §Perf).
        const LOOKAHEAD: usize = 16;
        let pred = if feats.pf_new == 0.0 && feats.dec_batch > 0.0 {
            let mut traj = [feats; LOOKAHEAD];
            for (i, t) in traj.iter_mut().enumerate() {
                t.dec_kv += i as f64 * feats.dec_batch;
            }
            self.perf.predict_batch(&traj)[0]
        } else {
            self.perf.predict(feats)
        };
        let dur = pred.t_step.max(1e-6);
        if self.sample_queue {
            self.queue_samples
                .push((now, self.sched.queue_len(), self.kv.used_tokens));
        }
        // energy: utilization from the analytical cluster model
        let util = if feats.pf_new > 0.0 {
            // prefill work present → compute-bound step
            crate::hardware::roofline::EFF_COMPUTE
        } else {
            // decode-only → memory-bound, low compute utilization
            0.08
        };
        self.stats.steps += 1;
        self.stats.busy_seconds += dur;
        self.stats.energy_joules +=
            power::step_energy(&self.cluster.npu, self.cluster.tp, util, dur);
        self.current = Some((now, dur));
        Some(now + SimTime::from_secs(dur))
    }

    fn finish_step(&mut self, now: SimTime, pool: &mut RequestPool) -> StepOutcome {
        self.current.take().expect("finish_step without step");
        // move the plan buffer out for the duration of the borrow-heavy
        // body; handed back (with its capacity) at the end
        let plan = std::mem::take(&mut self.plan);
        let mut out = StepOutcome::default();

        for (id, n) in &plan.prefill {
            let r = pool.get_mut(id).expect("prefill req");
            r.prefilled += n;
            self.acct.prefill_progress(*n);
            self.stats.prefill_tokens += *n as u64;
            if r.prefill_complete() {
                // the step completing a prompt emits the first token
                if r.first_token_time.is_none() {
                    r.first_token_time = Some(now);
                    r.last_token_time = Some(now);
                    r.decoded = 1;
                    self.acct.decode_progress(r.decode_seqs());
                    self.stats.decode_tokens += r.decode_seqs() as u64;
                }
                if !self.sched.serves_decode() {
                    // prefill-role client: hand off to a decode client
                    out.stage_done.push(*id);
                } else {
                    // combined client: Prefill stage → Decode stage in
                    // place (no coordinator round-trip)
                    if r.stage() == Stage::Prefill && !r.is_last_stage() {
                        r.advance_stage();
                    }
                    if r.decode_complete() {
                        out.stage_done.push(*id); // 1-token outputs
                    }
                }
            }
        }

        for id in &plan.decode {
            let r = pool.get_mut(id).expect("decode req");
            r.decoded += 1;
            self.acct.decode_progress(r.decode_seqs());
            self.stats.decode_tokens += r.decode_seqs() as u64;
            if r.first_token_time.is_none() {
                r.first_token_time = Some(now);
            }
            r.last_token_time = Some(now);
            if r.decode_complete() {
                out.stage_done.push(*id);
            }
        }

        // release finished requests from scheduler + KV + pool residency
        for id in &out.stage_done {
            if let Some(reserved) = self.sched.remove(*id) {
                self.kv.release(reserved);
            }
            self.acct.release(&pool[id]);
            pool.unassign(*id);
            self.stats.requests_served += 1;
        }
        self.plan = plan;
        out
    }

    fn load(&self) -> ClientLoad {
        ClientLoad {
            queued_requests: self.sched.queue_len() + self.sched.running_len(),
            input_tokens: self.acct.input_tokens,
            output_tokens: self.acct.output_tokens,
            kv_tokens: self.kv.used_tokens,
            tokens_left: self.acct.tokens_left,
        }
    }

    fn recompute_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len() + self.sched.running_len(),
            kv_tokens: self.kv.used_tokens,
            ..Default::default()
        };
        for r in pool.iter_client(self.id) {
            l.input_tokens += r.prompt_tokens as f64;
            l.output_tokens += (r.output_tokens * r.branches) as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn full_scan_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len() + self.sched.running_len(),
            kv_tokens: self.kv.used_tokens,
            ..Default::default()
        };
        for (_, r) in pool.iter().filter(|(_, r)| r.client == Some(self.id)) {
            l.input_tokens += r.prompt_tokens as f64;
            l.output_tokens += (r.output_tokens * r.branches) as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn stats(&self) -> ClientStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::LLAMA3_70B;
    use crate::hardware::npu::H100;
    use crate::perfmodel::RooflinePerfModel;
    use crate::scheduler::{BatchingKind, Packing, SchedConfig};
    use crate::workload::request::Request;

    fn client(kind: BatchingKind) -> LlmClient {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        LlmClient::new(
            0,
            cluster.clone(),
            LlmSched::new(kind, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        )
    }

    fn req(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    /// drive the client alone until idle; returns (finish_time, outcomes)
    fn drain(c: &mut LlmClient, pool: &mut RequestPool) -> (SimTime, Vec<ReqId>) {
        let mut now = SimTime::ZERO;
        let mut done = Vec::new();
        for _ in 0..100_000 {
            match c.maybe_start_step(now, pool) {
                Some(fin) => {
                    now = fin;
                    done.extend(c.finish_step(now, pool).stage_done);
                }
                None => break,
            }
        }
        (now, done)
    }

    #[test]
    fn continuous_runs_request_to_completion() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (fin, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        let r = &pool[&1];
        assert!(r.prefill_complete() && r.decode_complete());
        assert!(r.first_token_time.unwrap() < r.last_token_time.unwrap());
        // prefill ~50ms + 49 decode steps ~8ms each → hundreds of ms
        assert!(fin.as_secs() > 0.1 && fin.as_secs() < 2.0, "fin={fin}");
        // prefill emitted the first token: decode steps = out - 1
        assert_eq!(c.stats().steps as usize, 1 + 49);
        assert!(c.stats().energy_joules > 0.0);
    }

    #[test]
    fn ttft_faster_than_static_for_late_arrival() {
        // static batching makes request 2 wait for request 1's decode
        let run = |kind| {
            let mut c = client(kind);
            let mut pool = RequestPool::new();
            pool.insert(1, req(1, 2000, 100));
            pool.insert(2, req(2, 500, 10));
            c.accept(SimTime::ZERO, 1, &mut pool);
            // drive one step, then inject request 2
            let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
            c.finish_step(fin, &mut pool);
            c.accept(fin, 2, &mut pool);
            let mut pool2 = pool;
            let (_, done) = drain(&mut c, &mut pool2);
            assert!(done.contains(&2));
            pool2[&2].ttft().unwrap()
        };
        let t_cont = run(BatchingKind::Continuous);
        let t_static = run(BatchingKind::Static);
        assert!(
            t_cont < t_static,
            "continuous ttft {t_cont} must beat static {t_static}"
        );
    }

    #[test]
    fn prefill_only_hands_off_after_prefill() {
        let mut c = client(BatchingKind::PrefillOnly);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        let r = &pool[&1];
        assert!(r.prefill_complete());
        assert_eq!(r.decoded, 1, "prefill emits the first token");
        assert_eq!(r.stage(), Stage::Prefill, "stage advance is the coordinator's job");
        // KV released on handoff
        assert_eq!(c.kv.used_tokens, 0.0);
    }

    #[test]
    fn decode_only_serves_transferred_request() {
        let mut c = client(BatchingKind::DecodeOnly);
        let mut pool = RequestPool::new();
        let mut r = req(1, 1000, 50);
        r.prefilled = 1000;
        r.decoded = 1;
        r.advance_stage(); // Prefill -> Decode
        pool.insert(1, r);
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        assert!(pool[&1].decode_complete());
        assert_eq!(c.stats().steps, 49);
    }

    #[test]
    fn can_serve_respects_role_and_model() {
        let c = client(BatchingKind::PrefillOnly);
        assert!(c.can_serve(&Stage::Prefill, "llama3-70b"));
        assert!(!c.can_serve(&Stage::Decode, "llama3-70b"));
        assert!(!c.can_serve(&Stage::Prefill, "mistral-7b"));
        assert!(!c.can_serve(&Stage::Rag(Default::default()), "llama3-70b"));
        let d = client(BatchingKind::DecodeOnly);
        assert!(!d.can_serve(&Stage::Prefill, "llama3-70b"));
        assert!(d.can_serve(&Stage::Decode, "llama3-70b"));
    }

    #[test]
    fn load_reflects_owned_requests() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        pool.insert(2, req(2, 2000, 10)); // not accepted
        c.accept(SimTime::ZERO, 1, &mut pool);
        let l = c.load();
        assert_eq!(l.queued_requests, 1);
        assert_eq!(l.input_tokens, 1000.0);
        assert_eq!(l.tokens_left, 1050.0);
        assert_eq!(l, c.recompute_load(&pool));
    }

    #[test]
    fn incremental_load_tracks_step_progress() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let mut now = SimTime::ZERO;
        // after every step the O(1) counters must match the pool scan
        for _ in 0..100_000 {
            match c.maybe_start_step(now, &mut pool) {
                Some(fin) => {
                    now = fin;
                    c.finish_step(now, &mut pool);
                    assert_eq!(c.load(), c.recompute_load(&pool), "drift at {now}");
                }
                None => break,
            }
        }
        // drained: every counter returned to zero
        let l = c.load();
        assert_eq!(l.tokens_left, 0.0);
        assert_eq!(l.input_tokens, 0.0);
        assert_eq!(l.queued_requests, 0);
    }

    #[test]
    fn multibranch_decode_counts_sequences() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        let mut r = req(1, 100, 10);
        r.branches = 8;
        pool.insert(1, r);
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        // 8 branches × 10 tokens
        assert_eq!(c.stats().decode_tokens, 80);
    }
}
