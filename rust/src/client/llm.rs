//! LLM inference client (paper §III-C.4): an `LlmSched` with a pluggable
//! [`BatchPolicy`](crate::scheduler::BatchPolicy) in front of a hardware
//! cluster, with step latency priced by the `PerfModel` (AOT Pallas
//! predictor / native poly / roofline).
//!
//! A *combined* client serves both prefill and decode (continuous /
//! chunked / static / mixed batching). Disaggregated serving instantiates
//! prefill-role and decode-role clients; the roles are derived from the
//! policy's `serves_prefill`/`serves_decode` answers and the coordinator
//! moves the KV cache between them.
//!
//! **Co-resident models** (docs/models.md): a client may host several
//! [`ModelInstance`]s on one NPU shard — every model's weights stay
//! resident, so the KV pool shrinks to the HBM left after *all* weight
//! shards, and the scheduler runs one lane per model against that shared
//! budget (lane reservations are scaled by each model's KV bytes/token).
//! Every engine step executes exactly one model; steps alternate
//! round-robin across lanes with work. A single-instance client is
//! bit-identical to the pre-multi-model client: token-denominated KV
//! manager, one lane, aggregate == per-model load.

use crate::client::{Client, ClientLoad, ClientStats, LoadAccount, StepOutcome};
use crate::hardware::power;
use crate::hardware::roofline::LlmCluster;
use crate::memory::hierarchy::KvManager;
use crate::model::ModelId;
use crate::perfmodel::PerfModel;
use crate::scheduler::{
    BatchingKind, LaneSpec, LlmSched, Packing, RequestPool, SchedConfig, StepPlan,
};
use crate::sim::SimTime;
use crate::workload::request::{ReqId, Stage};

/// One co-resident model entry for [`LlmClient::with_models`]: the
/// hardware view, its step-time predictor and its batching-policy kind.
pub type ModelEntry = (LlmCluster, Box<dyn PerfModel>, BatchingKind);

/// Cluster-level serving role (docs/disaggregation.md), derived from
/// the batching policy's `serves_prefill`/`serves_decode` answers. A
/// `Prefill` client releases its KV budget when a request hands off;
/// the coordinator prices the migration to the `Decode` client over the
/// network. A `Colocated` client consumes `Stage::KvMigration` in
/// place at zero cost — the disaggregation serial oracle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterRole {
    /// serves only `Stage::Prefill`; hands KV off after the first token
    Prefill,
    /// serves only `Stage::Decode`; target of KV migrations
    Decode,
    /// serves both stages on one client (no hand-off)
    Colocated,
}

impl ClusterRole {
    pub fn name(&self) -> &'static str {
        match self {
            ClusterRole::Prefill => "prefill",
            ClusterRole::Decode => "decode",
            ClusterRole::Colocated => "colocated",
        }
    }
}

/// One co-resident model on an LLM client: its interned id, the
/// hardware shard view pricing its steps, the step-time predictor, and
/// the per-(client, model) load counters behind the O(1) router reads.
pub struct ModelInstance {
    pub model: ModelId,
    pub cluster: LlmCluster,
    pub perf: Box<dyn PerfModel>,
    acct: LoadAccount,
}

pub struct LlmClient {
    id: usize,
    /// co-resident models; index == scheduler lane index
    instances: Vec<ModelInstance>,
    /// `served_models` trait slice (parallel to `instances`)
    models: Vec<ModelId>,
    pub sched: LlmSched,
    /// shared KV pool. Units: tokens for a single-model client (exactly
    /// the pre-multi-model accounting), HBM *bytes* for a co-resident
    /// client (lane reservations are scaled by KV bytes/token).
    pub kv: KvManager,
    group: usize,
    /// the in-flight step, if any: (start, duration, scheduler lane)
    current: Option<(SimTime, f64, usize)>,
    /// reusable step-plan buffer: filled by `maybe_start_step`, drained
    /// by `finish_step`, capacity kept across steps (no allocations on
    /// the steady-state hot path)
    plan: StepPlan,
    stats: ClientStats,
    /// queue-length / memory samples for scheduler-level metrics
    pub queue_samples: Vec<(SimTime, usize, f64)>,
    sample_queue: bool,
}

impl LlmClient {
    pub fn new(
        id: usize,
        cluster: LlmCluster,
        sched: LlmSched,
        perf: Box<dyn PerfModel>,
    ) -> LlmClient {
        let kv = KvManager::new(cluster.kv_capacity_tokens());
        let model = ModelId::of_spec(&cluster.model);
        LlmClient {
            id,
            models: vec![model],
            instances: vec![ModelInstance {
                model,
                cluster,
                perf,
                acct: LoadAccount::default(),
            }],
            sched,
            kv,
            group: 0,
            current: None,
            plan: StepPlan::default(),
            stats: ClientStats::default(),
            queue_samples: Vec::new(),
            sample_queue: false,
        }
    }

    /// A client hosting several co-resident models that share one HBM
    /// budget. `entries`: one (hardware view, predictor, batching kind)
    /// per model; all clusters must share the NPU and TP degree. With a
    /// single entry this degenerates to [`LlmClient::new`] — same KV
    /// units, same scheduler shape, bit-identical behavior.
    pub fn with_models(
        id: usize,
        entries: Vec<ModelEntry>,
        packing: Packing,
        cfg: SchedConfig,
    ) -> LlmClient {
        assert!(!entries.is_empty(), "client needs at least one model");
        if entries.len() == 1 {
            let (cluster, perf, kind) = entries.into_iter().next().unwrap();
            return LlmClient::new(id, cluster, LlmSched::new(kind, packing, cfg), perf);
        }
        let npu = entries[0].0.npu.clone();
        let tp = entries[0].0.tp;
        let mut total_weights = 0.0;
        for (c, _, _) in &entries {
            assert_eq!(c.tp, tp, "co-resident models must share the TP degree");
            assert_eq!(c.npu.name, npu.name, "co-resident models must share the NPU");
            total_weights += c.model.weight_bytes();
        }
        // weight residency accounted per model: all shards stay in HBM
        // at once, and whatever survives is one shared KV byte pool
        let shared_kv_bytes = tp as f64 * npu.kv_budget(total_weights, tp);
        let mut lanes = Vec::with_capacity(entries.len());
        let mut instances = Vec::with_capacity(entries.len());
        let mut models = Vec::with_capacity(entries.len());
        for (cluster, perf, kind) in entries {
            let model = ModelId::of_spec(&cluster.model);
            assert!(
                !models.contains(&model),
                "model {model} listed twice on client {id}"
            );
            lanes.push(LaneSpec {
                model,
                policy: kind.policy(),
                kv_scale: cluster.model.kv_bytes_per_token(),
            });
            models.push(model);
            instances.push(ModelInstance {
                model,
                cluster,
                perf,
                acct: LoadAccount::default(),
            });
        }
        LlmClient {
            id,
            models,
            instances,
            sched: LlmSched::multi_model(lanes, packing, cfg),
            kv: KvManager::new(shared_kv_bytes),
            group: 0,
            current: None,
            plan: StepPlan::default(),
            stats: ClientStats::default(),
            queue_samples: Vec::new(),
            sample_queue: false,
        }
    }

    pub fn with_group(mut self, group: usize) -> LlmClient {
        self.group = group;
        self
    }

    /// Record scheduler-level metrics every step (off by default: hot path).
    pub fn with_queue_sampling(mut self) -> LlmClient {
        self.sample_queue = true;
        self
    }

    pub fn is_busy(&self) -> bool {
        self.current.is_some()
    }

    /// The primary model's hardware view (single-model clients: the
    /// only one).
    pub fn cluster(&self) -> &LlmCluster {
        &self.instances[0].cluster
    }

    /// Co-resident model instances, lane order.
    pub fn instances(&self) -> &[ModelInstance] {
        &self.instances
    }

    /// Lane/instance index hosting `model`, if any. O(instances) over a
    /// handful of entries — effectively the integer compare the routing
    /// hot path wants.
    #[inline]
    fn lane_of(&self, model: ModelId) -> Option<usize> {
        self.instances.iter().position(|i| i.model == model)
    }

    /// This client's cluster role, pinned by its batching policy.
    pub fn role(&self) -> ClusterRole {
        match (self.sched.serves_prefill(), self.sched.serves_decode()) {
            (true, false) => ClusterRole::Prefill,
            (false, true) => ClusterRole::Decode,
            _ => ClusterRole::Colocated,
        }
    }
}

impl Client for LlmClient {
    fn id(&self) -> usize {
        self.id
    }

    fn kind_name(&self) -> &'static str {
        match self.role() {
            ClusterRole::Prefill => "llm-prefill",
            ClusterRole::Decode => "llm-decode",
            ClusterRole::Colocated => "llm",
        }
    }

    fn group(&self) -> usize {
        self.group
    }

    fn can_serve(&self, stage: &Stage, model: ModelId) -> bool {
        let Some(lane) = self.lane_of(model) else {
            return false;
        };
        match stage {
            Stage::Prefill => self.sched.lane_serves_prefill(lane),
            Stage::Decode => self.sched.lane_serves_decode(lane),
            _ => false,
        }
    }

    fn accept(&mut self, _now: SimTime, id: ReqId, pool: &mut RequestPool) {
        pool.assign(id, self.id);
        let lane = self
            .lane_of(pool[&id].model)
            .expect("accept: model not hosted here");
        self.instances[lane].acct.accept(&pool[&id]);
        self.sched.enqueue_lane(lane, id);
    }

    fn maybe_start_step(&mut self, now: SimTime, pool: &mut RequestPool) -> Option<SimTime> {
        if self.current.is_some() {
            return None;
        }
        if !self.sched.plan_into(pool, &mut self.kv, &mut self.plan) {
            return None;
        }
        let lane = self.sched.planned_lane();
        let inst = &mut self.instances[lane];
        let feats = self.plan.features(pool);
        // Decode-only steps evolve predictably (same batch, KV grows by
        // one token per sequence per step), so price the next LOOKAHEAD
        // steps in one predict_batch call: behind the memoized PJRT
        // backend this turns ~16 executable invocations into one
        // (EXPERIMENTS.md §Perf).
        const LOOKAHEAD: usize = 16;
        let pred = if feats.pf_new == 0.0 && feats.dec_batch > 0.0 {
            let mut traj = [feats; LOOKAHEAD];
            for (i, t) in traj.iter_mut().enumerate() {
                t.dec_kv += i as f64 * feats.dec_batch;
            }
            inst.perf.predict_batch(&traj)[0]
        } else {
            inst.perf.predict(feats)
        };
        let dur = pred.t_step.max(1e-6);
        if self.sample_queue {
            self.queue_samples
                .push((now, self.sched.queue_len(), self.kv.used_tokens));
        }
        // energy: utilization from the analytical cluster model
        let util = if feats.pf_new > 0.0 {
            // prefill work present → compute-bound step
            crate::hardware::roofline::EFF_COMPUTE
        } else {
            // decode-only → memory-bound, low compute utilization
            0.08
        };
        self.stats.steps += 1;
        self.stats.busy_seconds += dur;
        self.stats.energy_joules +=
            power::step_energy(&inst.cluster.npu, inst.cluster.tp, util, dur);
        self.current = Some((now, dur, lane));
        Some(now + SimTime::from_secs(dur))
    }

    fn finish_step(&mut self, now: SimTime, pool: &mut RequestPool) -> StepOutcome {
        let (_, _, lane) = self.current.take().expect("finish_step without step");
        // move the plan buffer out for the duration of the borrow-heavy
        // body; handed back (with its capacity) at the end. Every
        // request in the plan belongs to the planned lane's model, so
        // one LoadAccount covers the whole step.
        let plan = std::mem::take(&mut self.plan);
        let acct = &mut self.instances[lane].acct;
        let mut out = StepOutcome::default();

        for (id, n) in &plan.prefill {
            let r = pool.get_mut(id).expect("prefill req");
            r.prefilled += n;
            acct.prefill_progress(*n);
            self.stats.prefill_tokens += *n as u64;
            if r.prefill_complete() {
                // the step completing a prompt emits the first token
                if r.first_token_time.is_none() {
                    r.first_token_time = Some(now);
                    r.last_token_time = Some(now);
                    r.decoded = 1;
                    acct.decode_progress(r.decode_seqs());
                    self.stats.decode_tokens += r.decode_seqs() as u64;
                }
                if !self.sched.lane_serves_decode(lane) {
                    // prefill-role client: hand off to a decode client
                    out.stage_done.push(*id);
                } else {
                    // combined client: Prefill stage → Decode stage in
                    // place (no coordinator round-trip)
                    if r.stage() == Stage::Prefill && !r.is_last_stage() {
                        r.advance_stage();
                    }
                    // colocated hand-off: the KV never leaves this
                    // client, so a KvMigration stage is consumed in
                    // place at zero cost — the disaggregation serial
                    // oracle (docs/disaggregation.md)
                    if r.stage() == Stage::KvMigration && !r.is_last_stage() {
                        r.advance_stage();
                    }
                    if r.decode_complete() {
                        out.stage_done.push(*id); // 1-token outputs
                    }
                }
            }
        }

        for id in &plan.decode {
            let r = pool.get_mut(id).expect("decode req");
            r.decoded += 1;
            acct.decode_progress(r.decode_seqs());
            self.stats.decode_tokens += r.decode_seqs() as u64;
            if r.first_token_time.is_none() {
                r.first_token_time = Some(now);
            }
            r.last_token_time = Some(now);
            if r.decode_complete() {
                out.stage_done.push(*id);
            }
        }

        // release finished requests from scheduler + KV + pool residency
        for id in &out.stage_done {
            if let Some(reserved) = self.sched.remove(*id) {
                self.kv.release(reserved);
            }
            acct.release(&pool[id]);
            pool.unassign(*id);
            self.stats.requests_served += 1;
        }
        self.plan = plan;
        out
    }

    fn evict(&mut self, id: ReqId, pool: &mut RequestPool) {
        if pool.get(&id).map(|r| r.client) != Some(Some(self.id)) {
            return;
        }
        // if a step is in flight with this request planned, purge it so
        // finish_step applies no progress for it (the queued EngineStep
        // event stays harmless)
        self.plan.prefill.retain(|(p, _)| *p != id);
        self.plan.decode.retain(|d| *d != id);
        if let Some(reserved) = self.sched.remove(id) {
            self.kv.release(reserved);
        }
        let lane = self
            .lane_of(pool[&id].model)
            .expect("evict: model not hosted here");
        self.instances[lane].acct.release(&pool[&id]);
        pool.unassign(id);
    }

    fn load(&self) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len() + self.sched.running_len(),
            kv_tokens: self.kv.used_tokens,
            ..Default::default()
        };
        for inst in &self.instances {
            l.input_tokens += inst.acct.input_tokens;
            l.output_tokens += inst.acct.output_tokens;
            l.tokens_left += inst.acct.tokens_left;
        }
        l
    }

    fn recompute_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len() + self.sched.running_len(),
            kv_tokens: self.kv.used_tokens,
            ..Default::default()
        };
        for r in pool.iter_client(self.id) {
            l.input_tokens += r.prompt_tokens as f64;
            l.output_tokens += (r.output_tokens * r.branches) as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn full_scan_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len() + self.sched.running_len(),
            kv_tokens: self.kv.used_tokens,
            ..Default::default()
        };
        for (_, r) in pool.iter().filter(|(_, r)| r.client == Some(self.id)) {
            l.input_tokens += r.prompt_tokens as f64;
            l.output_tokens += (r.output_tokens * r.branches) as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn load_for_model(&self, model: ModelId) -> ClientLoad {
        let Some(lane) = self.lane_of(model) else {
            return self.load();
        };
        let acct = &self.instances[lane].acct;
        ClientLoad {
            queued_requests: self.sched.lane_queue_len(lane) + self.sched.lane_running_len(lane),
            input_tokens: acct.input_tokens,
            output_tokens: acct.output_tokens,
            kv_tokens: self.sched.lane_kv_held(lane),
            tokens_left: acct.tokens_left,
        }
    }

    fn recompute_load_for_model(&self, model: ModelId, pool: &RequestPool) -> ClientLoad {
        let Some(lane) = self.lane_of(model) else {
            return self.recompute_load(pool);
        };
        let mut l = ClientLoad {
            queued_requests: self.sched.lane_queue_len(lane) + self.sched.lane_running_len(lane),
            // recomputed from the reservation map, NOT the incremental
            // counter — the per-model drift invariant compares the two
            kv_tokens: self.sched.lane_kv_recompute(lane),
            ..Default::default()
        };
        for r in pool.iter_client(self.id).filter(|r| r.model == model) {
            l.input_tokens += r.prompt_tokens as f64;
            l.output_tokens += (r.output_tokens * r.branches) as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn full_scan_load_for_model(&self, model: ModelId, pool: &RequestPool) -> ClientLoad {
        let Some(lane) = self.lane_of(model) else {
            return self.full_scan_load(pool);
        };
        let mut l = ClientLoad {
            queued_requests: self.sched.lane_queue_len(lane) + self.sched.lane_running_len(lane),
            // reservation-map recomputation (exact: integer token
            // sums), so full-scan routing never trusts the counter
            kv_tokens: self.sched.lane_kv_recompute(lane),
            ..Default::default()
        };
        for (_, r) in pool
            .iter()
            .filter(|(_, r)| r.client == Some(self.id) && r.model == model)
        {
            l.input_tokens += r.prompt_tokens as f64;
            l.output_tokens += (r.output_tokens * r.branches) as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn served_models(&self) -> &[ModelId] {
        &self.models
    }

    fn stats(&self) -> ClientStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::{LLAMA3_70B, LLAMA3_8B};
    use crate::hardware::npu::H100;
    use crate::perfmodel::RooflinePerfModel;
    use crate::scheduler::{BatchingKind, Packing, SchedConfig};
    use crate::workload::request::Request;

    fn client(kind: BatchingKind) -> LlmClient {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        LlmClient::new(
            0,
            cluster.clone(),
            LlmSched::new(kind, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        )
    }

    fn req(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    /// drive the client alone until idle; returns (finish_time, outcomes)
    fn drain(c: &mut LlmClient, pool: &mut RequestPool) -> (SimTime, Vec<ReqId>) {
        let mut now = SimTime::ZERO;
        let mut done = Vec::new();
        for _ in 0..100_000 {
            match c.maybe_start_step(now, pool) {
                Some(fin) => {
                    now = fin;
                    done.extend(c.finish_step(now, pool).stage_done);
                }
                None => break,
            }
        }
        (now, done)
    }

    #[test]
    fn continuous_runs_request_to_completion() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (fin, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        let r = &pool[&1];
        assert!(r.prefill_complete() && r.decode_complete());
        assert!(r.first_token_time.unwrap() < r.last_token_time.unwrap());
        // prefill ~50ms + 49 decode steps ~8ms each → hundreds of ms
        assert!(fin.as_secs() > 0.1 && fin.as_secs() < 2.0, "fin={fin}");
        // prefill emitted the first token: decode steps = out - 1
        assert_eq!(c.stats().steps as usize, 1 + 49);
        assert!(c.stats().energy_joules > 0.0);
    }

    #[test]
    fn ttft_faster_than_static_for_late_arrival() {
        // static batching makes request 2 wait for request 1's decode
        let run = |kind| {
            let mut c = client(kind);
            let mut pool = RequestPool::new();
            pool.insert(1, req(1, 2000, 100));
            pool.insert(2, req(2, 500, 10));
            c.accept(SimTime::ZERO, 1, &mut pool);
            // drive one step, then inject request 2
            let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
            c.finish_step(fin, &mut pool);
            c.accept(fin, 2, &mut pool);
            let mut pool2 = pool;
            let (_, done) = drain(&mut c, &mut pool2);
            assert!(done.contains(&2));
            pool2[&2].ttft().unwrap()
        };
        let t_cont = run(BatchingKind::Continuous);
        let t_static = run(BatchingKind::Static);
        assert!(
            t_cont < t_static,
            "continuous ttft {t_cont} must beat static {t_static}"
        );
    }

    #[test]
    fn prefill_only_hands_off_after_prefill() {
        let mut c = client(BatchingKind::PrefillOnly);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        let r = &pool[&1];
        assert!(r.prefill_complete());
        assert_eq!(r.decoded, 1, "prefill emits the first token");
        assert_eq!(r.stage(), Stage::Prefill, "stage advance is the coordinator's job");
        // KV released on handoff
        assert_eq!(c.kv.used_tokens, 0.0);
    }

    #[test]
    fn decode_only_serves_transferred_request() {
        let mut c = client(BatchingKind::DecodeOnly);
        let mut pool = RequestPool::new();
        let mut r = req(1, 1000, 50);
        r.prefilled = 1000;
        r.decoded = 1;
        r.advance_stage(); // Prefill -> Decode
        pool.insert(1, r);
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        assert!(pool[&1].decode_complete());
        assert_eq!(c.stats().steps, 49);
    }

    #[test]
    fn cluster_roles_follow_batching_policy() {
        assert_eq!(client(BatchingKind::PrefillOnly).role(), ClusterRole::Prefill);
        assert_eq!(client(BatchingKind::DecodeOnly).role(), ClusterRole::Decode);
        assert_eq!(client(BatchingKind::Continuous).role(), ClusterRole::Colocated);
        assert_eq!(ClusterRole::Prefill.name(), "prefill");
        assert_eq!(ClusterRole::Colocated.name(), "colocated");
    }

    #[test]
    fn colocated_client_consumes_kv_migration_in_place() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(
            1,
            Request::new(
                1,
                "llama3-70b",
                SimTime::ZERO,
                vec![Stage::Prefill, Stage::KvMigration, Stage::Decode],
                1000,
                50,
            ),
        );
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        assert!(pool[&1].decode_complete());
        assert_eq!(pool[&1].stage(), Stage::Decode, "migration consumed in place");
        // exactly the Regular-pipeline step count: the hand-off is free
        assert_eq!(c.stats().steps as usize, 1 + 49);
    }

    #[test]
    fn can_serve_respects_role_and_model() {
        let c = client(BatchingKind::PrefillOnly);
        let m70 = ModelId::named("llama3-70b");
        let m7 = ModelId::named("mistral-7b");
        assert!(c.can_serve(&Stage::Prefill, m70));
        assert!(!c.can_serve(&Stage::Decode, m70));
        assert!(!c.can_serve(&Stage::Prefill, m7));
        assert!(!c.can_serve(&Stage::Rag(Default::default()), m70));
        assert!(!c.can_serve(&Stage::ModelRoute, m70));
        assert!(!c.can_serve(&Stage::KvMigration, m70), "never routed to a client");
        let d = client(BatchingKind::DecodeOnly);
        assert!(!d.can_serve(&Stage::Prefill, m70));
        assert!(d.can_serve(&Stage::Decode, m70));
    }

    #[test]
    fn load_reflects_owned_requests() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        pool.insert(2, req(2, 2000, 10)); // not accepted
        c.accept(SimTime::ZERO, 1, &mut pool);
        let l = c.load();
        assert_eq!(l.queued_requests, 1);
        assert_eq!(l.input_tokens, 1000.0);
        assert_eq!(l.tokens_left, 1050.0);
        assert_eq!(l, c.recompute_load(&pool));
    }

    #[test]
    fn incremental_load_tracks_step_progress() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let mut now = SimTime::ZERO;
        // after every step the O(1) counters must match the pool scan
        for _ in 0..100_000 {
            match c.maybe_start_step(now, &mut pool) {
                Some(fin) => {
                    now = fin;
                    c.finish_step(now, &mut pool);
                    assert_eq!(c.load(), c.recompute_load(&pool), "drift at {now}");
                }
                None => break,
            }
        }
        // drained: every counter returned to zero
        let l = c.load();
        assert_eq!(l.tokens_left, 0.0);
        assert_eq!(l.input_tokens, 0.0);
        assert_eq!(l.queued_requests, 0);
    }

    #[test]
    fn evict_unwinds_acceptance_even_mid_step() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        pool.insert(2, req(2, 800, 20));
        c.accept(SimTime::ZERO, 1, &mut pool);
        c.accept(SimTime::ZERO, 2, &mut pool);
        // start a step so both requests are planned + KV-reserved
        let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        c.evict(1, &mut pool);
        assert_eq!(pool[&1].client, None);
        assert_eq!(c.load(), c.recompute_load(&pool), "counters unwound");
        // the queued EngineStep still fires harmlessly for the survivor
        let out = c.finish_step(fin, &mut pool);
        assert!(!out.stage_done.contains(&1));
        assert_eq!(pool[&1].prefilled, 0, "no progress applied to the evictee");
        assert!(pool[&2].prefilled > 0);
        c.evict(2, &mut pool);
        let l = c.load();
        assert_eq!((l.queued_requests, l.tokens_left), (0, 0.0));
        assert_eq!(c.kv.used_tokens, 0.0, "all reservations released");
        // ids not resident here are a no-op
        c.evict(7, &mut pool);
        assert_eq!(c.load(), c.recompute_load(&pool));
    }

    #[test]
    fn multibranch_decode_counts_sequences() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        let mut r = req(1, 100, 10);
        r.branches = 8;
        pool.insert(1, r);
        c.accept(SimTime::ZERO, 1, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done, vec![1]);
        // 8 branches × 10 tokens
        assert_eq!(c.stats().decode_tokens, 80);
    }

    // ---- co-resident models ------------------------------------------------

    fn dual_client() -> LlmClient {
        let c70 = LlmCluster::new(LLAMA3_70B, H100, 8);
        let c8 = LlmCluster::new(LLAMA3_8B, H100, 8);
        LlmClient::with_models(
            0,
            vec![
                (c8.clone(), Box::new(RooflinePerfModel::new(c8)), BatchingKind::Continuous),
                (c70.clone(), Box::new(RooflinePerfModel::new(c70)), BatchingKind::Continuous),
            ],
            Packing::Fcfs,
            SchedConfig::default(),
        )
    }

    fn req_for(id: u64, model: &str, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            model,
            SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    #[test]
    fn dual_client_serves_both_models_to_completion() {
        let mut c = dual_client();
        let m8 = ModelId::named("llama3-8b");
        let m70 = ModelId::named("llama3-70b");
        assert!(c.can_serve(&Stage::Prefill, m8));
        assert!(c.can_serve(&Stage::Decode, m70));
        assert!(!c.can_serve(&Stage::Prefill, ModelId::named("mistral-7b")));
        assert_eq!(c.served_models(), &[m8, m70]);

        let mut pool = RequestPool::new();
        pool.insert(1, req_for(1, "llama3-8b", 500, 20));
        pool.insert(2, req_for(2, "llama3-70b", 500, 20));
        c.accept(SimTime::ZERO, 1, &mut pool);
        c.accept(SimTime::ZERO, 2, &mut pool);
        let (_, done) = drain(&mut c, &mut pool);
        assert_eq!(done.len(), 2);
        assert!(pool[&1].decode_complete() && pool[&2].decode_complete());
        // shared pool fully released on drain
        assert_eq!(c.kv.used_tokens, 0.0);
        let l = c.load();
        assert_eq!(l.queued_requests, 0);
        assert_eq!(l.tokens_left, 0.0);
    }

    #[test]
    fn shared_hbm_budget_is_smaller_than_either_single_model_pool() {
        let c = dual_client();
        let single70 = LlmCluster::new(LLAMA3_70B, H100, 8);
        // the dual client's pool is in *bytes*; compare in bytes
        let single_bytes =
            single70.kv_capacity_tokens() * LLAMA3_70B.kv_bytes_per_token();
        assert!(
            c.kv.capacity_tokens < single_bytes,
            "co-residency must pay the extra weights: {} vs {}",
            c.kv.capacity_tokens,
            single_bytes
        );
    }

    #[test]
    fn per_model_load_isolates_lanes() {
        let mut c = dual_client();
        let m8 = ModelId::named("llama3-8b");
        let m70 = ModelId::named("llama3-70b");
        let mut pool = RequestPool::new();
        pool.insert(1, req_for(1, "llama3-8b", 1000, 50));
        pool.insert(2, req_for(2, "llama3-70b", 3000, 70));
        c.accept(SimTime::ZERO, 1, &mut pool);
        c.accept(SimTime::ZERO, 2, &mut pool);
        let l8 = c.load_for_model(m8);
        let l70 = c.load_for_model(m70);
        assert_eq!(l8.queued_requests, 1);
        assert_eq!(l8.input_tokens, 1000.0);
        assert_eq!(l8.tokens_left, 1050.0);
        assert_eq!(l70.input_tokens, 3000.0);
        assert_eq!(l70.tokens_left, 3070.0);
        // per-model recompute agrees with the incremental counters
        assert_eq!(l8, c.recompute_load_for_model(m8, &pool));
        assert_eq!(l70, c.recompute_load_for_model(m70, &pool));
        assert_eq!(l8, c.full_scan_load_for_model(m8, &pool));
        // aggregate is the lane sum
        let l = c.load();
        assert_eq!(l.input_tokens, 4000.0);
        assert_eq!(l.queued_requests, 2);
    }

    #[test]
    fn single_model_per_model_load_equals_aggregate() {
        let mut c = client(BatchingKind::Continuous);
        let mut pool = RequestPool::new();
        pool.insert(1, req(1, 1000, 50));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let m = ModelId::named("llama3-70b");
        assert_eq!(c.load_for_model(m), c.load());
        assert_eq!(
            c.full_scan_load_for_model(m, &pool),
            c.full_scan_load(&pool)
        );
        // drive one step so KV is reserved, then re-check
        let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        c.finish_step(fin, &mut pool);
        assert_eq!(c.load_for_model(m), c.load());
    }
}
