//! RAG client (paper §III-C.2): batched embedding + IVF-PQ retrieval +
//! re-ranking ahead of LLM inference. Uses the `Batched` base scheduler
//! ("to maximize the efficiency").

use crate::client::{Client, ClientLoad, ClientStats, LoadAccount, StepOutcome};
use crate::rag::{RagEngine, RagTiming};
use crate::scheduler::simple::Batched;
use crate::scheduler::RequestPool;
use crate::sim::SimTime;
use crate::workload::request::{RagParams, ReqId, Stage};

pub struct RagClient {
    id: usize,
    pub engine: RagEngine,
    sched: Batched,
    group: usize,
    current: Option<Vec<ReqId>>,
    acct: LoadAccount,
    stats: ClientStats,
    /// accumulated per-stage timing for Fig 9's breakdown
    pub timing_total: RagTiming,
}

impl RagClient {
    pub fn new(id: usize, engine: RagEngine, max_batch: usize) -> RagClient {
        RagClient {
            id,
            engine,
            sched: Batched::new(max_batch),
            group: 0,
            current: None,
            acct: LoadAccount::default(),
            stats: ClientStats::default(),
            timing_total: RagTiming::default(),
        }
    }

    pub fn with_group(mut self, group: usize) -> RagClient {
        self.group = group;
        self
    }
}

impl Client for RagClient {
    fn id(&self) -> usize {
        self.id
    }

    fn kind_name(&self) -> &'static str {
        "rag"
    }

    fn group(&self) -> usize {
        self.group
    }

    fn can_serve(&self, stage: &Stage, _model: crate::model::ModelId) -> bool {
        matches!(stage, Stage::Rag(_))
    }

    fn accept(&mut self, _now: SimTime, id: ReqId, pool: &mut RequestPool) {
        pool.assign(id, self.id);
        self.acct.accept(&pool[&id]);
        self.sched.enqueue(id);
    }

    fn maybe_start_step(&mut self, now: SimTime, pool: &mut RequestPool) -> Option<SimTime> {
        if self.current.is_some() || self.sched.queue_len() == 0 {
            return None;
        }
        let batch = self.sched.take_batch();
        // all requests in one experiment share RagParams; take the first's
        let params = match pool[&batch[0]].stage() {
            Stage::Rag(p) => p,
            _ => RagParams::default(),
        };
        let timing = self.engine.batch_timing(batch.len(), &params);
        self.timing_total.embed_s += timing.embed_s;
        self.timing_total.retrieve_s += timing.retrieve_s;
        self.timing_total.rerank_s += timing.rerank_s;
        let dur = timing.total().max(1e-6);
        self.stats.steps += 1;
        self.stats.busy_seconds += dur;
        // embedding device energy: compute-bound encoder pass
        self.stats.energy_joules += crate::hardware::power::step_energy(
            &self.engine.embedder.npu,
            self.engine.embedder.tp,
            0.5,
            timing.embed_s,
        ) + crate::hardware::power::step_energy(
            &self.engine.index.device,
            1,
            0.2,
            timing.retrieve_s + timing.rerank_s,
        );
        self.current = Some(batch);
        Some(now + SimTime::from_secs(dur))
    }

    fn finish_step(&mut self, _now: SimTime, pool: &mut RequestPool) -> StepOutcome {
        let batch = self.current.take().expect("finish without step");
        for id in &batch {
            // the retrieved context is folded into the prompt by the
            // coordinator *after* the request leaves this client, so the
            // accept-time contribution is exactly what we release
            self.acct.release(&pool[id]);
            pool.unassign(*id);
        }
        self.stats.requests_served += batch.len() as u64;
        StepOutcome {
            stage_done: batch,
            recomputed: Vec::new(),
        }
    }

    fn evict(&mut self, id: ReqId, pool: &mut RequestPool) {
        if pool.get(&id).map(|r| r.client) != Some(Some(self.id)) {
            return;
        }
        // purge from queue or from the in-flight batch (whose EngineStep
        // then finishes without this request)
        if !self.sched.remove(id) {
            if let Some(batch) = &mut self.current {
                batch.retain(|r| *r != id);
            }
        }
        self.acct.release(&pool[&id]);
        pool.unassign(id);
    }

    fn load(&self) -> ClientLoad {
        ClientLoad {
            queued_requests: self.sched.queue_len(),
            input_tokens: self.acct.input_tokens,
            tokens_left: self.acct.tokens_left,
            ..Default::default()
        }
    }

    fn recompute_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len(),
            ..Default::default()
        };
        for r in pool.iter_client(self.id) {
            l.input_tokens += r.prompt_tokens as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn full_scan_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len(),
            ..Default::default()
        };
        for (_, r) in pool.iter().filter(|(_, r)| r.client == Some(self.id)) {
            l.input_tokens += r.prompt_tokens as f64;
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn stats(&self) -> ClientStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::E5_BASE;
    use crate::hardware::npu::GRACE_CPU;
    use crate::hardware::roofline::LlmCluster;
    use crate::rag::ivfpq::IvfPq;
    use crate::workload::request::Request;

    fn client() -> RagClient {
        RagClient::new(
            3,
            RagEngine::new(
                LlmCluster::new(E5_BASE, GRACE_CPU, 1),
                IvfPq::new(GRACE_CPU, Default::default()),
            ),
            0,
        )
    }

    fn rag_req(id: u64) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Rag(RagParams::default()), Stage::Prefill, Stage::Decode],
            256,
            64,
        )
    }

    #[test]
    fn batch_completes_together_and_returns_all() {
        let mut c = client();
        let mut pool = RequestPool::new();
        for id in 1..=5u64 {
            pool.insert(id, rag_req(id));
            c.accept(SimTime::ZERO, id, &mut pool);
        }
        let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        assert!(fin > SimTime::ZERO);
        // busy until the step completes
        assert!(c.maybe_start_step(SimTime::ZERO, &mut pool).is_none());
        let out = c.finish_step(fin, &mut pool);
        assert_eq!(out.stage_done.len(), 5);
        assert_eq!(c.stats().requests_served, 5);
        assert!(c.timing_total.retrieve_s > 0.0);
    }

    #[test]
    fn serves_only_rag_stage() {
        let c = client();
        // RAG clients are model-agnostic: any model's requests retrieve
        let any = crate::model::ModelId::named("mistral-7b");
        let m70 = crate::model::ModelId::named("llama3-70b");
        assert!(c.can_serve(&Stage::Rag(RagParams::default()), any));
        assert!(!c.can_serve(&Stage::Prefill, m70));
        assert!(!c.can_serve(&Stage::Postprocess, m70));
    }

    #[test]
    fn idle_when_empty() {
        let mut c = client();
        let mut pool = RequestPool::new();
        assert!(c.maybe_start_step(SimTime::ZERO, &mut pool).is_none());
    }
}
