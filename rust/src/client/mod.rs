//! Clients (paper §III-C): Scheduler + Hardware-Cluster pairs operating
//! at engine-step granularity. Four kinds: LLM inference (combined or
//! disaggregated prefill/decode role), RAG, KV-cache retrieval, and
//! pre/post-processing.

pub mod kv;
pub mod llm;
pub mod prepost;
pub mod rag;

use crate::model::ModelId;
use crate::scheduler::RequestPool;
use crate::sim::SimTime;
use crate::workload::request::{ReqId, Request, Stage};

pub use kv::KvRetrievalClient;
pub use llm::{ClusterRole, LlmClient};
pub use prepost::PrePostClient;
pub use rag::RagClient;

/// Load snapshot used by the router's load-balancing policies
/// (§III-B.1: input length / output length / KV size / tokens left).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClientLoad {
    pub queued_requests: usize,
    pub input_tokens: f64,
    pub output_tokens: f64,
    pub kv_tokens: f64,
    pub tokens_left: f64,
}

/// Incrementally maintained token counters behind a client's O(1)
/// [`Client::load`]. Every mutation of an owned request must be
/// mirrored here; all deltas are integer-valued, so the running sums
/// stay bit-identical to a fresh full-pool recomputation
/// ([`Client::recompute_load`]) — the invariant the coordinator checks
/// after every event in debug builds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadAccount {
    pub input_tokens: f64,
    pub output_tokens: f64,
    pub tokens_left: f64,
}

impl LoadAccount {
    /// A routed request entered this client (must reflect the request's
    /// state *at accept time*).
    pub fn accept(&mut self, r: &Request) {
        self.input_tokens += r.prompt_tokens as f64;
        self.output_tokens += (r.output_tokens * r.branches) as f64;
        self.tokens_left += r.work_left_tokens();
    }

    /// A request left this client (stage done / transferred out) —
    /// subtract its *current* remaining contribution.
    pub fn release(&mut self, r: &Request) {
        self.input_tokens -= r.prompt_tokens as f64;
        self.output_tokens -= (r.output_tokens * r.branches) as f64;
        self.tokens_left -= r.work_left_tokens();
    }

    /// `tokens` prompt tokens were prefilled this step.
    pub fn prefill_progress(&mut self, tokens: usize) {
        self.tokens_left -= tokens as f64;
    }

    /// One decode iteration completed for a request with `seqs` parallel
    /// branches.
    pub fn decode_progress(&mut self, seqs: usize) {
        self.tokens_left -= seqs as f64;
    }
}

/// What happened to requests when a step finished.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// requests whose *current stage* completed on this client — the
    /// coordinator advances + routes them
    pub stage_done: Vec<ReqId>,
    /// requests whose KV-retrieval missed (cache recompute) — metrics
    pub recomputed: Vec<ReqId>,
}

/// A serving client. Single-threaded simulation: the coordinator drives
/// `accept → maybe_start_step → (EngineStep event) → finish_step`.
pub trait Client {
    fn id(&self) -> usize;

    fn kind_name(&self) -> &'static str;

    /// Can this client execute `stage` for `model`? `ModelId` equality
    /// is an integer compare — this sits on the routing hot path.
    fn can_serve(&self, stage: &Stage, model: ModelId) -> bool;

    /// Physical placement group (local-disaggregation locality).
    fn group(&self) -> usize {
        0
    }

    /// Take ownership of a routed request (enqueue into the scheduler).
    /// Implementations must register residency via `RequestPool::assign`
    /// — never by writing the request's `client` field directly — so the
    /// pool's per-client resident index stays exact; `finish_step`
    /// releases it with `RequestPool::unassign` for every request it
    /// reports in `StepOutcome::stage_done`.
    fn accept(&mut self, now: SimTime, id: ReqId, pool: &mut RequestPool);

    /// If idle and work is available, start a step and return its
    /// completion time (the coordinator schedules the EngineStep event).
    fn maybe_start_step(&mut self, now: SimTime, pool: &mut RequestPool) -> Option<SimTime>;

    /// The in-flight step completed: apply its effects.
    fn finish_step(&mut self, now: SimTime, pool: &mut RequestPool) -> StepOutcome;

    /// Forcibly evict a resident request (fault recovery: a crash
    /// orphaned it, its deadline elapsed, or it failed terminally while
    /// assigned). Implementations must fully unwind the acceptance:
    /// purge the id from the scheduler queue *and* any in-flight step
    /// plan, release KV reservations and per-model `LoadAccount`
    /// counters, and end residency via `RequestPool::unassign` — the
    /// debug-mode load invariant runs right after, so a partial unwind
    /// is caught immediately. Must be a no-op side-effect-wise for ids
    /// not resident here. A queued `EngineStep` event for a step whose
    /// plan the eviction emptied must stay harmless.
    fn evict(&mut self, id: ReqId, pool: &mut RequestPool);

    /// Router-visible load: an O(1) read of incrementally maintained
    /// counters. Implementations must never iterate the request pool
    /// here — this sits on the per-stage-transition routing hot path.
    fn load(&self) -> ClientLoad;

    /// Recompute the load from the pool's per-client resident list
    /// (`RequestPool::iter_client` — O(resident on this client), not
    /// O(total pool)). Ground truth for the debug-mode drift invariant
    /// and the differential tests; must equal [`Client::load`] exactly
    /// after every coordinator event. The resident list itself is
    /// validated against every request's `client` field by
    /// `RequestPool::validate_residency` in the same invariant check.
    fn recompute_load(&self, pool: &RequestPool) -> ClientLoad;

    /// Recompute the load by scanning the *entire* pool and filtering
    /// on each request's `client` field — the pre-refactor
    /// O(total pool) computation, kept verbatim as the
    /// [`LoadMode::FullScan`](crate::coordinator::LoadMode) bench
    /// baseline (so `speedup_vs_full_scan` stays comparable across
    /// PRs) and as the strongest ground truth in the debug invariant.
    /// Must equal [`Client::recompute_load`] exactly.
    fn full_scan_load(&self, pool: &RequestPool) -> ClientLoad {
        self.recompute_load(pool)
    }

    // ---- per-model load (multi-model clients) -----------------------------
    //
    // The router ranks candidates by the load *for the request's model*:
    // on a co-resident client, a drained small-model lane must look idle
    // even while the big-model lane is saturated. Single-model clients
    // keep the default — their aggregate IS the per-model load — so the
    // degenerate path stays bit-identical to the pre-multi-model router.

    /// O(1) read of the per-(client, model) counters. Default: the
    /// aggregate [`Client::load`] (exact for single-model clients).
    fn load_for_model(&self, model: ModelId) -> ClientLoad {
        let _ = model;
        self.load()
    }

    /// Per-model ground truth from the resident index — the per-model
    /// drift invariant compares this against [`Client::load_for_model`]
    /// after every event (debug builds).
    fn recompute_load_for_model(&self, model: ModelId, pool: &RequestPool) -> ClientLoad {
        let _ = model;
        self.recompute_load(pool)
    }

    /// Per-model whole-pool scan, mirroring [`Client::full_scan_load`]
    /// for the `LoadMode::FullScan` bench baseline — routing decisions
    /// must be identical across load modes, multi-model included.
    fn full_scan_load_for_model(&self, model: ModelId, pool: &RequestPool) -> ClientLoad {
        let _ = model;
        self.full_scan_load(pool)
    }

    /// Models this client hosts (empty for model-agnostic clients).
    /// Drives the per-model half of the coordinator's load invariant.
    fn served_models(&self) -> &[ModelId] {
        &[]
    }

    /// Busy-time and energy accounting (joules, busy-seconds, steps).
    fn stats(&self) -> ClientStats;
}

/// Operational statistics every client tracks (§III-F.2 client-level
/// metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClientStats {
    pub steps: u64,
    pub busy_seconds: f64,
    pub energy_joules: f64,
    pub requests_served: u64,
    /// prefill/decode token counters (LLM clients)
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
}
