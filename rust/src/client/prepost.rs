//! Pre/post-processing client (paper §III-C.1 / §III-E.4): tokenization
//! and padding on the way in; detokenization plus guard-model filtering
//! (a ~2B-parameter forward pass) on the way out. Uses the `Sequential`
//! base scheduler — "tasks without reuse possibility".

use crate::client::{Client, ClientLoad, ClientStats, StepOutcome};
use crate::hardware::roofline::LlmCluster;
use crate::scheduler::simple::Sequential;
use crate::scheduler::RequestPool;
use crate::sim::SimTime;
use crate::workload::request::{ReqId, Stage};

/// Per-token tokenize/detokenize cost ("runtime proportional to number
/// of generated tokens").
const TOKENIZE_S_PER_TOKEN: f64 = 2e-7;

pub struct PrePostClient {
    id: usize,
    /// guard model (~2B) running toxicity/bias filtering on outputs
    pub guard: Option<LlmCluster>,
    sched: Sequential,
    group: usize,
    current: Option<Vec<ReqId>>,
    stats: ClientStats,
}

impl PrePostClient {
    pub fn new(id: usize, cores: usize, guard: Option<LlmCluster>) -> PrePostClient {
        PrePostClient {
            id,
            guard,
            sched: Sequential::new(cores),
            group: 0,
            current: None,
            stats: ClientStats::default(),
        }
    }

    fn task_time(&self, pool: &RequestPool, id: ReqId) -> f64 {
        let r = &pool[&id];
        match r.stage() {
            Stage::Preprocess => r.prompt_tokens as f64 * TOKENIZE_S_PER_TOKEN + 50e-6,
            Stage::Postprocess => {
                let generated = (r.decoded * r.branches) as f64;
                let detok = generated * TOKENIZE_S_PER_TOKEN;
                // guard model scores the generated text (prefill pass)
                let filter = self
                    .guard
                    .as_ref()
                    .map(|g| g.embed_time(generated.max(1.0)))
                    .unwrap_or(0.0);
                detok + filter + 50e-6
            }
            _ => 1e-6,
        }
    }
}

impl Client for PrePostClient {
    fn id(&self) -> usize {
        self.id
    }

    fn kind_name(&self) -> &'static str {
        "prepost"
    }

    fn group(&self) -> usize {
        self.group
    }

    fn can_serve(&self, stage: &Stage, _model: crate::model::ModelId) -> bool {
        matches!(stage, Stage::Preprocess | Stage::Postprocess)
    }

    fn accept(&mut self, _now: SimTime, id: ReqId, pool: &mut RequestPool) {
        pool.assign(id, self.id);
        self.sched.enqueue(id);
    }

    fn maybe_start_step(&mut self, now: SimTime, pool: &mut RequestPool) -> Option<SimTime> {
        if self.current.is_some() || self.sched.queue_len() == 0 {
            return None;
        }
        let wave = self.sched.take_wave();
        // cores run in parallel: the wave finishes at the slowest task
        let dur = wave
            .iter()
            .map(|id| self.task_time(pool, *id))
            .fold(0.0f64, f64::max)
            .max(1e-6);
        self.stats.steps += 1;
        self.stats.busy_seconds += dur;
        if let Some(g) = &self.guard {
            self.stats.energy_joules +=
                crate::hardware::power::step_energy(&g.npu, g.tp, 0.3, dur);
        }
        self.current = Some(wave);
        Some(now + SimTime::from_secs(dur))
    }

    fn finish_step(&mut self, _now: SimTime, pool: &mut RequestPool) -> StepOutcome {
        let wave = self.current.take().expect("finish without step");
        self.stats.requests_served += wave.len() as u64;
        for id in &wave {
            pool.unassign(*id);
        }
        StepOutcome {
            stage_done: wave,
            recomputed: Vec::new(),
        }
    }

    fn evict(&mut self, id: ReqId, pool: &mut RequestPool) {
        if pool.get(&id).map(|r| r.client) != Some(Some(self.id)) {
            return;
        }
        // purge from queue or from the in-flight wave (whose EngineStep
        // then finishes without this request); no LoadAccount here
        if !self.sched.remove(id) {
            if let Some(wave) = &mut self.current {
                wave.retain(|r| *r != id);
            }
        }
        pool.unassign(id);
    }

    fn load(&self) -> ClientLoad {
        ClientLoad {
            queued_requests: self.sched.queue_len(),
            ..Default::default()
        }
    }

    fn recompute_load(&self, _pool: &RequestPool) -> ClientLoad {
        // queue length is the only load signal; it is O(1) already
        self.load()
    }

    fn stats(&self) -> ClientStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::GUARD_2B;
    use crate::hardware::npu::A100;
    use crate::workload::request::Request;

    fn guarded_req(id: u64) -> Request {
        let mut r = Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Preprocess, Stage::Prefill, Stage::Decode, Stage::Postprocess],
            1000,
            200,
        );
        r.decoded = 200;
        r
    }

    #[test]
    fn preprocess_fast_postprocess_guarded() {
        let mut c = PrePostClient::new(
            9,
            4,
            Some(LlmCluster::new(GUARD_2B, A100, 1)),
        );
        let mut pool = RequestPool::new();
        pool.insert(1, guarded_req(1));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let fin_pre = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        assert!(fin_pre.as_secs() < 2e-3, "preprocess is sub-ms: {fin_pre}");
        c.finish_step(fin_pre, &mut pool);

        // move to postprocess stage (finish_step released residency)
        pool.get_mut(&1).unwrap().stage_idx = 3;
        c.accept(fin_pre, 1, &mut pool);
        let fin_post = c.maybe_start_step(fin_pre, &mut pool).unwrap();
        // guard-2B forward over 200 tokens dominates
        assert!((fin_post - fin_pre).as_secs() > 1e-3);
        let out = c.finish_step(fin_post, &mut pool);
        assert_eq!(out.stage_done, vec![1]);
    }

    #[test]
    fn waves_respect_core_count() {
        let mut c = PrePostClient::new(9, 2, None);
        let mut pool = RequestPool::new();
        for id in 1..=5u64 {
            pool.insert(id, guarded_req(id));
            c.accept(SimTime::ZERO, id, &mut pool);
        }
        let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        let out = c.finish_step(fin, &mut pool);
        assert_eq!(out.stage_done.len(), 2, "2 cores → wave of 2");
    }
}
