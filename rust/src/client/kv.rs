//! KV-cache retrieval client (paper §III-C.3): fetches a request's past
//! context KV from the multi-level memory hierarchy (prefix caching /
//! past-memory retrieval). Misses fall back to recompute — the cached
//! tokens join the prompt and get prefilled downstream.

use crate::client::{Client, ClientLoad, ClientStats, LoadAccount, StepOutcome};
use crate::memory::hierarchy::Retrieval;
use crate::memory::storage::KvStore;
use crate::scheduler::simple::Batched;
use crate::scheduler::RequestPool;
use crate::sim::SimTime;
use crate::util::rng::Pcg;
use crate::workload::request::{ReqId, Stage};

pub struct KvRetrievalClient {
    id: usize,
    pub store: KvStore,
    /// KV bytes per token of the *served model* (what's being fetched)
    pub kv_bytes_per_token: f64,
    sched: Batched,
    group: usize,
    rng: Pcg,
    current: Option<(Vec<(ReqId, bool)>, SimTime)>, // (req, hit), finish
    acct: LoadAccount,
    stats: ClientStats,
    pub hits: u64,
    pub recomputes: u64,
}

impl KvRetrievalClient {
    pub fn new(
        id: usize,
        store: KvStore,
        kv_bytes_per_token: f64,
        max_batch: usize,
        seed: u64,
    ) -> KvRetrievalClient {
        KvRetrievalClient {
            id,
            store,
            kv_bytes_per_token,
            sched: Batched::new(max_batch),
            group: 0,
            rng: Pcg::new(seed ^ 0x4b56),
            current: None,
            acct: LoadAccount::default(),
            stats: ClientStats::default(),
            hits: 0,
            recomputes: 0,
        }
    }

    pub fn with_group(mut self, group: usize) -> KvRetrievalClient {
        self.group = group;
        self
    }
}

impl Client for KvRetrievalClient {
    fn id(&self) -> usize {
        self.id
    }

    fn kind_name(&self) -> &'static str {
        "kv-retrieval"
    }

    fn group(&self) -> usize {
        self.group
    }

    fn can_serve(&self, stage: &Stage, _model: crate::model::ModelId) -> bool {
        matches!(stage, Stage::KvRetrieval(_))
    }

    fn accept(&mut self, _now: SimTime, id: ReqId, pool: &mut RequestPool) {
        pool.assign(id, self.id);
        self.acct.accept(&pool[&id]);
        self.sched.enqueue(id);
    }

    fn maybe_start_step(&mut self, now: SimTime, pool: &mut RequestPool) -> Option<SimTime> {
        if self.current.is_some() || self.sched.queue_len() == 0 {
            return None;
        }
        let batch = self.sched.take_batch();
        let mut results = Vec::with_capacity(batch.len());
        let mut finish = now;
        for id in batch {
            let cached = match pool[&id].stage() {
                Stage::KvRetrieval(p) => p.cached_tokens,
                _ => 0,
            };
            let bytes = cached as f64 * self.kv_bytes_per_token;
            match self.store.retrieve(now, bytes, &mut self.rng) {
                Retrieval::Hit { latency, .. } => {
                    self.hits += 1;
                    finish = finish.max(now + SimTime::from_secs(latency));
                    results.push((id, true));
                }
                Retrieval::Recompute => {
                    // lookup miss costs only the hierarchy walk; the
                    // recompute itself happens at the prefill client
                    self.recomputes += 1;
                    results.push((id, false));
                }
            }
        }
        // one clamped completion time drives both the EngineStep event
        // and the busy-time accounting, so per-client utilization sums
        // match the event timeline exactly
        let end = finish.max(now + SimTime::from_nanos(1000));
        self.stats.steps += 1;
        self.stats.busy_seconds += (end - now).as_secs();
        self.current = Some((results, end));
        Some(end)
    }

    fn finish_step(&mut self, _now: SimTime, pool: &mut RequestPool) -> StepOutcome {
        let (results, _) = self.current.take().expect("finish without step");
        let mut out = StepOutcome::default();
        for (id, hit) in results {
            let r = pool.get_mut(&id).expect("kv req");
            // release the load contribution *before* a miss folds the
            // cached context into the prompt — the request leaves this
            // client in this very event, so the mutation belongs to the
            // downstream prefill client's accounting
            self.acct.release(r);
            if let Stage::KvRetrieval(p) = r.stage() {
                r.apply_kv_retrieval(p.cached_tokens, hit);
            }
            pool.unassign(id);
            if !hit {
                out.recomputed.push(id);
            }
            out.stage_done.push(id);
            self.stats.requests_served += 1;
        }
        out
    }

    fn evict(&mut self, id: ReqId, pool: &mut RequestPool) {
        if pool.get(&id).map(|r| r.client) != Some(Some(self.id)) {
            return;
        }
        // purge from queue or from the in-flight batch (whose EngineStep
        // then finishes without this request)
        if !self.sched.remove(id) {
            if let Some((results, _)) = &mut self.current {
                results.retain(|(r, _)| *r != id);
            }
        }
        self.acct.release(&pool[&id]);
        pool.unassign(id);
    }

    fn load(&self) -> ClientLoad {
        ClientLoad {
            queued_requests: self.sched.queue_len(),
            tokens_left: self.acct.tokens_left,
            ..Default::default()
        }
    }

    fn recompute_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len(),
            ..Default::default()
        };
        for r in pool.iter_client(self.id) {
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn full_scan_load(&self, pool: &RequestPool) -> ClientLoad {
        let mut l = ClientLoad {
            queued_requests: self.sched.queue_len(),
            ..Default::default()
        };
        for (_, r) in pool.iter().filter(|(_, r)| r.client == Some(self.id)) {
            l.tokens_left += r.work_left_tokens();
        }
        l
    }

    fn stats(&self) -> ClientStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::storage::{KvScenario, StorageConfig};
    use crate::workload::request::{KvParams, Request};

    fn kv_req(id: u64, cached: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![
                Stage::KvRetrieval(KvParams { cached_tokens: cached }),
                Stage::Prefill,
                Stage::Decode,
            ],
            500,
            64,
        )
    }

    fn client(cfg: StorageConfig) -> KvRetrievalClient {
        KvRetrievalClient::new(
            7,
            KvStore::new(cfg, KvScenario::Private),
            327_680.0, // llama-70b KV bytes/token
            0,
            42,
        )
    }

    #[test]
    fn hits_credit_past_tokens() {
        let mut c = client(StorageConfig::PlatformShared); // 95% hit
        let mut pool = RequestPool::new();
        for id in 1..=20u64 {
            pool.insert(id, kv_req(id, 3000));
            c.accept(SimTime::ZERO, id, &mut pool);
        }
        let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        let out = c.finish_step(fin, &mut pool);
        assert_eq!(out.stage_done.len(), 20);
        assert!(c.hits >= 15, "hits={}", c.hits);
        let hit_req = out
            .stage_done
            .iter()
            .find(|id| !out.recomputed.contains(id))
            .unwrap();
        assert_eq!(pool[hit_req].past_tokens, 3000);
        assert_eq!(pool[hit_req].prompt_tokens, 500);
    }

    #[test]
    fn recompute_store_pushes_context_into_prompt() {
        let mut c = client(StorageConfig::Recompute);
        let mut pool = RequestPool::new();
        pool.insert(1, kv_req(1, 3000));
        c.accept(SimTime::ZERO, 1, &mut pool);
        let fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
        let out = c.finish_step(fin, &mut pool);
        assert_eq!(out.recomputed, vec![1]);
        assert_eq!(pool[&1].past_tokens, 0);
        assert_eq!(pool[&1].prompt_tokens, 3500);
    }

    #[test]
    fn busy_seconds_match_event_timeline() {
        // regression: busy time must be derived from the same clamped
        // completion instant the EngineStep event is scheduled at
        let mut c = client(StorageConfig::PlatformShared);
        let mut pool = RequestPool::new();
        let mut now = SimTime::ZERO;
        let mut timeline = 0.0;
        for id in 1..=20u64 {
            pool.insert(id, kv_req(id, 500 * id as usize));
            c.accept(now, id, &mut pool);
            let fin = c.maybe_start_step(now, &mut pool).unwrap();
            c.finish_step(fin, &mut pool);
            timeline += (fin - now).as_secs();
            now = fin;
        }
        assert!(
            (c.stats().busy_seconds - timeline).abs() < 1e-12,
            "busy {} vs timeline {}",
            c.stats().busy_seconds,
            timeline
        );
    }

    #[test]
    fn retrieval_time_scales_with_cache_size() {
        // 24K-token retrieval takes much longer than 4K on the rack tier
        let run = |tokens: usize| {
            let mut c = client(StorageConfig::RackShared);
            let mut pool = RequestPool::new();
            pool.insert(1, kv_req(1, tokens));
            c.accept(SimTime::ZERO, 1, &mut pool);
            let mut fin = SimTime::ZERO;
            // retry until a hit (98% hit rate)
            for _ in 0..10 {
                fin = c.maybe_start_step(SimTime::ZERO, &mut pool).unwrap();
                let out = c.finish_step(fin, &mut pool);
                if out.recomputed.is_empty() {
                    break;
                }
                // finish_step already released residency; just re-accept
                c.accept(SimTime::ZERO, 1, &mut pool);
            }
            fin.as_secs()
        };
        let t4k = run(4096);
        let t24k = run(24576);
        assert!(t24k > 4.0 * t4k, "t4k={t4k} t24k={t24k}");
    }
}
