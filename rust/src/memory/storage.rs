//! Remote KV-cache storage architectures (paper §V-B, Fig 14):
//!
//!   (A) dedicated per-client cache   — LPDDR, 1 TB @ 128 GB/s
//!   (B) platform-level shared cache  — 4 TB @ 32 GB/s, 4 clients
//!   (C) rack-level shared cache      — 32 TB @ 2 GB/s, 32 clients
//!   (C+DCN) rack cache + data-center-network fallback to a replica
//!   (Recompute) no cache: past context recomputed by prefill
//!
//! Shared tiers are contended: concurrent retrievals from the sharing
//! clients serialize on the tier's `Link`. Hit rates differ between the
//! private-KV and shared-KV usage scenarios (capacity vs working set).

use super::hierarchy::{CacheLevel, Hierarchy, Retrieval};
use crate::network::link::{Link, LinkSpec};
use crate::sim::SimTime;
use crate::util::rng::Pcg;

/// The five Fig 15 design points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StorageConfig {
    DedicatedPerClient,
    PlatformShared,
    RackShared,
    RackSharedWithDcn,
    Recompute,
}

impl StorageConfig {
    pub fn name(&self) -> &'static str {
        match self {
            StorageConfig::DedicatedPerClient => "A:dedicated",
            StorageConfig::PlatformShared => "B:platform",
            StorageConfig::RackShared => "C:rack",
            StorageConfig::RackSharedWithDcn => "C+DCN",
            StorageConfig::Recompute => "recompute",
        }
    }

    pub fn all() -> [StorageConfig; 5] {
        [
            StorageConfig::DedicatedPerClient,
            StorageConfig::PlatformShared,
            StorageConfig::RackShared,
            StorageConfig::RackSharedWithDcn,
            StorageConfig::Recompute,
        ]
    }
}

/// Usage scenario (paper §V-B "Target Usecase").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KvScenario {
    /// per-user chat history: working set fits near the client
    Private,
    /// enterprise corpus of O(10^10) tokens with hot spots: only the
    /// big shared tiers achieve high hit rates
    Shared,
}

/// Hit rates per (config, scenario). Private contexts are small → the
/// 1 TB dedicated tier already hits ~90%; a 10^10-token shared corpus
/// (≈ 3 PB of KV at 320 KB/token) overwhelms everything below the rack
/// tier, whose hot-spot hit rate dominates.
fn hit_rates(cfg: StorageConfig, scenario: KvScenario) -> Vec<(CacheLevel, usize)> {
    // (level, sharing-degree) — sharing-degree scales contention.
    let ded = |hit: f64| CacheLevel {
        name: "dedicated-lpddr",
        capacity: 1e12,
        lookup_lat: 10e-6,
        bw: 128e9,
        hit_rate: hit,
    };
    let plat = |hit: f64| CacheLevel {
        name: "platform-shared",
        capacity: 4e12,
        lookup_lat: 100e-6,
        bw: 32e9,
        hit_rate: hit,
    };
    let rack = |hit: f64| CacheLevel {
        name: "rack-shared",
        capacity: 32e12,
        lookup_lat: 1e-3,
        bw: 2e9,
        hit_rate: hit,
    };
    let dcn = |hit: f64| CacheLevel {
        name: "dcn-replica",
        capacity: 128e12,
        lookup_lat: 20e-3,
        bw: 128e9,
        hit_rate: hit,
    };
    match (cfg, scenario) {
        (StorageConfig::DedicatedPerClient, KvScenario::Private) => vec![(ded(0.90), 1)],
        // a per-client slice of a petabyte corpus barely ever hits
        (StorageConfig::DedicatedPerClient, KvScenario::Shared) => vec![(ded(0.15), 1)],
        (StorageConfig::PlatformShared, KvScenario::Private) => vec![(plat(0.95), 4)],
        (StorageConfig::PlatformShared, KvScenario::Shared) => vec![(plat(0.40), 4)],
        (StorageConfig::RackShared, KvScenario::Private) => vec![(rack(0.98), 32)],
        (StorageConfig::RackShared, KvScenario::Shared) => vec![(rack(0.85), 32)],
        (StorageConfig::RackSharedWithDcn, KvScenario::Private) => {
            vec![(rack(0.98), 32), (dcn(0.99), 128)]
        }
        (StorageConfig::RackSharedWithDcn, KvScenario::Shared) => {
            vec![(rack(0.85), 32), (dcn(0.97), 128)]
        }
        (StorageConfig::Recompute, _) => vec![],
    }
}

/// A stateful storage tier backing a set of KV-retrieval clients.
pub struct KvStore {
    pub config: StorageConfig,
    pub scenario: KvScenario,
    pub hierarchy: Hierarchy,
    /// contended service links, one per level. The tier bandwidths in
    /// Fig 14 are *per accessing client*; a store handling `ports`
    /// clients' connections queues on the aggregate (ports × bw) while
    /// each individual pull still streams at the per-connection rate.
    links: Vec<Link>,
    ports: usize,
    pub recomputes: u64,
    pub hits: u64,
}

impl KvStore {
    pub fn new(config: StorageConfig, scenario: KvScenario) -> KvStore {
        KvStore::with_ports(config, scenario, 1)
    }

    /// `ports` = number of client connections this store instance
    /// aggregates (each at the tier's per-client bandwidth).
    pub fn with_ports(config: StorageConfig, scenario: KvScenario, ports: usize) -> KvStore {
        let ports = ports.max(1);
        let spec = hit_rates(config, scenario);
        let hierarchy = Hierarchy::new(spec.iter().map(|(l, _)| *l).collect());
        let links = spec
            .iter()
            .map(|(l, _sharing)| {
                Link::new(LinkSpec {
                    bw: l.bw * ports as f64,
                    lat: l.lookup_lat,
                })
            })
            .collect();
        KvStore {
            config,
            scenario,
            hierarchy,
            links,
            ports,
            recomputes: 0,
            hits: 0,
        }
    }

    /// Retrieve `kv_bytes` at `now`. Returns when the data is available,
    /// or `Recompute` (caller prices a prefill of the cached context).
    /// Contention: the chosen level's aggregate link serializes beyond
    /// `ports` concurrent pulls; each pull floors at the per-connection
    /// streaming time.
    pub fn retrieve(&mut self, now: SimTime, kv_bytes: f64, rng: &mut Pcg) -> Retrieval {
        match self.hierarchy.sample(kv_bytes, rng) {
            Retrieval::Hit { level, .. } => {
                self.hits += 1;
                let fin = self.links[level].transfer(now, kv_bytes);
                // per-connection floor: a single pull cannot exceed its
                // own 1-port bandwidth even on an idle aggregate link
                let floor = self.hierarchy.levels[level].retrieval_time(kv_bytes);
                Retrieval::Hit {
                    level,
                    latency: (fin - now).as_secs().max(floor),
                }
            }
            Retrieval::Recompute => {
                self.recomputes += 1;
                Retrieval::Recompute
            }
        }
    }

    /// Expected retrieval latency (Eq. 1) for reporting.
    pub fn expected(&self, kv_bytes: f64, recompute_s: f64) -> f64 {
        self.hierarchy.expected_with_recompute(kv_bytes, recompute_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn private_kv_prefers_platform_tier_at_4k() {
        // 4K tokens of llama3-70b KV = 4096 * 320KiB ≈ 1.34 GB
        let kv = 4096.0 * 327_680.0;
        let recompute = 0.15; // ~prefill of 4K tokens
        let a = KvStore::new(StorageConfig::DedicatedPerClient, KvScenario::Private);
        let b = KvStore::new(StorageConfig::PlatformShared, KvScenario::Private);
        let c = KvStore::new(StorageConfig::RackShared, KvScenario::Private);
        let (ea, eb, ec) = (
            a.expected(kv, recompute),
            b.expected(kv, recompute),
            c.expected(kv, recompute),
        );
        // the rack tier's 2 GB/s makes big pulls painfully slow
        assert!(ec > eb, "rack {ec} should lose to platform {eb}");
        // dedicated wins on raw speed but pays its lower hit rate
        assert!(ea < ec, "dedicated {ea} beats rack {ec} for private");
    }

    #[test]
    fn shared_kv_prefers_rack_tier() {
        let kv = 4096.0 * 327_680.0;
        // On a loaded cluster a recompute is not just the raw prefill:
        // it displaces foreground serving capacity and queues (the Fig 15
        // simulation captures this dynamically). Static comparison uses
        // the effective loaded-system cost.
        let recompute_loaded = 2.0;
        let a = KvStore::new(StorageConfig::DedicatedPerClient, KvScenario::Shared);
        let c = KvStore::new(StorageConfig::RackShared, KvScenario::Shared);
        assert!(
            c.expected(kv, recompute_loaded) < a.expected(kv, recompute_loaded),
            "shared corpus: rack cache must beat tiny dedicated caches ({} vs {})",
            c.expected(kv, recompute_loaded),
            a.expected(kv, recompute_loaded)
        );
    }

    #[test]
    fn recompute_competitive_short_prohibitive_long() {
        // paper: recompute viable at 4K tokens, prohibitive at 24K
        let c_short = KvStore::new(StorageConfig::RackShared, KvScenario::Private)
            .expected(4096.0 * 327_680.0, 0.15);
        let rec_short = 0.15;
        let c_long = KvStore::new(StorageConfig::RackShared, KvScenario::Private)
            .expected(24576.0 * 327_680.0, 1.6);
        let rec_long = 1.6;
        // short: recompute within ~2x of retrieval (competitive)
        assert!(rec_short < 2.0 * c_short + 0.2);
        // long: direct retrieval from rack cache strictly better than 24K prefill
        assert!(c_long < rec_long * 4.0);
    }

    #[test]
    fn contention_serializes_concurrent_pulls() {
        let mut s = KvStore::new(StorageConfig::PlatformShared, KvScenario::Private);
        let mut rng = Pcg::new(3);
        let kv = 1e9;
        let mut latencies = Vec::new();
        for _ in 0..8 {
            if let Retrieval::Hit { latency, .. } = s.retrieve(SimTime::ZERO, kv, &mut rng) {
                latencies.push(latency);
            }
        }
        assert!(latencies.len() >= 6, "platform hit rate is 0.95");
        let first = latencies[0];
        let last = *latencies.last().unwrap();
        assert!(last > 2.0 * first, "queueing must build: {first} .. {last}");
    }

    #[test]
    fn recompute_config_always_recomputes() {
        let mut s = KvStore::new(StorageConfig::Recompute, KvScenario::Private);
        let mut rng = Pcg::new(4);
        for _ in 0..10 {
            assert_eq!(s.retrieve(SimTime::ZERO, 1e9, &mut rng), Retrieval::Recompute);
        }
        assert_eq!(s.recomputes, 10);
    }

    #[test]
    fn dcn_fallback_raises_tail_not_floor() {
        let mut s = KvStore::new(StorageConfig::RackSharedWithDcn, KvScenario::Shared);
        let mut rng = Pcg::new(5);
        let mut lat = Vec::new();
        // small caches (1 MB), lightly-loaded ascending arrivals: the
        // rack tier serves in ~1.5 ms; the ~15% DCN fallbacks pay the
        // 20 ms link latency → heavy tail (the paper's "link latency
        // renders this approach less attractive")
        for i in 0..2000 {
            if let Retrieval::Hit { latency, .. } =
                s.retrieve(SimTime::from_secs(i as f64 * 0.05), 1e6, &mut rng)
            {
                lat.push(latency);
            }
        }
        let s50 = crate::util::stats::percentile(&lat, 50.0);
        let s99 = crate::util::stats::percentile(&lat, 99.0);
        assert!(s50 < 0.01, "rack tier should serve the median: {s50}");
        assert!(s99 > 0.02, "DCN fallback must show in the tail: {s99}");
    }
}
