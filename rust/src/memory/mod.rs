//! Memory modeling: the multi-level KV-cache hierarchy with Eq. 1
//! expected-latency semantics, per-client KV occupancy management, and
//! the Fig 14 remote-storage design points.

pub mod hierarchy;
pub mod storage;

pub use hierarchy::{CacheLevel, Hierarchy, KvManager, Retrieval};
pub use storage::{KvScenario, KvStore, StorageConfig};
