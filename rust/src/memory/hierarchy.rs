//! Multi-level KV-cache memory hierarchy (paper §III-E.3, Eq. 1):
//!
//!   f(KV, Cₙ) = Hitₙ · (T_lookupₙ + Size_KV / BWₙ)
//!             + (1 − Hitₙ) · f(KV, Cₙ₊₁)
//!
//! "unlike CPU caches where a miss leads to DRAM access, a miss in prefix
//! caching may result in the need to recompute the entire context" — the
//! terminal miss outcome is therefore `MissOutcome::Recompute`, priced by
//! the caller as a prefill of the cached tokens.

use crate::sim::SimTime;
use crate::util::rng::Pcg;

/// One level of the cache hierarchy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevel {
    pub name: &'static str,
    /// capacity in bytes (metrics/validation only — hit_rate abstracts it)
    pub capacity: f64,
    /// lookup latency, s ("ranging from nanoseconds to milliseconds")
    pub lookup_lat: f64,
    /// retrieval bandwidth, B/s
    pub bw: f64,
    /// probability the requested KV resides at this level
    pub hit_rate: f64,
}

impl CacheLevel {
    pub fn retrieval_time(&self, kv_bytes: f64) -> f64 {
        self.lookup_lat + kv_bytes / self.bw
    }
}

/// Named staging-tier presets for cluster-level KV migration
/// (docs/disaggregation.md): where a migrated KV cache lands on the
/// decode side before generation resumes. Hit rates are the probability
/// the tier has room (misses spill to the next tier); a stack like
/// `["hbm", "dram", "nvme"]` is the HBM → DRAM → NVMe waterfall of the
/// paper's storage discussion, and `scenarios/remote_kv.json` becomes
/// one point of this family.
pub const TIER_HBM: CacheLevel = CacheLevel {
    name: "hbm",
    capacity: 1e12,
    lookup_lat: 1e-6,
    bw: 2e12,
    hit_rate: 0.6,
};
pub const TIER_CXL: CacheLevel = CacheLevel {
    name: "cxl",
    capacity: 16e12,
    lookup_lat: 1e-6,
    bw: 64e9,
    hit_rate: 0.95,
};
pub const TIER_DRAM: CacheLevel = CacheLevel {
    name: "dram",
    capacity: 4e12,
    lookup_lat: 10e-6,
    bw: 200e9,
    hit_rate: 0.9,
};
pub const TIER_NVME: CacheLevel = CacheLevel {
    name: "nvme",
    capacity: 64e12,
    lookup_lat: 100e-6,
    bw: 12e9,
    hit_rate: 0.99,
};

/// Resolve a staging-tier preset by name (the `migration.pool` config
/// key). Unknown names are `None` — the config layer turns that into a
/// parse error, so dangling tier refs fail at `hermes scenario check`
/// time like dangling model refs do.
pub fn tier_by_name(name: &str) -> Option<CacheLevel> {
    match name {
        "hbm" => Some(TIER_HBM),
        "cxl" => Some(TIER_CXL),
        "dram" => Some(TIER_DRAM),
        "nvme" => Some(TIER_NVME),
        _ => None,
    }
}

/// What happened on a sampled retrieval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Retrieval {
    /// served by hierarchy level `level` after `latency` seconds
    Hit { level: usize, latency: f64 },
    /// missed everywhere: context must be recomputed via prefill
    Recompute,
}

/// A stack of cache levels, nearest first.
#[derive(Debug, Clone, Default)]
pub struct Hierarchy {
    pub levels: Vec<CacheLevel>,
}

impl Hierarchy {
    pub fn new(levels: Vec<CacheLevel>) -> Hierarchy {
        let mut any_hit = levels.is_empty();
        for l in &levels {
            assert!((0.0..=1.0).contains(&l.hit_rate), "bad hit rate {l:?}");
            assert!(l.bw > 0.0, "bad bandwidth {l:?}");
            any_hit |= l.hit_rate > 0.0;
        }
        // a stack whose rates are all exactly 0 never terminates in a
        // hit — retrieval silently degenerates to certain recompute.
        // The empty hierarchy stays legal: it *states* recompute-only.
        assert!(
            any_hit,
            "hierarchy never hits (every level's hit rate is 0); \
             use an empty hierarchy for recompute-only"
        );
        Hierarchy { levels }
    }

    /// Eq. 1 closed form. Returns `(expected_latency_given_hit_somewhere,
    /// p_recompute)`: the caller folds in the recompute branch with its
    /// own prefill cost model.
    pub fn expected(&self, kv_bytes: f64) -> (f64, f64) {
        let mut exp = 0.0;
        let mut p_reach = 1.0; // probability of reaching this level
        for l in &self.levels {
            exp += p_reach * l.hit_rate * l.retrieval_time(kv_bytes);
            p_reach *= 1.0 - l.hit_rate;
        }
        (exp, p_reach)
    }

    /// Eq. 1 including a recompute cost for the full-miss branch — the
    /// scalar the paper's formula produces.
    pub fn expected_with_recompute(&self, kv_bytes: f64, recompute_s: f64) -> f64 {
        let (exp, p_miss) = self.expected(kv_bytes);
        exp + p_miss * recompute_s
    }

    /// Sample one retrieval path (for per-request CDFs, Fig 15).
    pub fn sample(&self, kv_bytes: f64, rng: &mut Pcg) -> Retrieval {
        let mut latency = 0.0;
        for (i, l) in self.levels.iter().enumerate() {
            // a miss at level n still pays its lookup before falling through
            if rng.chance(l.hit_rate) {
                return Retrieval::Hit {
                    level: i,
                    latency: latency + l.retrieval_time(kv_bytes),
                };
            }
            latency += l.lookup_lat;
        }
        Retrieval::Recompute
    }
}

/// Per-client KV-cache occupancy manager (paper §III-D: "the scheduler
/// manages on-device memory by preventing request admission when memory
/// is insufficient and by evicting KV caches of completed requests").
#[derive(Debug, Clone)]
pub struct KvManager {
    pub capacity_tokens: f64,
    pub used_tokens: f64,
    /// (time, used) samples for step-wise memory-load metrics
    pub high_water: f64,
    pub rejections: u64,
}

impl KvManager {
    pub fn new(capacity_tokens: f64) -> KvManager {
        KvManager {
            capacity_tokens,
            used_tokens: 0.0,
            high_water: 0.0,
            rejections: 0,
        }
    }

    /// Try to admit a request that will peak at `peak_tokens`.
    pub fn admit(&mut self, peak_tokens: f64) -> bool {
        if self.used_tokens + peak_tokens <= self.capacity_tokens {
            self.used_tokens += peak_tokens;
            self.high_water = self.high_water.max(self.used_tokens);
            true
        } else {
            self.rejections += 1;
            false
        }
    }

    /// Release a completed/evicted request's reservation.
    pub fn release(&mut self, peak_tokens: f64) {
        self.used_tokens = (self.used_tokens - peak_tokens).max(0.0);
    }

    pub fn utilization(&self) -> f64 {
        if self.capacity_tokens <= 0.0 {
            1.0
        } else {
            self.used_tokens / self.capacity_tokens
        }
    }

    pub fn free_tokens(&self) -> f64 {
        (self.capacity_tokens - self.used_tokens).max(0.0)
    }
}

/// Timestamped memory-load sample (scheduler-level metrics).
#[derive(Debug, Clone, Copy)]
pub struct MemSample {
    pub t: SimTime,
    pub used_tokens: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_level() -> Hierarchy {
        Hierarchy::new(vec![
            CacheLevel {
                name: "local",
                capacity: 1e12,
                lookup_lat: 10e-6,
                bw: 128e9,
                hit_rate: 0.6,
            },
            CacheLevel {
                name: "rack",
                capacity: 32e12,
                lookup_lat: 1e-3,
                bw: 2e9,
                hit_rate: 0.8,
            },
        ])
    }

    #[test]
    fn eq1_closed_form_hand_check() {
        let h = two_level();
        let kv = 1e9; // 1 GB
        let t1 = 10e-6 + 1e9 / 128e9; // 7.823 ms
        let t2 = 1e-3 + 1e9 / 2e9; // 501 ms
        let expect = 0.6 * t1 + 0.4 * 0.8 * t2;
        let (exp, p_miss) = h.expected(kv);
        assert!((exp - expect).abs() < 1e-12);
        assert!((p_miss - 0.08).abs() < 1e-12);
        let full = h.expected_with_recompute(kv, 2.0);
        assert!((full - (expect + 0.08 * 2.0)).abs() < 1e-12);
    }

    #[test]
    fn monte_carlo_matches_closed_form() {
        let h = two_level();
        let kv = 1e9;
        let mut rng = Pcg::new(17);
        let n = 200_000;
        let recompute = 2.0;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += match h.sample(kv, &mut rng) {
                // closed form ignores pass-through lookup cost; it is
                // ≤ 1ms here and folded into the tolerance
                Retrieval::Hit { latency, .. } => latency,
                Retrieval::Recompute => recompute,
            };
        }
        let mc = acc / n as f64;
        let cf = h.expected_with_recompute(kv, recompute);
        assert!(
            (mc - cf).abs() / cf < 0.02,
            "monte-carlo {mc} vs closed form {cf}"
        );
    }

    #[test]
    fn recompute_only_hierarchy() {
        let h = Hierarchy::new(vec![]);
        let (exp, p_miss) = h.expected(1e9);
        assert_eq!(exp, 0.0);
        assert_eq!(p_miss, 1.0);
        let mut rng = Pcg::new(1);
        assert_eq!(h.sample(1e9, &mut rng), Retrieval::Recompute);
    }

    #[test]
    fn kv_manager_admission_and_eviction() {
        let mut m = KvManager::new(1000.0);
        assert!(m.admit(600.0));
        assert!(!m.admit(600.0));
        assert_eq!(m.rejections, 1);
        assert!(m.admit(400.0));
        assert_eq!(m.free_tokens(), 0.0);
        m.release(600.0);
        assert_eq!(m.used_tokens, 400.0);
        assert!(m.admit(500.0));
        assert_eq!(m.high_water, 1000.0);
        assert!((m.utilization() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn release_never_goes_negative() {
        let mut m = KvManager::new(100.0);
        m.admit(50.0);
        m.release(80.0);
        assert_eq!(m.used_tokens, 0.0);
    }

    #[test]
    #[should_panic(expected = "bad hit rate")]
    fn invalid_hit_rate_rejected() {
        Hierarchy::new(vec![CacheLevel {
            name: "x",
            capacity: 1.0,
            lookup_lat: 0.0,
            bw: 1.0,
            hit_rate: 1.5,
        }]);
    }

    #[test]
    #[should_panic(expected = "never hits")]
    fn all_zero_hit_rates_rejected() {
        // a non-empty stack that can never hit is a silent
        // recompute-certain config — reject it at construction
        Hierarchy::new(vec![
            CacheLevel { name: "a", capacity: 1.0, lookup_lat: 0.0, bw: 1.0, hit_rate: 0.0 },
            CacheLevel { name: "b", capacity: 1.0, lookup_lat: 0.0, bw: 1.0, hit_rate: 0.0 },
        ]);
    }

    #[test]
    #[should_panic(expected = "bad bandwidth")]
    fn zero_bandwidth_rejected() {
        Hierarchy::new(vec![CacheLevel {
            name: "x",
            capacity: 1.0,
            lookup_lat: 0.0,
            bw: 0.0,
            hit_rate: 0.5,
        }]);
    }

    #[test]
    fn tier_presets_resolve_by_name() {
        for name in ["hbm", "cxl", "dram", "nvme"] {
            let t = tier_by_name(name).expect("preset tier");
            assert_eq!(t.name, name);
            assert!(t.bw > 0.0 && (0.0..=1.0).contains(&t.hit_rate));
        }
        assert!(tier_by_name("tape").is_none());
        // a preset stack builds a valid hierarchy with a nonzero
        // expected staging latency
        let h = Hierarchy::new(vec![TIER_HBM, TIER_DRAM, TIER_NVME]);
        let (exp, p_miss) = h.expected(1e9);
        assert!(exp > 0.0);
        assert!(p_miss < 0.01, "waterfall should almost always land");
    }
}
