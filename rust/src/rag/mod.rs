//! Retrieval-Augmented Generation cluster modeling (paper §III-E.2):
//! (i) query embedding — an encoder prefill on the embedding device;
//! (ii) IVF-PQ retrieval — RAGO-style analytical cost on the retrieval
//!      device (memory-bound database scans);
//! (iii) re-ranking of the top candidates.

pub mod ivfpq;

use crate::hardware::roofline::LlmCluster;
use crate::workload::request::RagParams;
use ivfpq::IvfPq;

/// A RAG engine: embedding model placed on one device, retrieval +
/// re-rank on another (or the same — the Fig 9 co-location study).
pub struct RagEngine {
    /// encoder on the embedding device
    pub embedder: LlmCluster,
    /// IVF-PQ index on the retrieval device
    pub index: IvfPq,
}

/// Per-batch stage timings (reported separately for Fig 9's breakdown).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RagTiming {
    pub embed_s: f64,
    pub retrieve_s: f64,
    pub rerank_s: f64,
}

impl RagTiming {
    pub fn total(&self) -> f64 {
        self.embed_s + self.retrieve_s + self.rerank_s
    }
}

impl RagEngine {
    pub fn new(embedder: LlmCluster, index: IvfPq) -> RagEngine {
        RagEngine { embedder, index }
    }

    /// Price a batched RAG stage: `queries` concurrent requests with the
    /// given parameters (batched scheduler — §III-D).
    pub fn batch_timing(&self, queries: usize, p: &RagParams) -> RagTiming {
        if queries == 0 {
            return RagTiming::default();
        }
        // embedding: encoder forward over all query tokens in one batch
        let embed_s = self
            .embedder
            .embed_time((queries * p.query_tokens) as f64);
        let retrieve_s = self.index.batch_search_time(queries, p);
        let rerank_s = self.index.batch_rerank_time(queries, p);
        RagTiming {
            embed_s,
            retrieve_s,
            rerank_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::{E5_BASE, MISTRAL_7B};
    use crate::hardware::npu::{A100, GRACE_CPU, SPR_CPU};

    fn engine(embed_model: crate::hardware::ModelSpec, dev: crate::hardware::NpuSpec) -> RagEngine {
        RagEngine::new(
            LlmCluster::new(embed_model, dev, 1),
            IvfPq::new(GRACE_CPU, Default::default()),
        )
    }

    #[test]
    fn fig9_large_embedder_on_small_cpu_is_the_bottleneck() {
        let p = RagParams::default();
        let spr = engine(MISTRAL_7B, SPR_CPU).batch_timing(1, &p);
        let a100 = engine(MISTRAL_7B, A100).batch_timing(1, &p);
        // on the small CPU, embedding dominates the whole RAG stage
        assert!(
            spr.embed_s > spr.retrieve_s + spr.rerank_s,
            "embed {} vs retrieval {}",
            spr.embed_s,
            spr.retrieve_s + spr.rerank_s
        );
        // offloading to A100 collapses the embed term dramatically
        assert!(spr.embed_s / a100.embed_s > 10.0);
    }

    #[test]
    fn small_embedder_cheap_everywhere() {
        let p = RagParams::default();
        let t = engine(E5_BASE, SPR_CPU).batch_timing(1, &p);
        assert!(t.embed_s < 0.1, "E5-Base embed should be fast: {}", t.embed_s);
    }

    #[test]
    fn batching_amortizes_retrieval() {
        let p = RagParams::default();
        let e = engine(E5_BASE, GRACE_CPU);
        let t1 = e.batch_timing(1, &p).retrieve_s;
        let t8 = e.batch_timing(8, &p).retrieve_s;
        // 8 queries share the centroid-table scan → well under 8×
        assert!(t8 < 6.0 * t1, "t1={t1} t8={t8}");
        assert!(t8 > t1);
    }

    #[test]
    fn zero_queries_free() {
        let p = RagParams::default();
        assert_eq!(engine(E5_BASE, SPR_CPU).batch_timing(0, &p).total(), 0.0);
    }
}
