//! IVF-PQ retrieval cost model (paper §III-E.2: "we implement IVF-PQ
//! modelling equations described in RAGO").
//!
//! Query cost decomposes into:
//!   1. coarse scan — distance to all `centroids` (memory-bound read of
//!      the fp32 centroid table; amortized across a batch);
//!   2. PQ scan — `nprobe · points_per_probe` candidates × `pq_m` byte
//!      codes each (LUT adds, memory-bound, per query);
//!   3. re-rank — full-precision re-scoring of the top candidates.

use crate::hardware::npu::NpuSpec;
use crate::hardware::roofline::{EFF_COMPUTE, EFF_MEM};
use crate::workload::request::RagParams;

/// Index-level parameters (database-side; per-query knobs ride on
/// `RagParams`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IvfPqConfig {
    /// embedding dimensionality
    pub dim: usize,
    /// PQ sub-quantizers per vector (bytes per code)
    pub pq_m: usize,
    /// candidates re-scored at full precision before the final top-k
    pub rerank_candidates: usize,
    /// fixed software overhead per batch (index traversal bookkeeping)
    pub overhead_s: f64,
}

impl Default for IvfPqConfig {
    fn default() -> IvfPqConfig {
        IvfPqConfig {
            dim: 768,
            pq_m: 64,
            rerank_candidates: 1000,
            overhead_s: 200e-6,
        }
    }
}

/// An IVF-PQ index resident on a retrieval device.
#[derive(Debug, Clone)]
pub struct IvfPq {
    pub device: NpuSpec,
    pub cfg: IvfPqConfig,
}

impl IvfPq {
    pub fn new(device: NpuSpec, cfg: IvfPqConfig) -> IvfPq {
        IvfPq { device, cfg }
    }

    fn roofline(&self, flops: f64, bytes: f64) -> f64 {
        let t_c = flops / (EFF_COMPUTE * self.device.peak_flops);
        let t_m = bytes / (EFF_MEM * self.device.mem_bw);
        t_c.max(t_m)
    }

    /// Batched ANN search: coarse scan (table read shared by the batch)
    /// + per-query PQ scans.
    pub fn batch_search_time(&self, queries: usize, p: &RagParams) -> f64 {
        if queries == 0 {
            return 0.0;
        }
        let q = queries as f64;
        let d = self.cfg.dim as f64;

        // coarse scan: centroid table is streamed ONCE for the batch;
        // each query computes distances to every centroid.
        let coarse_bytes = p.centroids * d * 4.0;
        let coarse_flops = q * p.centroids * 2.0 * d;
        let t_coarse = self.roofline(coarse_flops, coarse_bytes);

        // PQ scan: each query touches nprobe·ppp codes of pq_m bytes,
        // one LUT add per byte.
        let codes = (p.nprobe * p.points_per_probe) as f64 * self.cfg.pq_m as f64;
        let t_pq = self.roofline(q * codes, q * codes);

        t_coarse + t_pq + self.cfg.overhead_s
    }

    /// Full-precision re-ranking of the PQ scan's top candidates.
    pub fn batch_rerank_time(&self, queries: usize, p: &RagParams) -> f64 {
        if queries == 0 {
            return 0.0;
        }
        let q = queries as f64;
        let d = self.cfg.dim as f64;
        let cands = self.cfg.rerank_candidates.max(p.docs) as f64;
        let bytes = q * cands * d * 4.0;
        let flops = q * cands * 2.0 * d;
        self.roofline(flops, bytes)
    }

    /// Resident index footprint, bytes (for capacity checks): PQ codes for
    /// `n_vectors` + the centroid table.
    pub fn index_bytes(&self, n_vectors: f64, p: &RagParams) -> f64 {
        n_vectors * self.cfg.pq_m as f64 + p.centroids * self.cfg.dim as f64 * 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::npu::{GRACE_CPU, SPR_CPU};

    fn grace() -> IvfPq {
        IvfPq::new(GRACE_CPU, IvfPqConfig::default())
    }

    #[test]
    fn default_search_is_milliseconds_scale() {
        // 4M centroids × 768 dim × 4B = 12.3 GB coarse table;
        // @ 0.75·768 GB/s ≈ 21 ms — CPU ANN search at paper scale
        let t = grace().batch_search_time(1, &RagParams::default());
        assert!(t > 5e-3 && t < 100e-3, "t={t}");
    }

    #[test]
    fn coarse_scan_amortizes_with_batch() {
        let idx = grace();
        let p = RagParams::default();
        let t1 = idx.batch_search_time(1, &p);
        let t16 = idx.batch_search_time(16, &p);
        assert!(t16 < 10.0 * t1, "t1={t1} t16={t16}");
    }

    #[test]
    fn slower_memory_slower_search() {
        let p = RagParams::default();
        let fast = grace().batch_search_time(1, &p);
        let slow = IvfPq::new(SPR_CPU, IvfPqConfig::default()).batch_search_time(1, &p);
        assert!(slow > 1.5 * fast, "fast={fast} slow={slow}");
    }

    #[test]
    fn more_probes_cost_more() {
        let idx = grace();
        let base = RagParams::default();
        let heavy = RagParams {
            nprobe: 500,
            ..base
        };
        assert!(idx.batch_search_time(4, &heavy) > idx.batch_search_time(4, &base));
    }

    #[test]
    fn rerank_much_cheaper_than_search() {
        let idx = grace();
        let p = RagParams::default();
        assert!(idx.batch_rerank_time(1, &p) < 0.2 * idx.batch_search_time(1, &p));
    }

    #[test]
    fn index_footprint_billion_scale() {
        let idx = grace();
        let bytes = idx.index_bytes(1e9, &RagParams::default());
        // 1B vectors × 64B codes + 12 GB centroids ≈ 76 GB
        assert!(bytes > 60e9 && bytes < 100e9, "bytes={bytes}");
    }
}
