//! Synthetic request traces (paper §III-F.1).
//!
//! The paper replays the 2023 Azure LLM inference production traces
//! ("Conv" and "Code") plus synthetic normal-distribution traces. The
//! Azure files are not redistributable here, so `TraceKind::AzureConv` /
//! `AzureCode` generate log-normal token distributions matched to the
//! published summary statistics (Splitwise, Table 1: Conv median prompt
//! ≈ 1020 / median output ≈ 211; Code median prompt ≈ 1930 / median
//! output ≈ 31 — long-input/short-output). The experiment conclusions
//! depend on these *shapes*, not on individual trace rows (DESIGN.md §3).

use super::request::{KvParams, RagParams, Request, Stage, StageList};
use crate::model::ModelId;
use crate::sim::SimTime;
use crate::util::rng::{Arrival, Pcg};

/// Token-length distribution family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceKind {
    /// chat: short prompts, mid-length answers
    AzureConv,
    /// code generation: long prompts, short completions
    AzureCode,
    /// user-configurable Normal(mean, σ) prompt/output lengths
    Synthetic {
        in_mean: f64,
        in_std: f64,
        out_mean: f64,
        out_std: f64,
    },
}

impl TraceKind {
    /// Sample (prompt_tokens, output_tokens).
    pub fn sample(&self, rng: &mut Pcg) -> (usize, usize) {
        let clamp = |v: f64| v.round().clamp(16.0, 16384.0) as usize;
        match *self {
            TraceKind::AzureConv => {
                // medians from the published trace summaries; σ calibrated
                // to the reported p90/p50 spread (conv p90 prompt ≈ 2.6k)
                let p = rng.lognormal(1020f64.ln(), 0.73);
                let o = rng.lognormal(211f64.ln(), 0.66);
                (clamp(p), clamp(o))
            }
            TraceKind::AzureCode => {
                // code p90 prompt ≈ 3.9k (σ≈0.55), capped at the 8K
                // context window of the 2023 trace's serving stack
                let p = rng.lognormal(1930f64.ln(), 0.55).min(8192.0);
                let o = rng.lognormal(31f64.ln(), 0.6);
                (clamp(p), clamp(o))
            }
            TraceKind::Synthetic {
                in_mean,
                in_std,
                out_mean,
                out_std,
            } => (
                clamp(rng.normal_mu_sigma(in_mean, in_std)),
                clamp(rng.normal_mu_sigma(out_mean, out_std)),
            ),
        }
    }
}

/// Which stages a request passes through (Fig 1 pipelines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pipeline {
    /// prefill → decode
    Regular,
    /// RAG → prefill → decode
    Rag(RagParams),
    /// KV retrieval → prefill → decode
    KvRetrieval(KvParams),
    /// preprocess → prefill → decode → postprocess (hallucination/
    /// safeguard verification, Fig 1a)
    Guarded,
    /// model-route → prefill → decode: the serving model is chosen per
    /// request by the run's model policy (MIST's "dynamic model routing"
    /// as a first-class stage)
    Routed,
    /// model-route → prefill → decode → model-route → prefill → decode:
    /// small-model-first with an escalation point after the first answer
    /// (the cascade policy finishes or re-runs on the large model)
    Cascade,
    /// prefill → KV migration → decode: cluster-level disaggregation
    /// with an explicit KV hand-off between the prefill-role and
    /// decode-role clients (docs/disaggregation.md)
    Disagg,
}

impl Pipeline {
    /// The stage list, inline (no heap allocation — this runs once per
    /// generated request on the streaming-arrival hot path).
    pub fn stages(&self) -> StageList {
        match *self {
            Pipeline::Regular => StageList::new(&[Stage::Prefill, Stage::Decode]),
            Pipeline::Rag(p) => StageList::new(&[Stage::Rag(p), Stage::Prefill, Stage::Decode]),
            Pipeline::KvRetrieval(p) => {
                StageList::new(&[Stage::KvRetrieval(p), Stage::Prefill, Stage::Decode])
            }
            Pipeline::Guarded => StageList::new(&[
                Stage::Preprocess,
                Stage::Prefill,
                Stage::Decode,
                Stage::Postprocess,
            ]),
            Pipeline::Routed => {
                StageList::new(&[Stage::ModelRoute, Stage::Prefill, Stage::Decode])
            }
            Pipeline::Cascade => StageList::new(&[
                Stage::ModelRoute,
                Stage::Prefill,
                Stage::Decode,
                Stage::ModelRoute,
                Stage::Prefill,
                Stage::Decode,
            ]),
            Pipeline::Disagg => {
                StageList::new(&[Stage::Prefill, Stage::KvMigration, Stage::Decode])
            }
        }
    }
}

/// Reasoning configuration (paper §IV-A): single-path scales output
/// 8–32×; multi-path scales 4–16× with N parallel branches sharing the
/// prefill KV.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Reasoning {
    None,
    SinglePath { scale: f64 },
    MultiPath { scale: f64, branches: usize },
}

/// Full workload specification — one entry per request class.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// the initial serving model (routed pipelines may rewrite a
    /// request's model at its `ModelRoute` stages)
    pub model: ModelId,
    pub trace: TraceKind,
    pub pipeline: Pipeline,
    pub reasoning: Reasoning,
    pub arrival: Arrival,
    pub n_requests: usize,
    pub seed: u64,
    /// per-request deadline in seconds after arrival (the class SLO,
    /// docs/robustness.md): requests still in flight when it elapses
    /// time out and fail. None (default) disables deadlines for the
    /// class and keeps generation byte-identical to pre-deadline runs.
    pub deadline: Option<f64>,
}

impl WorkloadSpec {
    pub fn new(model: impl Into<ModelId>, trace: TraceKind, n: usize, rate: f64) -> WorkloadSpec {
        WorkloadSpec {
            model: model.into(),
            trace,
            pipeline: Pipeline::Regular,
            reasoning: Reasoning::None,
            arrival: Arrival::Poisson { rate },
            n_requests: n,
            seed: 0,
            deadline: None,
        }
    }

    pub fn with_deadline(mut self, seconds: f64) -> WorkloadSpec {
        self.deadline = Some(seconds);
        self
    }

    pub fn with_pipeline(mut self, p: Pipeline) -> WorkloadSpec {
        self.pipeline = p;
        self
    }

    pub fn with_reasoning(mut self, r: Reasoning) -> WorkloadSpec {
        self.reasoning = r;
        self
    }

    pub fn with_arrival(mut self, a: Arrival) -> WorkloadSpec {
        self.arrival = a;
        self
    }

    pub fn with_seed(mut self, s: u64) -> WorkloadSpec {
        self.seed = s;
        self
    }

    /// The seed every generation path derives its rng from —
    /// `generate` and the lazy [`ClassStream`](super::stream::ClassStream)
    /// must start from the same stream to stay bit-identical.
    pub(crate) fn rng_seed(&self) -> u64 {
        self.seed ^ 0x48455253
    }

    /// Sample the `i`-th request of this class given its arrival time.
    /// Shared by eager generation and the streaming source; the rng must
    /// be positioned exactly past the class's timestamp draws.
    pub(crate) fn sample_request(&self, i: usize, t: f64, id_base: u64, rng: &mut Pcg) -> Request {
        let (prompt, mut output) = self.trace.sample(rng);
        let mut branches = 1usize;
        match self.reasoning {
            Reasoning::None => {}
            Reasoning::SinglePath { scale } => {
                output = ((output as f64) * scale).round() as usize;
            }
            Reasoning::MultiPath { scale, branches: b } => {
                output = ((output as f64) * scale).round() as usize;
                branches = b.max(1);
            }
        }
        let mut r = Request::new(
            id_base + i as u64,
            self.model,
            SimTime::from_secs(t),
            self.pipeline.stages(),
            prompt,
            output.clamp(1, 65536),
        );
        r.branches = branches;
        // attached after construction, from arrival time + the class
        // SLO — no extra PCG draws, so deadline-free classes generate
        // bit-identical streams
        r.deadline = self.deadline.map(|d| SimTime::from_secs(t + d));
        r
    }

    /// Generate the request stream (sorted by arrival, ids dense from
    /// `id_base`).
    pub fn generate(&self, id_base: u64) -> Vec<Request> {
        let mut rng = Pcg::new(self.rng_seed());
        let times = self.arrival.timestamps(self.n_requests, &mut rng);
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| self.sample_request(i, t, id_base, &mut rng))
            .collect()
    }
}

/// A mixture of request classes sharing one serving system — the
/// "workload mix" axis of the scenario registry (e.g. 70% regular
/// prefill-decode + 30% RAG). Each class keeps its own trace, pipeline,
/// reasoning mode and arrival process; fractions weight both the request
/// count and the injection rate.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    /// (fraction, class); fractions are normalized on construction
    pub classes: Vec<(f64, WorkloadSpec)>,
}

impl WorkloadMix {
    /// A single-class mix (the common case).
    pub fn single(spec: WorkloadSpec) -> WorkloadMix {
        WorkloadMix {
            classes: vec![(1.0, spec)],
        }
    }

    /// Build from weighted classes; weights are normalized to fractions.
    pub fn new(classes: Vec<(f64, WorkloadSpec)>) -> WorkloadMix {
        let total: f64 = classes.iter().map(|(f, _)| f.max(0.0)).sum();
        let norm = if total > 0.0 { total } else { 1.0 };
        WorkloadMix {
            classes: classes
                .into_iter()
                .map(|(f, s)| (f.max(0.0) / norm, s))
                .collect(),
        }
    }

    pub fn n_total(&self) -> usize {
        self.classes.iter().map(|(_, s)| s.n_requests).sum()
    }

    /// The dominant class (largest fraction) — used for `auto` SLO
    /// resolution and reporting.
    pub fn primary(&self) -> &WorkloadSpec {
        &self
            .classes
            .iter()
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .expect("empty workload mix")
            .1
    }

    /// Distribute `n` requests across classes by fraction (remainder to
    /// the first class) and set each class's arrival to its share of the
    /// total injection rate, preserving the process shape.
    pub fn scaled(&self, n: usize, total_rate: f64) -> WorkloadMix {
        let mut classes: Vec<(f64, WorkloadSpec)> = self
            .classes
            .iter()
            .map(|(f, s)| {
                let mut s = s.clone();
                s.n_requests = ((n as f64) * f).round() as usize;
                s.arrival = s.arrival.scaled_to((total_rate * f).max(1e-9));
                (*f, s)
            })
            .collect();
        let assigned: i64 = classes.iter().map(|(_, s)| s.n_requests as i64).sum();
        if let Some((_, first)) = classes.first_mut() {
            // absorb the rounding remainder so the mix totals exactly n
            first.n_requests =
                (first.n_requests as i64 + n as i64 - assigned).max(0) as usize;
        }
        WorkloadMix { classes }
    }

    /// Class `i`'s spec with the per-class seed decorrelation applied
    /// (class streams sharing a scenario seed must not correlate) —
    /// shared by [`WorkloadMix::generate`] and the streaming source so
    /// the two paths draw from identical PCG streams.
    pub(crate) fn class_spec(&self, i: usize) -> WorkloadSpec {
        let mut spec = self.classes[i].1.clone();
        spec.seed = spec.seed.wrapping_add(i as u64 * 0x9E37_79B9);
        spec
    }

    /// Generate the merged request stream: per-class streams with
    /// disjoint dense id ranges, interleaved by arrival time.
    pub fn generate(&self) -> Vec<Request> {
        let mut all = Vec::with_capacity(self.n_total());
        let mut id_base = 0u64;
        for i in 0..self.classes.len() {
            let spec = self.class_spec(i);
            all.extend(spec.generate(id_base));
            id_base += spec.n_requests as u64;
        }
        all.sort_by(|a, b| a.arrival.cmp(&b.arrival).then(a.id.cmp(&b.id)));
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn medians(kind: TraceKind) -> (f64, f64) {
        let mut rng = Pcg::new(42);
        let mut ins = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..20_000 {
            let (p, o) = kind.sample(&mut rng);
            ins.push(p as f64);
            outs.push(o as f64);
        }
        (Summary::of(&ins).p50, Summary::of(&outs).p50)
    }

    #[test]
    fn conv_trace_matches_published_medians() {
        let (p, o) = medians(TraceKind::AzureConv);
        assert!((p - 1020.0).abs() / 1020.0 < 0.1, "prompt median {p}");
        assert!((o - 211.0).abs() / 211.0 < 0.1, "output median {o}");
    }

    #[test]
    fn code_trace_long_input_short_output() {
        let (p, o) = medians(TraceKind::AzureCode);
        assert!((p - 1930.0).abs() / 1930.0 < 0.1, "prompt median {p}");
        assert!((o - 31.0).abs() / 31.0 < 0.15, "output median {o}");
        assert!(p / o > 20.0, "code must be input-heavy");
    }

    #[test]
    fn synthetic_trace_configurable() {
        let kind = TraceKind::Synthetic {
            in_mean: 2000.0,
            in_std: 600.0, // paper Fig 8: 2k / σ=30%
            out_mean: 2000.0,
            out_std: 600.0,
        };
        let (p, o) = medians(kind);
        assert!((p - 2000.0).abs() < 100.0);
        assert!((o - 2000.0).abs() < 100.0);
    }

    #[test]
    fn generate_produces_sorted_unique_ids() {
        let spec = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 500, 10.0);
        let reqs = spec.generate(100);
        assert_eq!(reqs.len(), 500);
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert_eq!(reqs[0].id, 100);
        assert_eq!(reqs[499].id, 599);
    }

    #[test]
    fn reasoning_scales_outputs() {
        let base = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 200, 10.0);
        let plain = base.clone().generate(0);
        let single = base
            .clone()
            .with_reasoning(Reasoning::SinglePath { scale: 16.0 })
            .generate(0);
        let multi = base
            .with_reasoning(Reasoning::MultiPath {
                scale: 8.0,
                branches: 8,
            })
            .generate(0);
        let sum = |rs: &[Request]| rs.iter().map(|r| r.output_tokens).sum::<usize>() as f64;
        assert!((sum(&single) / sum(&plain) - 16.0).abs() < 0.5);
        assert!((sum(&multi) / sum(&plain) - 8.0).abs() < 0.5);
        assert!(multi.iter().all(|r| r.branches == 8));
        assert!(plain.iter().all(|r| r.branches == 1));
    }

    #[test]
    fn pipelines_build_expected_stages() {
        assert_eq!(Pipeline::Regular.stages().len(), 2);
        assert_eq!(Pipeline::Rag(RagParams::default()).stages().len(), 3);
        assert_eq!(
            Pipeline::KvRetrieval(KvParams { cached_tokens: 3000 }).stages()[0],
            Stage::KvRetrieval(KvParams { cached_tokens: 3000 })
        );
        assert_eq!(Pipeline::Guarded.stages().len(), 4);
        assert_eq!(
            Pipeline::Routed.stages(),
            vec![Stage::ModelRoute, Stage::Prefill, Stage::Decode]
        );
        let cascade = Pipeline::Cascade.stages();
        assert_eq!(cascade.len(), 6);
        assert_eq!(cascade[3], Stage::ModelRoute, "escalation point after decode");
        assert_eq!(
            Pipeline::Disagg.stages(),
            vec![Stage::Prefill, Stage::KvMigration, Stage::Decode]
        );
    }

    #[test]
    fn mix_scales_counts_rates_and_merges_sorted() {
        let conv = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 0, 4.0);
        let rag = conv
            .clone()
            .with_pipeline(Pipeline::Rag(RagParams::default()));
        let mix = WorkloadMix::new(vec![(3.0, conv), (1.0, rag)]).scaled(100, 8.0);
        assert_eq!(mix.n_total(), 100);
        assert_eq!(mix.classes[0].1.n_requests, 75);
        assert_eq!(mix.classes[1].1.n_requests, 25);
        assert!((mix.classes[0].1.arrival.rate() - 6.0).abs() < 1e-9);
        assert!((mix.classes[1].1.arrival.rate() - 2.0).abs() < 1e-9);
        assert!((mix.classes[0].0 - 0.75).abs() < 1e-12, "weights normalized");
        let reqs = mix.generate();
        assert_eq!(reqs.len(), 100);
        assert!(reqs.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // ids are unique across classes
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100);
        // both pipeline shapes present
        assert!(reqs.iter().any(|r| r.stages.len() == 2));
        assert!(reqs.iter().any(|r| r.stages.len() == 3));
    }

    #[test]
    fn single_class_mix_matches_plain_generation() {
        let spec = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 50, 5.0).with_seed(3);
        let plain = spec.clone().generate(0);
        let mixed = WorkloadMix::single(spec).generate();
        assert_eq!(plain.len(), mixed.len());
        for (a, b) in plain.iter().zip(&mixed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.arrival, b.arrival);
        }
    }

    #[test]
    fn same_seed_same_trace() {
        let spec = WorkloadSpec::new("llama3-70b", TraceKind::AzureCode, 100, 5.0).with_seed(7);
        let a = spec.generate(0);
        let b = spec.generate(0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.arrival, y.arrival);
        }
    }
}
