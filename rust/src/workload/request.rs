//! Request model (paper §III-F): a request is a pipeline of stages with
//! distinct compute/memory demands, plus the per-stage and per-token
//! timestamps the metrics layer aggregates.

use crate::model::ModelId;
use crate::sim::SimTime;

pub type ReqId = u64;

/// RAG stage parameters (paper §IV-B defaults: IVF-PQ with 4M centroids,
/// 50 probes, 5K points per probe; 20 docs × 512 tokens retrieved).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RagParams {
    /// tokens embedded by the encoder (the user query)
    pub query_tokens: usize,
    /// documents returned after re-ranking
    pub docs: usize,
    /// tokens per document appended to the prompt
    pub doc_tokens: usize,
    pub centroids: f64,
    pub nprobe: usize,
    pub points_per_probe: usize,
}

impl Default for RagParams {
    fn default() -> RagParams {
        RagParams {
            query_tokens: 128,
            docs: 20,
            doc_tokens: 512,
            centroids: 4e6,
            nprobe: 50,
            points_per_probe: 5000,
        }
    }
}

impl RagParams {
    /// Context tokens the RAG stage prepends to the prompt.
    pub fn context_tokens(&self) -> usize {
        self.docs * self.doc_tokens
    }
}

/// KV-cache retrieval stage parameters (§V-A: 3K cached tokens; Fig 15:
/// 4K short / 24K long).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvParams {
    /// past-context tokens whose KV is fetched instead of recomputed
    pub cached_tokens: usize,
}

/// One stage of the inference pipeline (Fig 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Stage {
    /// tokenization/padding on a preprocessing client
    Preprocess,
    /// embedding + retrieval + re-rank on a RAG client
    Rag(RagParams),
    /// fetch past KV from the memory hierarchy on a KV-retrieval client
    KvRetrieval(KvParams),
    /// dynamic model selection: the coordinator applies the run's
    /// [`ModelPolicy`](crate::model::policy::ModelPolicy) and consumes
    /// the stage inline — it costs zero simulated time and never
    /// occupies a client. A second `ModelRoute` after `Decode` is the
    /// cascade escalation point (re-run on a bigger model, or finish).
    ModelRoute,
    /// prompt processing on an LLM client (possibly chunked)
    Prefill,
    /// hand the prefilled KV cache off to a decode-role client
    /// (cluster-level disaggregation, docs/disaggregation.md). Like
    /// `ModelRoute` the coordinator consumes it inline — it costs zero
    /// client time and never occupies a client; the KV bytes it
    /// represents are priced on the network hop to the decode client,
    /// optionally through a tiered migration pool.
    KvMigration,
    /// autoregressive generation on an LLM client
    Decode,
    /// detokenize + guard-model filtering on a postprocessing client
    Postprocess,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Preprocess => "preprocess",
            Stage::Rag(_) => "rag",
            Stage::KvRetrieval(_) => "kv_retrieval",
            Stage::ModelRoute => "model_route",
            Stage::Prefill => "prefill",
            Stage::KvMigration => "kv_migration",
            Stage::Decode => "decode",
            Stage::Postprocess => "postprocess",
        }
    }
}

/// Longest pipeline the inline stage array can hold. The longest
/// shipped pipeline ([`Cascade`](crate::workload::trace::Pipeline)) has
/// 6 stages; 8 leaves headroom without growing [`Request`].
pub const MAX_STAGES: usize = 8;

/// Fixed-capacity inline pipeline. `Pipeline::stages` is evaluated once
/// per *generated* request on the streaming-arrival hot path, so the
/// stage array lives inline in the `Request` instead of behind a
/// per-arrival heap allocation. Derefs to `&[Stage]`, so indexing,
/// slicing and iteration read exactly like the `Vec<Stage>` it
/// replaced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageList {
    len: u8,
    stages: [Stage; MAX_STAGES],
}

impl StageList {
    pub fn new(stages: &[Stage]) -> StageList {
        assert!(
            stages.len() <= MAX_STAGES,
            "pipeline of {} stages exceeds MAX_STAGES = {MAX_STAGES}",
            stages.len()
        );
        // unused slots hold an arbitrary filler (never read: every
        // access goes through the `len`-bounded slice)
        let mut list = StageList { len: stages.len() as u8, stages: [Stage::Prefill; MAX_STAGES] };
        list.stages[..stages.len()].copy_from_slice(stages);
        list
    }

    pub fn as_slice(&self) -> &[Stage] {
        &self.stages[..self.len as usize]
    }
}

impl std::ops::Deref for StageList {
    type Target = [Stage];
    fn deref(&self) -> &[Stage] {
        self.as_slice()
    }
}

impl From<&[Stage]> for StageList {
    fn from(stages: &[Stage]) -> StageList {
        StageList::new(stages)
    }
}

impl<const N: usize> From<[Stage; N]> for StageList {
    fn from(stages: [Stage; N]) -> StageList {
        StageList::new(&stages)
    }
}

impl From<Vec<Stage>> for StageList {
    fn from(stages: Vec<Stage>) -> StageList {
        StageList::new(&stages)
    }
}

impl PartialEq<Vec<Stage>> for StageList {
    fn eq(&self, other: &Vec<Stage>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Timestamps for one completed stage (metrics / Chrome tracing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageRecord {
    pub stage_idx: usize,
    pub client: usize,
    pub start: SimTime,
    pub end: SimTime,
}

/// A request flowing through the simulated serving system.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    /// interned model identity; `Stage::ModelRoute` stages may rewrite
    /// it (cascade escalation), so it is the *current* serving model
    pub model: ModelId,
    pub arrival: SimTime,
    /// pipeline definition (inline — no per-request heap allocation)
    pub stages: StageList,
    /// index of the stage currently executing / queued
    pub stage_idx: usize,

    // ---- token accounting -------------------------------------------------
    /// prompt tokens that must be prefilled (RAG context is added on
    /// completion of the RAG stage)
    pub prompt_tokens: usize,
    /// past tokens whose KV was retrieved (attended over, not recomputed)
    pub past_tokens: usize,
    /// decode target per branch
    pub output_tokens: usize,
    /// parallel reasoning branches (1 = single-path); prefill KV shared
    pub branches: usize,

    // ---- runtime state ----------------------------------------------------
    /// prompt tokens already prefilled (chunked batching progresses this)
    pub prefilled: usize,
    /// decode tokens generated per branch
    pub decoded: usize,
    /// decode tokens (branches included) generated by earlier cascade
    /// passes whose answer was superseded by an escalation — real work
    /// for throughput/energy accounting, excluded from TPOT
    pub prior_decoded: usize,
    /// client currently holding the request
    pub client: Option<usize>,

    // ---- robustness (docs/robustness.md) ----------------------------------
    /// 0-based try counter: bumped by each retry (transient hand-off
    /// failure, crash orphaning, no-healthy-candidate backoff)
    pub attempt: u32,
    /// absolute completion deadline (workload-class `deadline` key);
    /// elapsing it fails the request as a timeout
    pub deadline: Option<SimTime>,
    /// terminal failure marker — set by `Coordinator::fail` so stale
    /// queued events against this id become no-ops
    pub failed: bool,
    /// the failure was a deadline timeout
    pub timed_out: bool,
    /// the failure was a load-shed (no healthy candidate, `shed: true`)
    pub shed: bool,

    // ---- metrics ----------------------------------------------------------
    /// when the current stage was accepted by its client (set by the
    /// coordinator on push; used for stage span records)
    pub stage_accept: SimTime,
    pub records: Vec<StageRecord>,
    /// first token of the *current* cascade pass (escalation clears it
    /// so TPOT measures the pass that produced the final answer)
    pub first_token_time: Option<SimTime>,
    pub last_token_time: Option<SimTime>,
    /// first token the user ever saw, frozen across cascade escalation
    /// (None until an escalation happens — [`Request::ttft`] falls back
    /// to `first_token_time`, so the plain path is untouched)
    pub first_response_time: Option<SimTime>,
    pub finished: Option<SimTime>,
}

impl Request {
    pub fn new(
        id: ReqId,
        model: impl Into<ModelId>,
        arrival: SimTime,
        stages: impl Into<StageList>,
        prompt_tokens: usize,
        output_tokens: usize,
    ) -> Request {
        let stages = stages.into();
        assert!(!stages.is_empty());
        assert!(prompt_tokens > 0 && output_tokens > 0);
        Request {
            id,
            model: model.into(),
            arrival,
            stages,
            stage_idx: 0,
            prompt_tokens,
            past_tokens: 0,
            output_tokens,
            branches: 1,
            prefilled: 0,
            decoded: 0,
            prior_decoded: 0,
            client: None,
            attempt: 0,
            deadline: None,
            failed: false,
            timed_out: false,
            shed: false,
            stage_accept: SimTime::ZERO,
            records: Vec::new(),
            first_token_time: None,
            last_token_time: None,
            first_response_time: None,
            finished: None,
        }
    }

    pub fn stage(&self) -> Stage {
        self.stages[self.stage_idx]
    }

    /// How many `ModelRoute` stages precede the current one — the
    /// 0-based ordinal the coordinator hands to the model policy (0 =
    /// initial model selection, 1 = cascade escalation point, …).
    pub fn model_route_ordinal(&self) -> usize {
        self.stages[..self.stage_idx]
            .iter()
            .filter(|s| matches!(s, Stage::ModelRoute))
            .count()
    }

    pub fn is_last_stage(&self) -> bool {
        self.stage_idx + 1 == self.stages.len()
    }

    /// Advance the pipeline, applying stage side effects (RAG context
    /// growth). KV-retrieval outcomes are applied by the retrieval client
    /// via [`Request::apply_kv_retrieval`] because they depend on the
    /// sampled hit/recompute result. Returns false if that was the final
    /// stage.
    pub fn advance_stage(&mut self) -> bool {
        if let Stage::Rag(p) = self.stage() {
            self.prompt_tokens += p.context_tokens();
        }
        if self.is_last_stage() {
            return false;
        }
        self.stage_idx += 1;
        true
    }

    /// Record the KV-retrieval stage outcome: a hit credits the cached
    /// context as `past_tokens` (attended over, not recomputed); a full
    /// miss means the context must be *recomputed* — it joins the prompt
    /// and will be prefilled (paper §III-E.3).
    pub fn apply_kv_retrieval(&mut self, cached_tokens: usize, hit: bool) {
        if hit {
            self.past_tokens += cached_tokens;
        } else {
            self.prompt_tokens += cached_tokens;
        }
    }

    // ---- scheduler-facing accounting ---------------------------------------

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> usize {
        self.prompt_tokens.saturating_sub(self.prefilled)
    }

    pub fn prefill_complete(&self) -> bool {
        self.prefill_remaining() == 0
    }

    /// Decode tokens still to generate (per branch).
    pub fn decode_remaining(&self) -> usize {
        self.output_tokens.saturating_sub(self.decoded)
    }

    pub fn decode_complete(&self) -> bool {
        self.decode_remaining() == 0
    }

    /// Sequences this request contributes to a decode batch.
    pub fn decode_seqs(&self) -> usize {
        self.branches
    }

    /// KV-cache tokens currently held for this request: shared prefix
    /// (past + prefilled prompt) counted once + per-branch decode chains.
    pub fn kv_tokens(&self) -> f64 {
        (self.past_tokens + self.prefilled) as f64 + (self.branches * self.decoded) as f64
    }

    /// KV footprint when decode finishes — used for admission control.
    pub fn kv_tokens_peak(&self) -> f64 {
        (self.past_tokens + self.prompt_tokens) as f64
            + (self.branches * self.output_tokens) as f64
    }

    /// Total context a decode step attends over, per branch.
    pub fn decode_ctx(&self) -> f64 {
        (self.past_tokens + self.prompt_tokens + self.decoded) as f64
    }

    /// "Work left" metric for Least-Work-Left packing / load routing.
    pub fn work_left_tokens(&self) -> f64 {
        self.prefill_remaining() as f64
            + (self.decode_remaining() * self.branches) as f64
    }

    /// Total decode tokens generated across all cascade passes
    /// (branches included) — the throughput/energy numerator. Equals
    /// `decoded × branches` for non-escalated requests.
    pub fn generated_tokens(&self) -> usize {
        self.prior_decoded + self.decoded * self.branches
    }

    // ---- latency metrics ----------------------------------------------------

    /// Time to the first token the user ever saw: the first cascade
    /// pass's first token when an escalation intervened, else the
    /// current pass's.
    pub fn ttft(&self) -> Option<f64> {
        self.first_response_time
            .or(self.first_token_time)
            .map(|t| (t - self.arrival).as_secs())
    }

    /// Time per output token after the first (s/token).
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_time, self.last_token_time) {
            (Some(a), Some(b)) if self.decoded > 1 => {
                Some((b - a).as_secs() / (self.decoded - 1) as f64)
            }
            _ => None,
        }
    }

    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished.map(|t| (t - self.arrival).as_secs())
    }
}

/// Compact POD snapshot of a finished (or failed) request: the dozen
/// timestamp/token fields the metrics layer actually reads. The
/// coordinator folds every completion into one of these (in completion
/// order), so under request retirement the arena slot — the `Request`
/// with its heap-allocated `stages`/`records` — can be recycled while
/// percentile-exact latency samples survive the run. The latency
/// accessors mirror [`Request`]'s formulas exactly;
/// `RunMetrics::collect` consumes these records, and
/// `rust/tests/retirement_equivalence.rs` pins record-based collection
/// against the retained-pool scan bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompletionRecord {
    pub id: ReqId,
    /// the model that produced the final answer (cascades: post-escalation)
    pub model: ModelId,
    pub arrival: SimTime,
    /// completion stamp (`None` for failed requests)
    pub finished: Option<SimTime>,
    pub first_token_time: Option<SimTime>,
    pub last_token_time: Option<SimTime>,
    pub first_response_time: Option<SimTime>,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub decoded: usize,
    pub branches: usize,
    pub prior_decoded: usize,
    /// the request could not be placed (counted in `failed`, excluded
    /// from latency/throughput aggregation)
    pub failed: bool,
    /// tries the request consumed (0 = first try succeeded)
    pub attempt: u32,
    /// the failure was a deadline timeout
    pub timed_out: bool,
    /// the failure was a load-shed under faults
    pub shed: bool,
}

impl CompletionRecord {
    /// Snapshot `r` at its completion (or failure) instant.
    pub fn of(r: &Request, failed: bool) -> CompletionRecord {
        CompletionRecord {
            id: r.id,
            model: r.model,
            arrival: r.arrival,
            finished: r.finished,
            first_token_time: r.first_token_time,
            last_token_time: r.last_token_time,
            first_response_time: r.first_response_time,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.output_tokens,
            decoded: r.decoded,
            branches: r.branches,
            prior_decoded: r.prior_decoded,
            failed,
            attempt: r.attempt,
            timed_out: r.timed_out,
            shed: r.shed,
        }
    }

    /// Same formula as [`Request::ttft`].
    pub fn ttft(&self) -> Option<f64> {
        self.first_response_time
            .or(self.first_token_time)
            .map(|t| (t - self.arrival).as_secs())
    }

    /// Same formula as [`Request::tpot`].
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_time, self.last_token_time) {
            (Some(a), Some(b)) if self.decoded > 1 => {
                Some((b - a).as_secs() / (self.decoded - 1) as f64)
            }
            _ => None,
        }
    }

    /// Same formula as [`Request::e2e_latency`].
    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished.map(|t| (t - self.arrival).as_secs())
    }

    /// Same formula as [`Request::generated_tokens`].
    pub fn generated_tokens(&self) -> usize {
        self.prior_decoded + self.decoded * self.branches
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn req(stages: Vec<Stage>) -> Request {
        Request::new(1, "llama3-70b", SimTime::ZERO, stages, 1000, 200)
    }

    #[test]
    fn pipeline_advances_with_side_effects() {
        let mut r = req(vec![
            Stage::Rag(RagParams::default()),
            Stage::Prefill,
            Stage::Decode,
        ]);
        assert_eq!(r.stage(), Stage::Rag(RagParams::default()));
        assert!(r.advance_stage());
        // RAG added 20 × 512 = 10240 context tokens (Fig 9 setup)
        assert_eq!(r.prompt_tokens, 1000 + 10_240);
        assert_eq!(r.stage(), Stage::Prefill);
        assert!(r.advance_stage());
        assert_eq!(r.stage(), Stage::Decode);
        assert!(!r.advance_stage());
    }

    #[test]
    fn kv_retrieval_hit_adds_past_tokens() {
        let mut r = req(vec![
            Stage::KvRetrieval(KvParams { cached_tokens: 3000 }),
            Stage::Prefill,
            Stage::Decode,
        ]);
        r.apply_kv_retrieval(3000, true);
        r.advance_stage();
        assert_eq!(r.past_tokens, 3000);
        // prefill unchanged — cached context is NOT recomputed (paper §V-A)
        assert_eq!(r.prompt_tokens, 1000);
        assert_eq!(r.decode_ctx(), 4000.0);
    }

    #[test]
    fn kv_retrieval_miss_recomputes_context() {
        let mut r = req(vec![
            Stage::KvRetrieval(KvParams { cached_tokens: 3000 }),
            Stage::Prefill,
            Stage::Decode,
        ]);
        r.apply_kv_retrieval(3000, false);
        assert_eq!(r.past_tokens, 0);
        assert_eq!(r.prompt_tokens, 4000, "missed context joins the prompt");
    }

    #[test]
    fn prefill_and_decode_progress() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        assert_eq!(r.prefill_remaining(), 1000);
        r.prefilled += 512;
        assert_eq!(r.prefill_remaining(), 488);
        assert!(!r.prefill_complete());
        r.prefilled = 1000;
        assert!(r.prefill_complete());
        r.decoded = 200;
        assert!(r.decode_complete());
    }

    #[test]
    fn multipath_reasoning_kv_accounting() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        r.branches = 8;
        r.prefilled = 1000;
        r.decoded = 100;
        // shared prefix once + 8 branches × 100 decode tokens
        assert_eq!(r.kv_tokens(), 1000.0 + 800.0);
        assert_eq!(r.kv_tokens_peak(), 1000.0 + 8.0 * 200.0);
        assert_eq!(r.decode_seqs(), 8);
        assert_eq!(r.work_left_tokens(), 100.0 * 8.0);
    }

    #[test]
    fn model_route_ordinals_count_prior_routes() {
        let mut r = req(vec![
            Stage::ModelRoute,
            Stage::Prefill,
            Stage::Decode,
            Stage::ModelRoute,
            Stage::Prefill,
            Stage::Decode,
        ]);
        assert_eq!(r.stage(), Stage::ModelRoute);
        assert_eq!(r.model_route_ordinal(), 0);
        r.advance_stage(); // -> Prefill
        r.advance_stage(); // -> Decode
        r.advance_stage(); // -> second ModelRoute
        assert_eq!(r.stage(), Stage::ModelRoute);
        assert_eq!(r.model_route_ordinal(), 1);
        assert_eq!(Stage::ModelRoute.name(), "model_route");
    }

    #[test]
    fn stage_list_derefs_like_a_vec() {
        let v = vec![Stage::Prefill, Stage::KvMigration, Stage::Decode];
        let list = StageList::new(&v);
        assert_eq!(list.len(), 3);
        assert_eq!(list, v);
        assert_eq!(list[1], Stage::KvMigration);
        assert_eq!(Stage::KvMigration.name(), "kv_migration");
        assert_eq!(&list[..2], &v[..2]);
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_STAGES")]
    fn stage_list_rejects_oversized_pipelines() {
        StageList::new(&[Stage::Decode; MAX_STAGES + 1]);
    }

    #[test]
    fn latency_metrics() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        assert_eq!(r.ttft(), None);
        r.first_token_time = Some(SimTime::from_secs(0.5));
        r.last_token_time = Some(SimTime::from_secs(2.5));
        r.decoded = 201;
        r.finished = Some(SimTime::from_secs(3.0));
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.tpot().unwrap() - 0.01).abs() < 1e-12);
        assert!((r.e2e_latency().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn completion_record_mirrors_request_metrics() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        r.branches = 4;
        r.decoded = 201;
        r.prior_decoded = 37;
        r.first_token_time = Some(SimTime::from_secs(0.5));
        r.last_token_time = Some(SimTime::from_secs(2.5));
        r.first_response_time = Some(SimTime::from_secs(0.25));
        r.finished = Some(SimTime::from_secs(3.0));
        let rec = CompletionRecord::of(&r, false);
        assert_eq!(rec.ttft(), r.ttft());
        assert_eq!(rec.tpot(), r.tpot());
        assert_eq!(rec.e2e_latency(), r.e2e_latency());
        assert_eq!(rec.generated_tokens(), r.generated_tokens());
        assert!(!rec.failed);
        // failure keeps the identity but marks the record
        let failed = CompletionRecord::of(&r, true);
        assert!(failed.failed);
        assert_eq!(failed.id, r.id);
    }
}
