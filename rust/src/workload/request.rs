//! Request model (paper §III-F): a request is a pipeline of stages with
//! distinct compute/memory demands, plus the per-stage and per-token
//! timestamps the metrics layer aggregates.

use crate::sim::SimTime;

pub type ReqId = u64;

/// RAG stage parameters (paper §IV-B defaults: IVF-PQ with 4M centroids,
/// 50 probes, 5K points per probe; 20 docs × 512 tokens retrieved).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RagParams {
    /// tokens embedded by the encoder (the user query)
    pub query_tokens: usize,
    /// documents returned after re-ranking
    pub docs: usize,
    /// tokens per document appended to the prompt
    pub doc_tokens: usize,
    pub centroids: f64,
    pub nprobe: usize,
    pub points_per_probe: usize,
}

impl Default for RagParams {
    fn default() -> RagParams {
        RagParams {
            query_tokens: 128,
            docs: 20,
            doc_tokens: 512,
            centroids: 4e6,
            nprobe: 50,
            points_per_probe: 5000,
        }
    }
}

impl RagParams {
    /// Context tokens the RAG stage prepends to the prompt.
    pub fn context_tokens(&self) -> usize {
        self.docs * self.doc_tokens
    }
}

/// KV-cache retrieval stage parameters (§V-A: 3K cached tokens; Fig 15:
/// 4K short / 24K long).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvParams {
    /// past-context tokens whose KV is fetched instead of recomputed
    pub cached_tokens: usize,
}

/// One stage of the inference pipeline (Fig 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Stage {
    /// tokenization/padding on a preprocessing client
    Preprocess,
    /// embedding + retrieval + re-rank on a RAG client
    Rag(RagParams),
    /// fetch past KV from the memory hierarchy on a KV-retrieval client
    KvRetrieval(KvParams),
    /// prompt processing on an LLM client (possibly chunked)
    Prefill,
    /// autoregressive generation on an LLM client
    Decode,
    /// detokenize + guard-model filtering on a postprocessing client
    Postprocess,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Preprocess => "preprocess",
            Stage::Rag(_) => "rag",
            Stage::KvRetrieval(_) => "kv_retrieval",
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
            Stage::Postprocess => "postprocess",
        }
    }
}

/// Timestamps for one completed stage (metrics / Chrome tracing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageRecord {
    pub stage_idx: usize,
    pub client: usize,
    pub start: SimTime,
    pub end: SimTime,
}

/// A request flowing through the simulated serving system.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    pub model: &'static str,
    pub arrival: SimTime,
    /// pipeline definition
    pub stages: Vec<Stage>,
    /// index of the stage currently executing / queued
    pub stage_idx: usize,

    // ---- token accounting -------------------------------------------------
    /// prompt tokens that must be prefilled (RAG context is added on
    /// completion of the RAG stage)
    pub prompt_tokens: usize,
    /// past tokens whose KV was retrieved (attended over, not recomputed)
    pub past_tokens: usize,
    /// decode target per branch
    pub output_tokens: usize,
    /// parallel reasoning branches (1 = single-path); prefill KV shared
    pub branches: usize,

    // ---- runtime state ----------------------------------------------------
    /// prompt tokens already prefilled (chunked batching progresses this)
    pub prefilled: usize,
    /// decode tokens generated per branch
    pub decoded: usize,
    /// client currently holding the request
    pub client: Option<usize>,

    // ---- metrics ----------------------------------------------------------
    /// when the current stage was accepted by its client (set by the
    /// coordinator on push; used for stage span records)
    pub stage_accept: SimTime,
    pub records: Vec<StageRecord>,
    pub first_token_time: Option<SimTime>,
    pub last_token_time: Option<SimTime>,
    pub finished: Option<SimTime>,
}

impl Request {
    pub fn new(
        id: ReqId,
        model: &'static str,
        arrival: SimTime,
        stages: Vec<Stage>,
        prompt_tokens: usize,
        output_tokens: usize,
    ) -> Request {
        assert!(!stages.is_empty());
        assert!(prompt_tokens > 0 && output_tokens > 0);
        Request {
            id,
            model,
            arrival,
            stages,
            stage_idx: 0,
            prompt_tokens,
            past_tokens: 0,
            output_tokens,
            branches: 1,
            prefilled: 0,
            decoded: 0,
            client: None,
            stage_accept: SimTime::ZERO,
            records: Vec::new(),
            first_token_time: None,
            last_token_time: None,
            finished: None,
        }
    }

    pub fn stage(&self) -> Stage {
        self.stages[self.stage_idx]
    }

    pub fn is_last_stage(&self) -> bool {
        self.stage_idx + 1 == self.stages.len()
    }

    /// Advance the pipeline, applying stage side effects (RAG context
    /// growth). KV-retrieval outcomes are applied by the retrieval client
    /// via [`Request::apply_kv_retrieval`] because they depend on the
    /// sampled hit/recompute result. Returns false if that was the final
    /// stage.
    pub fn advance_stage(&mut self) -> bool {
        if let Stage::Rag(p) = self.stage() {
            self.prompt_tokens += p.context_tokens();
        }
        if self.is_last_stage() {
            return false;
        }
        self.stage_idx += 1;
        true
    }

    /// Record the KV-retrieval stage outcome: a hit credits the cached
    /// context as `past_tokens` (attended over, not recomputed); a full
    /// miss means the context must be *recomputed* — it joins the prompt
    /// and will be prefilled (paper §III-E.3).
    pub fn apply_kv_retrieval(&mut self, cached_tokens: usize, hit: bool) {
        if hit {
            self.past_tokens += cached_tokens;
        } else {
            self.prompt_tokens += cached_tokens;
        }
    }

    // ---- scheduler-facing accounting ---------------------------------------

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> usize {
        self.prompt_tokens.saturating_sub(self.prefilled)
    }

    pub fn prefill_complete(&self) -> bool {
        self.prefill_remaining() == 0
    }

    /// Decode tokens still to generate (per branch).
    pub fn decode_remaining(&self) -> usize {
        self.output_tokens.saturating_sub(self.decoded)
    }

    pub fn decode_complete(&self) -> bool {
        self.decode_remaining() == 0
    }

    /// Sequences this request contributes to a decode batch.
    pub fn decode_seqs(&self) -> usize {
        self.branches
    }

    /// KV-cache tokens currently held for this request: shared prefix
    /// (past + prefilled prompt) counted once + per-branch decode chains.
    pub fn kv_tokens(&self) -> f64 {
        (self.past_tokens + self.prefilled) as f64 + (self.branches * self.decoded) as f64
    }

    /// KV footprint when decode finishes — used for admission control.
    pub fn kv_tokens_peak(&self) -> f64 {
        (self.past_tokens + self.prompt_tokens) as f64
            + (self.branches * self.output_tokens) as f64
    }

    /// Total context a decode step attends over, per branch.
    pub fn decode_ctx(&self) -> f64 {
        (self.past_tokens + self.prompt_tokens + self.decoded) as f64
    }

    /// "Work left" metric for Least-Work-Left packing / load routing.
    pub fn work_left_tokens(&self) -> f64 {
        self.prefill_remaining() as f64
            + (self.decode_remaining() * self.branches) as f64
    }

    // ---- latency metrics ----------------------------------------------------

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_time
            .map(|t| (t - self.arrival).as_secs())
    }

    /// Time per output token after the first (s/token).
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_time, self.last_token_time) {
            (Some(a), Some(b)) if self.decoded > 1 => {
                Some((b - a).as_secs() / (self.decoded - 1) as f64)
            }
            _ => None,
        }
    }

    pub fn e2e_latency(&self) -> Option<f64> {
        self.finished.map(|t| (t - self.arrival).as_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn req(stages: Vec<Stage>) -> Request {
        Request::new(1, "llama3-70b", SimTime::ZERO, stages, 1000, 200)
    }

    #[test]
    fn pipeline_advances_with_side_effects() {
        let mut r = req(vec![
            Stage::Rag(RagParams::default()),
            Stage::Prefill,
            Stage::Decode,
        ]);
        assert_eq!(r.stage(), Stage::Rag(RagParams::default()));
        assert!(r.advance_stage());
        // RAG added 20 × 512 = 10240 context tokens (Fig 9 setup)
        assert_eq!(r.prompt_tokens, 1000 + 10_240);
        assert_eq!(r.stage(), Stage::Prefill);
        assert!(r.advance_stage());
        assert_eq!(r.stage(), Stage::Decode);
        assert!(!r.advance_stage());
    }

    #[test]
    fn kv_retrieval_hit_adds_past_tokens() {
        let mut r = req(vec![
            Stage::KvRetrieval(KvParams { cached_tokens: 3000 }),
            Stage::Prefill,
            Stage::Decode,
        ]);
        r.apply_kv_retrieval(3000, true);
        r.advance_stage();
        assert_eq!(r.past_tokens, 3000);
        // prefill unchanged — cached context is NOT recomputed (paper §V-A)
        assert_eq!(r.prompt_tokens, 1000);
        assert_eq!(r.decode_ctx(), 4000.0);
    }

    #[test]
    fn kv_retrieval_miss_recomputes_context() {
        let mut r = req(vec![
            Stage::KvRetrieval(KvParams { cached_tokens: 3000 }),
            Stage::Prefill,
            Stage::Decode,
        ]);
        r.apply_kv_retrieval(3000, false);
        assert_eq!(r.past_tokens, 0);
        assert_eq!(r.prompt_tokens, 4000, "missed context joins the prompt");
    }

    #[test]
    fn prefill_and_decode_progress() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        assert_eq!(r.prefill_remaining(), 1000);
        r.prefilled += 512;
        assert_eq!(r.prefill_remaining(), 488);
        assert!(!r.prefill_complete());
        r.prefilled = 1000;
        assert!(r.prefill_complete());
        r.decoded = 200;
        assert!(r.decode_complete());
    }

    #[test]
    fn multipath_reasoning_kv_accounting() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        r.branches = 8;
        r.prefilled = 1000;
        r.decoded = 100;
        // shared prefix once + 8 branches × 100 decode tokens
        assert_eq!(r.kv_tokens(), 1000.0 + 800.0);
        assert_eq!(r.kv_tokens_peak(), 1000.0 + 8.0 * 200.0);
        assert_eq!(r.decode_seqs(), 8);
        assert_eq!(r.work_left_tokens(), 100.0 * 8.0);
    }

    #[test]
    fn latency_metrics() {
        let mut r = req(vec![Stage::Prefill, Stage::Decode]);
        assert_eq!(r.ttft(), None);
        r.first_token_time = Some(SimTime::from_secs(0.5));
        r.last_token_time = Some(SimTime::from_secs(2.5));
        r.decoded = 201;
        r.finished = Some(SimTime::from_secs(3.0));
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.tpot().unwrap() - 0.01).abs() < 1e-12);
        assert!((r.e2e_latency().unwrap() - 3.0).abs() < 1e-12);
    }
}
