//! Streaming (lazy) workload generation — the O(in-flight) arrival path.
//!
//! `WorkloadSpec::generate` / `WorkloadMix::generate` materialize the
//! whole request stream upfront, so the event queue and the request
//! pool hold the entire trace at t=0 — memory O(total requests) before
//! the first event fires. [`StreamingMix`] generates the same stream
//! lazily: each workload class keeps an O(1) incremental arrival
//! generator ([`ArrivalTimes`](crate::util::rng::ArrivalTimes)) plus a
//! token-sampling rng pre-advanced past the class's timestamp draws,
//! and the mix holds **at most one pending request per class stream**,
//! merged by `(arrival, id)` — exactly the sort order of
//! `WorkloadMix::generate`.
//!
//! The laziness is behaviorally invisible: both paths consume the same
//! PCG streams draw-for-draw, so the emitted requests are bit-identical
//! to eager generation (pinned by the differential tests below and by
//! `rust/tests/retirement_equivalence.rs` end to end). The coordinator
//! drives this through
//! [`Coordinator::stream`](crate::coordinator::Coordinator::stream);
//! see docs/performance.md ("Memory model").

use super::request::Request;
use super::trace::{WorkloadMix, WorkloadSpec};
use crate::sim::SimTime;
use crate::util::rng::{ArrivalTimes, Pcg};

/// Lazily generates one workload class's requests in id (= arrival)
/// order, bit-identical to `spec.generate(id_base)`.
pub struct ClassStream {
    spec: WorkloadSpec,
    times: ArrivalTimes,
    /// token-sampling stream, pre-advanced past the class's `n`
    /// timestamp draws (where `generate`'s single rng would sit when it
    /// starts sampling)
    rng: Pcg,
    next_idx: usize,
    id_base: u64,
}

impl ClassStream {
    pub fn new(spec: WorkloadSpec, id_base: u64) -> ClassStream {
        let rng = Pcg::new(spec.rng_seed());
        // advance a clone through the exact timestamp draw sequence —
        // O(n) time once, O(1) memory (no timestamp vector is kept)
        let mut sampler = ArrivalTimes::new(spec.arrival.clone(), rng.clone());
        for _ in 0..spec.n_requests {
            sampler.next_time();
        }
        ClassStream {
            times: ArrivalTimes::new(spec.arrival.clone(), rng),
            rng: sampler.into_rng(),
            next_idx: 0,
            id_base,
            spec,
        }
    }
}

impl Iterator for ClassStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.next_idx >= self.spec.n_requests {
            return None;
        }
        let i = self.next_idx;
        self.next_idx += 1;
        let t = self.times.next_time();
        Some(self.spec.sample_request(i, t, self.id_base, &mut self.rng))
    }
}

/// Lazy equivalent of `WorkloadMix::generate`: a k-way merge over the
/// class streams holding one pending request per class. Memory is
/// O(classes) regardless of trace length.
pub struct StreamingMix {
    streams: Vec<ClassStream>,
    /// at most one generated-but-unconsumed request per class
    pending: Vec<Option<Request>>,
    total: usize,
    emitted: usize,
}

impl StreamingMix {
    pub fn new(mix: &WorkloadMix) -> StreamingMix {
        StreamingMix::filtered(mix, |_| true)
    }

    /// A lazy source over the subset of `mix`'s classes selected by
    /// `keep` (by class index). Every kept class draws the same PCG
    /// streams and keeps the same global `id_base` it has in the full
    /// mix, so the union of the per-domain filtered sources of a
    /// sharded run ([`crate::coordinator::shard`]) emits exactly the
    /// requests [`StreamingMix::new`] would — partitioned, not
    /// resampled.
    pub fn filtered(mix: &WorkloadMix, keep: impl Fn(usize) -> bool) -> StreamingMix {
        let mut streams = Vec::with_capacity(mix.classes.len());
        let mut id_base = 0u64;
        let mut total = 0usize;
        for i in 0..mix.classes.len() {
            let spec = mix.class_spec(i);
            let n = spec.n_requests;
            if keep(i) {
                total += n;
                streams.push(ClassStream::new(spec, id_base));
            }
            id_base += n as u64;
        }
        let pending = streams.iter_mut().map(|s| s.next()).collect();
        StreamingMix {
            streams,
            pending,
            total,
            emitted: 0,
        }
    }

    /// Total requests this source will emit over its lifetime.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Requests not yet emitted.
    pub fn remaining(&self) -> usize {
        self.total - self.emitted
    }

    /// Index of the pending request with the smallest `(arrival, id)` —
    /// per-class streams are sorted, so the merge reproduces
    /// `WorkloadMix::generate`'s global sort order exactly (ids are
    /// globally unique, so ties in arrival time are fully ordered).
    fn min_idx(&self) -> Option<usize> {
        self.pending
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.as_ref().map(|r| (i, (r.arrival, r.id))))
            .min_by_key(|(_, key)| *key)
            .map(|(i, _)| i)
    }

    /// Arrival time of the next request, without consuming it.
    pub fn peek_arrival(&self) -> Option<SimTime> {
        self.min_idx()
            .map(|i| self.pending[i].as_ref().unwrap().arrival)
    }
}

impl Iterator for StreamingMix {
    type Item = Request;

    /// Emit the next request (globally sorted by `(arrival, id)`) and
    /// refill that class's pending slot.
    fn next(&mut self) -> Option<Request> {
        let i = self.min_idx()?;
        let r = self.pending[i].take();
        self.pending[i] = self.streams[i].next();
        self.emitted += 1;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Arrival;
    use crate::workload::request::{KvParams, RagParams};
    use crate::workload::trace::{Pipeline, Reasoning, TraceKind};

    fn assert_same_requests(eager: &[Request], mut lazy: impl FnMut() -> Option<Request>) {
        for (i, e) in eager.iter().enumerate() {
            let l = lazy().unwrap_or_else(|| panic!("stream ended early at {i}"));
            assert_eq!(e.id, l.id, "id at {i}");
            assert_eq!(e.arrival, l.arrival, "arrival of {}", e.id);
            assert_eq!(e.model, l.model, "model of {}", e.id);
            assert_eq!(e.prompt_tokens, l.prompt_tokens, "prompt of {}", e.id);
            assert_eq!(e.output_tokens, l.output_tokens, "output of {}", e.id);
            assert_eq!(e.branches, l.branches, "branches of {}", e.id);
            assert_eq!(e.stages, l.stages, "stages of {}", e.id);
        }
        assert!(lazy().is_none(), "stream emitted extra requests");
    }

    #[test]
    fn class_stream_matches_eager_generation() {
        for arrival in [
            Arrival::Poisson { rate: 5.0 },
            Arrival::Uniform { rate: 5.0 },
            Arrival::Normal { rate: 5.0, cv: 0.3 },
            Arrival::Bursty { rate: 5.0, burst_mult: 4.0, calm_s: 2.0, burst_s: 0.5 },
        ] {
            let spec = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 400, 5.0)
                .with_seed(13)
                .with_arrival(arrival)
                .with_reasoning(Reasoning::MultiPath { scale: 2.0, branches: 4 });
            let eager = spec.generate(100);
            let mut stream = ClassStream::new(spec, 100);
            assert_same_requests(&eager, || stream.next());
        }
    }

    #[test]
    fn streaming_mix_matches_eager_merge() {
        let base = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 0, 1.0).with_seed(19);
        let rag = base.clone().with_pipeline(Pipeline::Rag(RagParams {
            docs: 4,
            doc_tokens: 256,
            ..Default::default()
        }));
        let kv = base
            .clone()
            .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 2048 }));
        let mix = WorkloadMix::new(vec![(0.5, base), (0.3, rag), (0.2, kv)]).scaled(300, 6.0);
        let eager = mix.generate();
        let mut stream = StreamingMix::new(&mix);
        assert_eq!(stream.total(), eager.len());
        assert_eq!(stream.peek_arrival(), Some(eager[0].arrival));
        assert_same_requests(&eager, || stream.next());
        assert_eq!(stream.remaining(), 0);
        assert_eq!(stream.peek_arrival(), None);
    }

    #[test]
    fn filtered_streams_partition_the_full_mix() {
        let base = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 0, 1.0).with_seed(23);
        let rag = base.clone().with_pipeline(Pipeline::Rag(RagParams::default()));
        let kv = base
            .clone()
            .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 1024 }));
        let mix = WorkloadMix::new(vec![(0.4, base), (0.4, rag), (0.2, kv)]).scaled(200, 6.0);
        let eager = mix.generate();
        // split classes {0, 2} / {1}: ids, arrivals and token draws must
        // be identical to the corresponding eager requests (same id_base,
        // same PCG streams), and the two halves must cover the mix
        let even = StreamingMix::filtered(&mix, |i| i != 1);
        let odd = StreamingMix::filtered(&mix, |i| i == 1);
        assert_eq!(even.total() + odd.total(), eager.len());
        let mut merged: Vec<Request> = even.chain(odd).collect();
        merged.sort_by_key(|r| (r.arrival, r.id));
        let mut it = merged.into_iter();
        assert_same_requests(&eager, || it.next());
    }

    #[test]
    fn streaming_mix_breaks_exact_arrival_ties_by_id() {
        // two classes on identical Uniform clocks produce *exactly* equal
        // arrival timestamps — the merge must fall back to id order, the
        // same tie-break `WorkloadMix::generate`'s sort applies
        let a = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 50, 4.0)
            .with_seed(7)
            .with_arrival(Arrival::Uniform { rate: 4.0 });
        let b = a.clone();
        let mix = WorkloadMix::new(vec![(1.0, a), (1.0, b)]);
        let eager = mix.generate();
        assert!(
            eager.windows(2).any(|w| w[0].arrival == w[1].arrival),
            "test setup must produce arrival ties"
        );
        let mut stream = StreamingMix::new(&mix);
        assert_same_requests(&eager, || stream.next());
    }
}
