//! Request modeling (paper §III-F): request/stage definitions, synthetic
//! Azure-like traces, reasoning expansion, and arrival processes (the
//! arrival distributions themselves live in `util::rng::Arrival`).

pub mod request;
pub mod stream;
pub mod trace;

pub use request::{CompletionRecord, KvParams, RagParams, ReqId, Request, Stage};
pub use stream::{ClassStream, StreamingMix};
pub use trace::{Pipeline, Reasoning, TraceKind, WorkloadMix, WorkloadSpec};
