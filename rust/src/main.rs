//! HERMES command-line interface.
//!
//!   hermes simulate --config cfg.json [--out metrics.json]
//!                   [--trace trace.json] [--shards K]
//!                   [--metrics exact|sketch] [--quiet]
//!                   [--faults on|off] [--fault-seed N]
//!   hermes sweep    --config cfg.json --rates 1,2,4,8 [--jobs N]
//!                   [--out sweep.json]
//!   hermes scenario <name|path.json> [--fast] [--jobs N] [--out sweep.json]
//!   hermes scenario --list                # registry under scenarios/
//!   hermes bench    [name...] [--fast] [--baseline auto|on|off] [--jobs N]
//!                   [--shards K] [--metrics auto|exact|sketch]
//!                   [--out BENCH_core.json]
//!   hermes experiment <fig5|fig6|fig8|fig9|fig10|fig11|fig12|fig13|fig15|table3|disagg|faults>
//!                   [--fast] [--jobs N]
//!   hermes artifacts                      # list AOT predictor variants
//!
//! Every run is deterministic given the config's seed — including under
//! `--jobs N` (independent runs fan across a bounded worker pool and
//! come back in submission order) and `--shards K` (one run is
//! partitioned into conservative time-window domains): both are
//! bit-identical to the serial `--jobs 1 --shards 1` oracle
//! (docs/performance.md, "Parallel execution" / "Sharded execution").

use anyhow::{bail, Context, Result};

use hermes::bench;
use hermes::config::SimConfig;
use hermes::coordinator::shard::{run_sharded, Arrivals};
use hermes::experiments;
use hermes::metrics::{trace_export, MetricsSink, RunMetrics};
use hermes::runtime::ArtifactBundle;
use hermes::scenario::{runner, Scenario};
use hermes::sim::driver;
use hermes::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("simulate") => simulate(&args),
        Some("sweep") => sweep(&args),
        Some("scenario") => scenario(&args),
        Some("bench") => bench_cmd(&args),
        Some("experiment") => experiment(&args),
        Some("artifacts") => artifacts(&args),
        Some(other) => {
            bail!("unknown subcommand '{other}' (try: simulate, sweep, scenario, bench, experiment, artifacts)")
        }
        None => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("HERMES — heterogeneous multi-stage LLM inference execution simulator");
    println!();
    println!("usage:");
    println!("  hermes simulate --config cfg.json [--out m.json] [--trace t.json] [--shards K] [--metrics exact|sketch] [--faults on|off] [--fault-seed N]");
    println!("  hermes sweep --config cfg.json --rates 1,2,4 [--jobs N] [--out sweep.json]");
    println!("  hermes scenario <name|path.json> [--fast] [--jobs N] [--out sweep.json]   (--list to enumerate)");
    println!("  hermes scenario check             # resolve every scenario's model/policy/npu refs");
    println!("  hermes bench [name...] [--fast] [--baseline auto|on|off] [--jobs N] [--shards K] [--metrics auto|exact|sketch] [--out BENCH_core.json]");
    println!("  hermes experiment <fig5|fig6|fig8|fig9|fig10|fig11|fig12|fig13|fig15|table3|ablations|multimodel|disagg|faults|all> [--fast] [--jobs N]");
    println!("  hermes artifacts");
    println!();
    println!("--jobs N fans independent runs across N worker threads; --shards K");
    println!("partitions one run into K conservative time-window domains. Both are");
    println!("bit-identical to the default serial run (--jobs 1 --shards 1).");
    println!("--metrics sketch streams completions through mergeable quantile");
    println!("sketches (O(1) metrics memory; percentiles within a 1% relative-error");
    println!("bound of the default exact retained-records mode).");
}

/// Parse `--jobs N` (default 1 — the serial bit-exactness oracle).
/// Strict: a malformed or zero value is an error, not a silent
/// fall-back to serial.
fn jobs_arg(args: &Args) -> Result<usize> {
    Ok(args
        .positive_usize("jobs")
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or(1))
}

/// Parse `--shards K` (default 1 — the serial single-queue event loop).
/// Same strictness as `--jobs`: a typo must not silently report serial
/// numbers as sharded ones.
fn shards_arg(args: &Args) -> Result<usize> {
    Ok(args
        .positive_usize("shards")
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or(1))
}

/// Parse `--metrics` against `allowed` (simulate: exact|sketch; bench
/// adds `auto` = defer to each scenario's `extras.metrics`). Strict: a
/// typo must not silently run under the wrong metrics contract.
fn metrics_arg(args: &Args, default: &str, allowed: &[&str]) -> Result<String> {
    args.one_of("metrics", default, allowed).map_err(|e| anyhow::anyhow!(e))
}

fn simulate(args: &Args) -> Result<()> {
    let cfg_path = args.opt_str("config").context("--config required")?;
    let out = args.opt_str("out");
    let trace_out = args.opt_str("trace");
    let quiet = args.bool_or("quiet", false);
    let shards = shards_arg(args)?;
    let sketch = metrics_arg(args, "exact", &["exact", "sketch"])? == "sketch";
    // --faults off disables the config's fault plan without editing the
    // file; --fault-seed re-rolls the fault schedule (crash timing stays
    // scenario-pinned, but stage-failure coin flips and backoff jitter
    // re-draw) while the workload seed stays put
    let faults_off = args.one_of("faults", "on", &["on", "off"]).map_err(|e| anyhow::anyhow!(e))?
        == "off";
    let fault_seed = match args.opt_str("fault-seed") {
        Some(s) => Some(s.parse::<u64>().with_context(|| format!("bad --fault-seed '{s}'"))?),
        None => None,
    };
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    if shards > 1 && trace_out.is_some() {
        // the chrome exporter walks the retained serial coordinator;
        // a sharded run merges per-domain results and keeps none
        bail!("--trace requires the serial event loop; drop --shards or run with --shards 1");
    }

    let mut cfg = SimConfig::from_file(&cfg_path)?;
    if faults_off {
        cfg.serving.faults = None;
    }
    if let Some(seed) = fault_seed {
        match cfg.serving.faults.as_mut() {
            Some(f) => f.seed = seed,
            // strict: overriding a seed that nothing draws from is a
            // typo'd invocation, not a no-op
            None => bail!("--fault-seed given but no fault plan is active (config has no 'faults' block, or --faults off)"),
        }
    }
    if shards > 1 {
        let arrivals = Arrivals::Inject(cfg.workload.generate(0));
        let t0 = std::time::Instant::now();
        // per-domain sinks, merged back in domain order by the sharded
        // harness — percentile sketches are bit-identical to --shards 1
        let outcome = run_sharded(
            || {
                let mut c = cfg.serving.build()?;
                if sketch {
                    c.sink = Some(MetricsSink::new(cfg.slo));
                }
                Ok(c)
            },
            arrivals,
            shards,
        )?;
        let wall = t0.elapsed().as_secs_f64();
        let m = RunMetrics::collect_outcome(&outcome, &cfg.slo);
        if !quiet {
            println!(
                "simulated {:.2}s of serving in {:.3}s wall ({:.0} events/s, {} of {} requested shard domains)",
                m.makespan,
                wall,
                m.events as f64 / wall.max(1e-9),
                outcome.domains,
                outcome.shards,
            );
            print_metrics(&m);
            println!(
                "SLO(all-six): {}",
                if m.slo_satisfied(&cfg.slo) { "SATISFIED" } else { "violated" }
            );
        }
        if let Some(path) = out {
            std::fs::write(&path, m.to_json().to_pretty())?;
            if !quiet {
                println!("metrics -> {path}");
            }
        }
        return Ok(());
    }
    let mut coord = cfg.serving.build()?;
    if sketch {
        // fold completions into the streaming sink at retirement time
        // instead of retaining CompletionRecords
        coord.sink = Some(MetricsSink::new(cfg.slo));
    }
    coord.inject(cfg.workload.generate(0));
    let t0 = std::time::Instant::now();
    coord.run();
    let wall = t0.elapsed().as_secs_f64();
    let m = RunMetrics::collect(&coord, &cfg.slo);

    if !quiet {
        println!(
            "simulated {:.2}s of serving in {:.3}s wall ({:.0} events/s)",
            m.makespan,
            wall,
            m.events as f64 / wall.max(1e-9)
        );
        print_metrics(&m);
        println!(
            "SLO(all-six): {}",
            if m.slo_satisfied(&cfg.slo) {
                "SATISFIED"
            } else {
                "violated"
            }
        );
    }
    if let Some(path) = out {
        std::fs::write(&path, m.to_json().to_pretty())?;
        if !quiet {
            println!("metrics -> {path}");
        }
    }
    if let Some(path) = trace_out {
        std::fs::write(&path, trace_export::chrome_trace(&coord).to_string())?;
        if !quiet {
            println!("chrome trace -> {path} (open in chrome://tracing)");
        }
    }
    Ok(())
}

fn print_metrics(m: &RunMetrics) {
    println!(
        "  serviced {}/{} (failed {})  makespan {:.2}s",
        m.n_serviced, m.n_requests, m.n_failed, m.makespan
    );
    println!(
        "  TTFT  mean {:.1}ms  p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms",
        m.ttft.mean * 1e3,
        m.ttft.p50 * 1e3,
        m.ttft.p90 * 1e3,
        m.ttft.p99 * 1e3
    );
    println!(
        "  TPOT  mean {:.2}ms  p50 {:.2}ms  p90 {:.2}ms  p99 {:.2}ms",
        m.tpot.mean * 1e3,
        m.tpot.p50 * 1e3,
        m.tpot.p90 * 1e3,
        m.tpot.p99 * 1e3
    );
    println!(
        "  E2E   mean {:.2}s  p50 {:.2}s  p99 {:.2}s",
        m.e2e.mean, m.e2e.p50, m.e2e.p99
    );
    println!(
        "  throughput {:.0} tok/s   goodput {:.0}%   energy {:.1} kJ   {:.2} tok/J",
        m.throughput_tok_s,
        m.goodput_frac * 100.0,
        m.energy_joules / 1e3,
        m.tok_per_joule
    );
    if m.retries + m.timeouts + m.shed + m.orphaned > 0 || m.availability < 1.0 {
        println!(
            "  faults: retries {}  timeouts {}  shed {}  orphaned {}   availability {:.2}%",
            m.retries,
            m.timeouts,
            m.shed,
            m.orphaned,
            m.availability * 100.0
        );
    }
}

fn sweep(args: &Args) -> Result<()> {
    let cfg_path = args.opt_str("config").context("--config required")?;
    let rates: Vec<f64> = args
        .str_or("rates", "0.5,1,2,4")
        .split(',')
        .map(|s| s.trim().parse::<f64>().context("bad rate"))
        .collect::<Result<_>>()?;
    let out = args.opt_str("out");
    hermes::sim::parallel::set_jobs(jobs_arg(args)?);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let cfg = SimConfig::from_file(&cfg_path)?;
    let points = driver::sweep_rates(&cfg.serving, &cfg.workload, &cfg.slo, &rates)?;
    println!("rate_per_client  throughput_tok_s  ttft_p99_ms  tpot_p99_ms  slo");
    let mut doc_rows = Vec::new();
    for p in &points {
        println!(
            "{:>15.2}  {:>16.0}  {:>11.1}  {:>11.2}  {}",
            p.rate,
            p.metrics.throughput_tok_s,
            p.metrics.ttft.p99 * 1e3,
            p.metrics.tpot.p99 * 1e3,
            if p.slo_ok { "ok" } else { "VIOLATED" }
        );
        let mut row = p.metrics.to_json();
        row.set("rate", p.rate).set("slo_ok", p.slo_ok);
        doc_rows.push(row);
    }
    if let Some(best) = driver::best_under_slo(&points) {
        println!(
            "best under SLO: rate {:.2} -> {:.0} tok/s",
            best.rate, best.metrics.throughput_tok_s
        );
    } else {
        println!("no swept rate satisfies all six SLOs");
    }
    if let Some(path) = out {
        std::fs::write(&path, hermes::util::json::Json::Arr(doc_rows).to_pretty())?;
        println!("sweep -> {path}");
    }
    Ok(())
}

/// Run a declarative scenario file: sweep every batching strategy in its
/// roster across its rate ladder and print the paper-style table. New
/// scenarios need only a JSON file — no Rust.
fn scenario(args: &Args) -> Result<()> {
    if args.bool_or("list", false) {
        args.finish().map_err(|e| anyhow::anyhow!(e))?;
        println!("scenarios in {}:", Scenario::dir().display());
        for name in Scenario::list() {
            match Scenario::load(&name) {
                Ok(sc) => {
                    let figure = sc.figure.clone().map(|f| format!(" [{f}]")).unwrap_or_default();
                    println!("  {name:<16} {}{figure}", sc.title);
                }
                Err(e) => println!("  {name:<16} (unreadable: {e})"),
            }
        }
        return Ok(());
    }
    let which = args
        .positional
        .first()
        .cloned()
        .context("scenario name or path required (see `hermes scenario --list`)")?;
    if which == "check" {
        args.finish().map_err(|e| anyhow::anyhow!(e))?;
        return scenario_check();
    }
    let fast = args.bool_or("fast", false);
    let out = args.opt_str("out");
    hermes::sim::parallel::set_jobs(jobs_arg(args)?);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let sc = Scenario::load(&which)?;
    let scale = sc.scale(fast);
    println!(
        "scenario '{}' — {} ({} clients, rates {:?})",
        sc.name, sc.title, scale.clients, scale.rates
    );
    let mut doc_rows = Vec::new();
    for panel in sc.panels_or_default() {
        let results = runner::sweep(&sc, Some(&panel), fast)?;
        let caption = if panel.label.is_empty() {
            sc.title.clone()
        } else {
            format!("{} — {}", sc.title, panel.label)
        };
        hermes::experiments::common::print_normalized(&results, &caption);
        for r in &results {
            for p in &r.points {
                let mut row = p.metrics.to_json();
                row.set("strategy", r.label.clone())
                    .set("panel", panel.label.clone())
                    .set("rate", p.rate)
                    .set("slo_ok", p.slo_ok);
                doc_rows.push(row);
            }
        }
    }
    if let Some(path) = out {
        std::fs::write(&path, hermes::util::json::Json::Arr(doc_rows).to_pretty())?;
        println!("sweep -> {path}");
    }
    Ok(())
}

/// `hermes scenario check`: parse every file under `scenarios/` and
/// resolve all model / model-policy / NPU / storage references down to
/// constructed clients at both scales. Exits non-zero on the first
/// pass if any scenario has a dangling reference — wired into CI so a
/// renamed model or policy can't break a scenario silently.
fn scenario_check() -> Result<()> {
    let names = Scenario::list();
    if names.is_empty() {
        bail!("no scenarios found under {}", Scenario::dir().display());
    }
    let mut failures = 0usize;
    for name in &names {
        let outcome = Scenario::load(name).and_then(|sc| sc.check());
        match outcome {
            Ok(()) => println!("  {name:<24} OK"),
            Err(e) => {
                failures += 1;
                println!("  {name:<24} FAILED: {e:#}");
            }
        }
    }
    if failures > 0 {
        bail!("{failures}/{} scenarios failed the reference check", names.len());
    }
    println!("all {} scenarios resolve cleanly", names.len());
    Ok(())
}

/// Run the core-speed benchmark scenarios (`scenarios/bench_*.json` by
/// default), print a summary table and write `BENCH_core.json` — the
/// perf trajectory every PR defends (docs/performance.md).
///
/// `--baseline` gates both reference configurations: the hashmap-pool
/// run (pre-arena pool; cheap, on unless `off`) and the full-scan run
/// (pre-incremental routing; hours at 100k+ scale, so `auto` defers to
/// the scenario's `extras.baseline`).
fn bench_cmd(args: &Args) -> Result<()> {
    // the parser reads `--fast <name>` as fast="<name>" (its documented
    // boolean/positional ambiguity); at bench scale that silently swaps
    // an hours-long paper run for a seconds smoke, so reject it loudly
    match args.str_or("fast", "false").as_str() {
        "true" | "false" | "1" | "0" | "yes" | "no" => {}
        other => bail!(
            "--fast takes no value (got '{other}'); put scenario names first: hermes bench {other} --fast"
        ),
    }
    let fast = args.bool_or("fast", false);
    let out = args.str_or("out", "BENCH_core.json");
    let baseline = match args.str_or("baseline", "auto").as_str() {
        "auto" => bench::Baseline::Auto,
        "on" | "true" | "1" | "yes" => bench::Baseline::On,
        "off" | "false" | "0" | "no" => bench::Baseline::Off,
        other => bail!("--baseline must be auto|on|off, got '{other}'"),
    };
    let jobs = jobs_arg(args)?;
    let shards = shards_arg(args)?;
    // `auto` defers to each scenario's `extras.metrics` (the 100M tier
    // ships "sketch"); exact|sketch force the mode across every scenario
    let metrics = match metrics_arg(args, "auto", &["auto", "exact", "sketch"])?.as_str() {
        "exact" => bench::MetricsOverride::Exact,
        "sketch" => bench::MetricsOverride::Sketch,
        _ => bench::MetricsOverride::Auto,
    };
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let names = if args.positional.is_empty() {
        bench::bench_scenarios()
    } else {
        args.positional.clone()
    };
    if names.is_empty() {
        bail!("no bench_* scenarios found under scenarios/");
    }

    bench::run_and_report(&names, fast, baseline, jobs, shards, metrics, &out)?;
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .cloned()
        .context("experiment name required (fig5..fig15, table3)")?;
    let fast = args.bool_or("fast", false);
    // experiments reach their sweeps through deeply nested fig*
    // wrappers, so the job count travels via the process-wide knob
    // instead of a parameter on every signature
    hermes::sim::parallel::set_jobs(jobs_arg(args)?);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    experiments::run_by_name(&which, fast)
}

fn artifacts(args: &Args) -> Result<()> {
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let dir = ArtifactBundle::default_dir();
    let bundle = ArtifactBundle::open(&dir)?;
    println!("artifact bundle at {} :", dir.display());
    for key in bundle.variant_keys() {
        let c = &bundle.coefficients;
        let mse_dec = c
            .at(&[&key, "mse_dec"])
            .and_then(|j| j.as_f64())
            .unwrap_or(0.0);
        let mse_pf = c
            .at(&[&key, "mse_pf"])
            .and_then(|j| j.as_f64())
            .unwrap_or(0.0);
        println!("  {key:<28} mse_dec={mse_dec:.2e}  mse_pf={mse_pf:.2e}");
    }
    Ok(())
}
