//! Deterministic fault injection and failure recovery
//! (docs/robustness.md).
//!
//! A scenario's `faults` block compiles into a [`FaultPlan`]: client
//! crash/recover windows, client slowdown windows, link outage or
//! degradation windows on rack egress paths, and a per-hand-off
//! transient failure probability — plus the [`RetryPolicy`] and
//! load-shedding switch the recovery machinery uses. Every query is a
//! **pure function of simulated time and request identity**:
//!
//! * window queries ([`FaultPlan::health_at`],
//!   [`FaultPlan::slowdown_at`], [`FaultPlan::link_outage_at`],
//!   [`FaultPlan::link_degrade_at`]) read precompiled `[start, end)`
//!   intervals, and
//! * stochastic draws ([`FaultPlan::stage_fails`],
//!   [`FaultPlan::backoff_delay`]) each derive a fresh one-shot
//!   [`Pcg`] stream keyed by `(fault_seed, request, site, kind)`.
//!
//! Nothing depends on event interleaving or shared RNG state, so the
//! same plan produces bit-identical fault schedules in the serial event
//! loop, under `--jobs N` (independent runs) and across `--shards K`
//! conservative-window domains — `rust/tests/fault_equivalence.rs`
//! pins this.

use anyhow::{bail, Result};

use crate::sim::SimTime;
use crate::util::rng::Pcg;
use crate::workload::request::ReqId;

/// Bounded exponential backoff for retried hand-offs and re-routed
/// orphans: attempt `k` (1-based) waits
/// `base * factor^(k-1) * (1 + jitter * (u - 0.5))` seconds, with `u`
/// drawn from the per-(request, attempt) stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// total tries a request gets (1 = no retries)
    pub max_attempts: u32,
    /// first backoff in seconds
    pub base: f64,
    /// exponential growth per attempt
    pub factor: f64,
    /// relative jitter amplitude in [0, 1] (0 = deterministic delays;
    /// still seed-deterministic when positive)
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 3, base: 0.05, factor: 2.0, jitter: 0.5 }
    }
}

/// A client crash window: the client is dark over `[at, at + down_for)`
/// seconds; at the crash instant its resident requests are evicted and
/// re-routed (or shed), and at recovery it simply becomes routable
/// again.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashSpec {
    pub client: usize,
    pub at: f64,
    pub down_for: f64,
}

/// A client slowdown window: engine steps *started* inside
/// `[at, at + dur)` take `factor` times as long (straggler modeling —
/// thermal throttling, a noisy neighbor, a failed NIC lane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownSpec {
    pub client: usize,
    pub factor: f64,
    pub at: f64,
    pub dur: f64,
}

/// A network fault window on a rack's egress paths over
/// `[at, at + dur)`: `degrade: Some(f)` multiplies the bytes of every
/// hand-off leaving the rack by `f` (a brown-out); `degrade: None` is a
/// full outage — hand-offs stall and retry with backoff until the
/// window passes or attempts run out.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFaultSpec {
    pub rack: usize,
    pub at: f64,
    pub dur: f64,
    pub degrade: Option<f64>,
}

/// The scenario-facing fault description (the `faults` config key),
/// validated structurally at parse time and against the serving pool
/// at build time ([`FaultPlan::compile`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// seed for the per-decision PCG streams (defaults to the serving
    /// seed; `--fault-seed` overrides)
    pub seed: u64,
    pub crashes: Vec<CrashSpec>,
    pub slowdowns: Vec<SlowdownSpec>,
    pub links: Vec<LinkFaultSpec>,
    /// probability that any single stage hand-off transiently fails
    /// and must be retried (drawn per (request, stage, attempt))
    pub stage_failure_prob: f64,
    pub retry: RetryPolicy,
    /// shed a request immediately when no healthy candidate exists for
    /// its next stage, instead of backoff-retrying the placement
    pub shed: bool,
}

impl FaultSpec {
    pub fn new(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            crashes: Vec::new(),
            slowdowns: Vec::new(),
            links: Vec::new(),
            stage_failure_prob: 0.0,
            retry: RetryPolicy::default(),
            shed: false,
        }
    }
}

/// One compiled fault window: `(target, [start, end))` plus the
/// window's payload.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Window {
    target: usize,
    start: SimTime,
    end: SimTime,
    /// slowdown factor / degrade factor; outage windows carry
    /// `f64::INFINITY` as their marker
    factor: f64,
}

impl Window {
    fn covers(&self, t: SimTime, target: usize) -> bool {
        self.target == target && self.start <= t && t < self.end
    }
}

// per-decision stream kinds — mixed into the PCG key so the hand-off
// failure draw and the backoff jitter draw of the same (request,
// attempt) never alias
const KIND_STAGE_FAIL: u64 = 0x53;
const KIND_BACKOFF: u64 = 0x42;

/// Boost-style hash combine; the constant is the same golden-ratio
/// increment `Pcg::fork` mixes with. [`Pcg::new`] runs SplitMix64 over
/// the result, so this only needs to separate keys, not distribute
/// them.
fn mix(h: u64, v: u64) -> u64 {
    h ^ v
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(h << 6)
        .wrapping_add(h >> 2)
}

/// A validated, precompiled fault schedule. Cheap to clone (a few
/// windows), carried by every coordinator of a run — each sharded
/// domain holds an identical copy, which is what makes the pure
/// time/identity queries agree everywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    crashes: Vec<Window>,
    slowdowns: Vec<Window>,
    links: Vec<Window>,
    stage_failure_prob: f64,
    pub retry: RetryPolicy,
    pub shed: bool,
    /// client count the plan was validated against (availability
    /// denominators)
    n_clients: usize,
}

impl FaultPlan {
    /// Validate `spec` against a serving pool of `n_clients` clients on
    /// `n_racks` racks and precompile its windows. Every structural
    /// error — an out-of-range client/rack, a probability outside
    /// [0, 1], a non-finite or non-positive time — is a build error, so
    /// `hermes scenario check` rejects dangling fault targets exactly
    /// like dangling model or NPU names.
    pub fn compile(spec: &FaultSpec, n_clients: usize, n_racks: usize) -> Result<FaultPlan> {
        let window = |what: &str, at: f64, dur: f64| -> Result<(SimTime, SimTime)> {
            if !at.is_finite() || at < 0.0 {
                bail!("faults: {what} start {at} must be finite and >= 0");
            }
            if !dur.is_finite() || dur <= 0.0 {
                bail!("faults: {what} duration {dur} must be finite and > 0");
            }
            Ok((SimTime::from_secs(at), SimTime::from_secs(at + dur)))
        };
        let mut crashes = Vec::with_capacity(spec.crashes.len());
        for c in &spec.crashes {
            if c.client >= n_clients {
                bail!("faults: crash targets client {} but the pool has {n_clients}", c.client);
            }
            let (start, end) = window("crash", c.at, c.down_for)?;
            crashes.push(Window { target: c.client, start, end, factor: f64::INFINITY });
        }
        let mut slowdowns = Vec::with_capacity(spec.slowdowns.len());
        for s in &spec.slowdowns {
            if s.client >= n_clients {
                bail!(
                    "faults: slowdown targets client {} but the pool has {n_clients}",
                    s.client
                );
            }
            if !s.factor.is_finite() || s.factor < 1.0 {
                bail!("faults: slowdown factor {} must be finite and >= 1", s.factor);
            }
            let (start, end) = window("slowdown", s.at, s.dur)?;
            slowdowns.push(Window { target: s.client, start, end, factor: s.factor });
        }
        let mut links = Vec::with_capacity(spec.links.len());
        for l in &spec.links {
            if l.rack >= n_racks {
                bail!("faults: link fault targets rack {} but the topology has {n_racks}", l.rack);
            }
            let factor = match l.degrade {
                Some(f) => {
                    if !f.is_finite() || f < 1.0 {
                        bail!("faults: link degrade factor {f} must be finite and >= 1");
                    }
                    f
                }
                None => f64::INFINITY,
            };
            let (start, end) = window("link fault", l.at, l.dur)?;
            links.push(Window { target: l.rack, start, end, factor });
        }
        if !(0.0..=1.0).contains(&spec.stage_failure_prob) {
            bail!(
                "faults: stage failure probability {} must be in [0, 1]",
                spec.stage_failure_prob
            );
        }
        let r = spec.retry;
        if r.max_attempts == 0 {
            bail!("faults: retry max_attempts must be >= 1");
        }
        if !r.base.is_finite() || r.base <= 0.0 {
            bail!("faults: retry base {} must be finite and > 0", r.base);
        }
        if !r.factor.is_finite() || r.factor < 1.0 {
            bail!("faults: retry factor {} must be finite and >= 1", r.factor);
        }
        if !(0.0..=1.0).contains(&r.jitter) {
            bail!("faults: retry jitter {} must be in [0, 1]", r.jitter);
        }
        Ok(FaultPlan {
            seed: spec.seed,
            crashes,
            slowdowns,
            links,
            stage_failure_prob: spec.stage_failure_prob,
            retry: r,
            shed: spec.shed,
            n_clients,
        })
    }

    /// Is `client` up at `t`? (No crash window covers the instant.)
    pub fn health_at(&self, t: SimTime, client: usize) -> bool {
        !self.crashes.iter().any(|w| w.covers(t, client))
    }

    /// Step-duration multiplier for a step `client` starts at `t`
    /// (1.0 = nominal; overlapping windows take the worst factor).
    pub fn slowdown_at(&self, t: SimTime, client: usize) -> f64 {
        self.slowdowns
            .iter()
            .filter(|w| w.covers(t, client))
            .fold(1.0, |acc, w| acc.max(w.factor))
    }

    /// Is `rack`'s egress path fully out at `t`?
    pub fn link_outage_at(&self, t: SimTime, rack: usize) -> bool {
        self.links.iter().any(|w| w.covers(t, rack) && w.factor.is_infinite())
    }

    /// Byte multiplier for hand-offs leaving `rack` at `t` (1.0 =
    /// nominal; outage windows are handled by
    /// [`FaultPlan::link_outage_at`] and excluded here).
    pub fn link_degrade_at(&self, t: SimTime, rack: usize) -> f64 {
        self.links
            .iter()
            .filter(|w| w.covers(t, rack) && w.factor.is_finite())
            .fold(1.0, |acc, w| acc.max(w.factor))
    }

    /// Does the hand-off of request `id` out of stage `stage_idx` on
    /// try `attempt` transiently fail? A fresh one-shot PCG stream per
    /// decision: independent of event interleaving, so sharded domains
    /// agree with the serial oracle.
    pub fn stage_fails(&self, id: ReqId, stage_idx: usize, attempt: u32) -> bool {
        if self.stage_failure_prob <= 0.0 {
            return false;
        }
        let key = mix(
            mix(mix(self.seed, KIND_STAGE_FAIL), id),
            ((stage_idx as u64) << 32) | attempt as u64,
        );
        Pcg::new(key).chance(self.stage_failure_prob)
    }

    /// Backoff before try `attempt` (1-based: the first retry is
    /// attempt 1) of request `id`, in seconds. Always finite and
    /// strictly positive (jitter is capped at ±50% of the nominal
    /// delay).
    pub fn backoff_delay(&self, id: ReqId, attempt: u32) -> f64 {
        let r = self.retry;
        let nominal = r.base * r.factor.powi(attempt.saturating_sub(1) as i32);
        let key = mix(mix(mix(self.seed, KIND_BACKOFF), id), attempt as u64);
        let u = Pcg::new(key).f64();
        nominal * (1.0 + r.jitter * (u - 0.5))
    }

    /// Crash instants as `(time, crash index)`, for the coordinator to
    /// arm `Event::Fault` entries (sharded runs arm only the crashes of
    /// domain-owned clients; the union across domains equals the serial
    /// schedule).
    pub fn crash_events(&self) -> impl Iterator<Item = (SimTime, usize)> + '_ {
        self.crashes.iter().enumerate().map(|(i, w)| (w.start, i))
    }

    /// The client crash window `idx` targets.
    pub fn crash_client(&self, idx: usize) -> usize {
        self.crashes[idx].target
    }

    /// Mean per-client availability over `[0, horizon)`: one minus the
    /// crashed client-seconds (overlapping windows merged per client)
    /// over the total client-seconds. 1.0 for an empty horizon or a
    /// crash-free plan.
    pub fn availability(&self, horizon: SimTime) -> f64 {
        let h = horizon.as_secs();
        if h <= 0.0 || self.n_clients == 0 || self.crashes.is_empty() {
            return 1.0;
        }
        let mut down = 0.0;
        for client in 0..self.n_clients {
            let mut spans: Vec<(f64, f64)> = self
                .crashes
                .iter()
                .filter(|w| w.target == client)
                .map(|w| (w.start.as_secs().min(h), w.end.as_secs().min(h)))
                .filter(|(s, e)| e > s)
                .collect();
            spans.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut cursor = 0.0;
            for (s, e) in spans {
                let s = s.max(cursor);
                if e > s {
                    down += e - s;
                    cursor = e;
                }
            }
        }
        1.0 - down / (h * self.n_clients as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FaultSpec {
        let mut s = FaultSpec::new(7);
        s.crashes.push(CrashSpec { client: 1, at: 10.0, down_for: 5.0 });
        s.slowdowns.push(SlowdownSpec { client: 0, factor: 2.0, at: 3.0, dur: 4.0 });
        s.links.push(LinkFaultSpec { rack: 0, at: 20.0, dur: 2.0, degrade: None });
        s.links.push(LinkFaultSpec { rack: 1, at: 20.0, dur: 2.0, degrade: Some(4.0) });
        s.stage_failure_prob = 0.25;
        s
    }

    #[test]
    fn windows_are_half_open() {
        let p = FaultPlan::compile(&spec(), 4, 2).unwrap();
        assert!(p.health_at(SimTime::from_secs(9.999), 1));
        assert!(!p.health_at(SimTime::from_secs(10.0), 1));
        assert!(!p.health_at(SimTime::from_secs(14.999), 1));
        assert!(p.health_at(SimTime::from_secs(15.0), 1));
        // other clients are untouched
        assert!(p.health_at(SimTime::from_secs(12.0), 0));
        assert_eq!(p.slowdown_at(SimTime::from_secs(5.0), 0), 2.0);
        assert_eq!(p.slowdown_at(SimTime::from_secs(5.0), 1), 1.0);
        assert_eq!(p.slowdown_at(SimTime::from_secs(8.0), 0), 1.0);
        assert!(p.link_outage_at(SimTime::from_secs(21.0), 0));
        assert!(!p.link_outage_at(SimTime::from_secs(21.0), 1));
        assert_eq!(p.link_degrade_at(SimTime::from_secs(21.0), 1), 4.0);
        assert_eq!(p.link_degrade_at(SimTime::from_secs(23.0), 1), 1.0);
    }

    #[test]
    fn draws_are_pure_functions_of_identity() {
        let p = FaultPlan::compile(&spec(), 4, 2).unwrap();
        let q = FaultPlan::compile(&spec(), 4, 2).unwrap();
        for id in 0..200u64 {
            for attempt in 0..3u32 {
                assert_eq!(p.stage_fails(id, 2, attempt), q.stage_fails(id, 2, attempt));
                let d = p.backoff_delay(id, attempt + 1);
                assert_eq!(d, q.backoff_delay(id, attempt + 1));
                assert!(d.is_finite() && d > 0.0, "backoff must stay positive, got {d}");
            }
        }
        // the failure rate tracks the configured probability
        let hits = (0..2000u64).filter(|&id| p.stage_fails(id, 1, 0)).count();
        let rate = hits as f64 / 2000.0;
        assert!((rate - 0.25).abs() < 0.05, "empirical rate {rate} far from 0.25");
        // distinct sites draw from distinct streams
        assert_ne!(
            (0..64u64).map(|id| p.stage_fails(id, 1, 0)).collect::<Vec<_>>(),
            (0..64u64).map(|id| p.stage_fails(id, 2, 0)).collect::<Vec<_>>(),
        );
        // a different seed reshuffles the schedule
        let mut other = spec();
        other.seed = 8;
        let o = FaultPlan::compile(&other, 4, 2).unwrap();
        assert_ne!(
            (0..256u64).map(|id| p.stage_fails(id, 1, 0)).collect::<Vec<_>>(),
            (0..256u64).map(|id| o.stage_fails(id, 1, 0)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn backoff_grows_exponentially() {
        let mut s = spec();
        s.retry = RetryPolicy { max_attempts: 5, base: 0.1, factor: 2.0, jitter: 0.0 };
        let p = FaultPlan::compile(&s, 4, 2).unwrap();
        assert_eq!(p.backoff_delay(9, 1), 0.1);
        assert_eq!(p.backoff_delay(9, 2), 0.2);
        assert_eq!(p.backoff_delay(9, 3), 0.4);
    }

    #[test]
    fn compile_rejects_bad_specs() {
        let ok = |s: &FaultSpec| FaultPlan::compile(s, 4, 2);
        assert!(ok(&spec()).is_ok());
        let mut s = spec();
        s.crashes[0].client = 4;
        assert!(ok(&s).unwrap_err().to_string().contains("client 4"));
        let mut s = spec();
        s.links[0].rack = 2;
        assert!(ok(&s).unwrap_err().to_string().contains("rack 2"));
        let mut s = spec();
        s.crashes[0].down_for = 0.0;
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.slowdowns[0].factor = 0.5;
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.links[1].degrade = Some(f64::NAN);
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.stage_failure_prob = 1.5;
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.retry.max_attempts = 0;
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.retry.base = -1.0;
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.retry.jitter = 2.0;
        assert!(ok(&s).is_err());
        let mut s = spec();
        s.crashes[0].at = f64::INFINITY;
        assert!(ok(&s).is_err());
    }

    #[test]
    fn availability_merges_overlapping_windows() {
        let mut s = FaultSpec::new(1);
        s.crashes.push(CrashSpec { client: 0, at: 0.0, down_for: 10.0 });
        s.crashes.push(CrashSpec { client: 0, at: 5.0, down_for: 10.0 });
        let p = FaultPlan::compile(&s, 2, 1).unwrap();
        // client 0 is down over [0, 15) of a 20s horizon on a 2-client
        // pool: 15 / 40 client-seconds lost
        let a = p.availability(SimTime::from_secs(20.0));
        assert!((a - (1.0 - 15.0 / 40.0)).abs() < 1e-12, "availability {a}");
        // horizon clamps the second window
        let b = p.availability(SimTime::from_secs(10.0));
        assert!((b - 0.5).abs() < 1e-12, "availability {b}");
        assert_eq!(p.availability(SimTime::ZERO), 1.0);
    }
}
