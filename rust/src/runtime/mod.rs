//! PJRT runtime bridge: load AOT artifacts (`artifacts/*.hlo.txt`) and
//! execute them on the XLA CPU client from the simulator hot path.
//!
//! Interchange format is HLO **text**, not serialized `HloModuleProto` —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Shared PJRT CPU client. Construct once; compile many executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

/// A compiled runtime-predictor executable: f32[rows, n_raw] -> f32[rows, 3]
/// (lowered with return_tuple=True, so the output is a 1-tuple).
pub struct PredictorExe {
    exe: xla::PjRtLoadedExecutable,
    pub rows: usize,
    pub n_raw: usize,
    pub variant: String,
}

impl PredictorExe {
    /// Execute on a row-major feature buffer of exactly `rows * n_raw` f32s.
    pub fn run(&self, features: &[f32]) -> Result<Vec<f32>> {
        if features.len() != self.rows * self.n_raw {
            bail!(
                "feature buffer is {} floats, executable wants {}x{}",
                features.len(),
                self.rows,
                self.n_raw
            );
        }
        let x = xla::Literal::vec1(features).reshape(&[self.rows as i64, self.n_raw as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The artifact bundle produced by `make artifacts` (python/compile/aot.py).
pub struct ArtifactBundle {
    pub dir: PathBuf,
    pub manifest: Json,
    pub coefficients: Json,
}

impl ArtifactBundle {
    /// Default location: `$HERMES_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("HERMES_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn open(dir: &Path) -> Result<ArtifactBundle> {
        let read = |name: &str| -> Result<Json> {
            let path = dir.join(name);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
            Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
        };
        Ok(ArtifactBundle {
            dir: dir.to_path_buf(),
            manifest: read("manifest.json")?,
            coefficients: read("coefficients.json")?,
        })
    }

    /// Variant keys look like "llama3-70b@h100/tp8".
    pub fn variant_key(model: &str, npu: &str, tp: usize) -> String {
        format!("{model}@{npu}/tp{tp}")
    }

    pub fn has_variant(&self, key: &str) -> bool {
        self.manifest.at(&["variants", key]).is_some()
    }

    pub fn variant_keys(&self) -> Vec<String> {
        match self.manifest.get("variants") {
            Some(Json::Obj(m)) => m.keys().cloned().collect(),
            _ => Vec::new(),
        }
    }

    /// Compile the predictor executable for a variant.
    pub fn load_predictor(&self, rt: &Runtime, key: &str) -> Result<PredictorExe> {
        let v = self
            .manifest
            .at(&["variants", key])
            .with_context(|| format!("variant '{key}' not in manifest"))?;
        let file = v
            .get("file")
            .and_then(Json::as_str)
            .context("manifest variant missing 'file'")?;
        let rows = self.manifest.usize_or("rows", 64);
        let n_raw = self.manifest.usize_or("n_raw", 5);
        let exe = rt.load_hlo_text(&self.dir.join(file))?;
        Ok(PredictorExe {
            exe,
            rows,
            n_raw,
            variant: key.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-backed tests live in rust/tests/pjrt_parity.rs (they need
    // `make artifacts` to have run). Here: pure bundle-parsing logic.

    #[test]
    fn variant_key_format() {
        assert_eq!(
            ArtifactBundle::variant_key("llama3-70b", "h100", 8),
            "llama3-70b@h100/tp8"
        );
    }

    #[test]
    fn missing_bundle_is_a_clear_error() {
        let err = match ArtifactBundle::open(Path::new("/nonexistent/dir")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
