//! Bounded parallel execution of independent simulation runs.
//!
//! Sweeps and benchmarks run many *independent* coordinator instances:
//! every (spec, workload, rate) point is a self-contained simulation
//! whose outcome is fully determined by its inputs. This module fans
//! those runs across a `--jobs N` worker pool (std `thread::scope`, no
//! dependencies) and collects the results back in **submission order**,
//! so the output is identical regardless of which worker finished
//! first.
//!
//! Two properties make parallel runs bit-identical to serial ones (the
//! differential guarantee `rust/tests/parallel_equivalence.rs` pins):
//!
//! * **Runs share no mutable state.** Coordinators are constructed
//!   *inside* the worker (PJRT handles and the builder's shared
//!   predictor cache are `Rc`-based and deliberately never cross a
//!   thread boundary); only plain-data inputs (`ServingSpec`,
//!   `Scenario`, `WorkloadMix`, `SloLadder`) are shared by reference.
//!   The one process-global touched on the hot path — the `ModelId`
//!   interning registry — is append-only behind an `RwLock`, and ids
//!   are name-identified, so interleaved interning cannot change any
//!   run's behavior.
//! * **Results are collected by submission index**, not completion
//!   order, so scheduling nondeterminism never reaches the caller.
//!
//! `jobs <= 1` short-circuits to an inline loop on the calling thread —
//! the literal serial path, spawning nothing. That is the bit-exactness
//! oracle `--jobs 1` advertises: parallel output can always be checked
//! against a run that never touched a thread.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide default worker count for sweep fan-out (`--jobs N`).
/// Defaults to 1 (serial): parallelism is opt-in so every run stays
/// comparable to the oracle unless the user asks for more cores.
static JOBS: AtomicUsize = AtomicUsize::new(1);

/// The configured default job count (≥ 1).
pub fn jobs() -> usize {
    JOBS.load(Ordering::Relaxed).max(1)
}

/// Set the process-wide default job count (clamped to ≥ 1). Called by
/// the CLI (`--jobs N`) before dispatching a subcommand, so deeply
/// nested sweep call sites need no threading of the parameter.
pub fn set_jobs(n: usize) {
    JOBS.store(n.max(1), Ordering::Relaxed);
}

/// Process-wide default shard count for sharded single-run execution
/// (`--shards N`, [`crate::coordinator::shard::run_sharded`]). Defaults
/// to 1: one domain — the literal serial event loop, the bit-exactness
/// oracle. Orthogonal to `--jobs`: jobs fan out *independent* sweep
/// points, shards split *one* run into conservative-window domains, and
/// the two compose (each sweep worker may run its point sharded).
static SHARDS: AtomicUsize = AtomicUsize::new(1);

/// The configured default shard count (≥ 1).
pub fn shards() -> usize {
    SHARDS.load(Ordering::Relaxed).max(1)
}

/// Set the process-wide default shard count (clamped to ≥ 1). Called by
/// the CLI (`--shards N`) before dispatching a subcommand.
pub fn set_shards(n: usize) {
    SHARDS.store(n.max(1), Ordering::Relaxed);
}

/// Run `n` independent tasks on at most `jobs` worker threads and
/// return their results indexed by submission order (`task(i)` lands at
/// `out[i]`).
///
/// With `jobs <= 1` (or a single task) the tasks execute inline on the
/// calling thread, in order — no threads are spawned. Otherwise workers
/// pull the next unstarted index from an atomic cursor, so an expensive
/// task never blocks the queue behind it. A panicking task propagates:
/// `thread::scope` re-raises worker panics on join.
pub fn run<T, F>(jobs: usize, n: usize, task: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        return (0..n).map(task).collect();
    }
    let workers = jobs.min(n);
    let next = AtomicUsize::new(0);
    // one slot per task: workers write disjoint indices, so each slot's
    // mutex is uncontended — it exists to make the write safe, not to
    // serialize anything
    let mut slots: Vec<Mutex<Option<T>>> = Vec::with_capacity(n);
    slots.resize_with(n, || Mutex::new(None));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(task(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .unwrap()
                .expect("scope joined every worker, so every slot is filled")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_submission_order() {
        // make later-submitted tasks finish first so completion order
        // and submission order disagree
        let out = run(4, 8, |i| {
            std::thread::sleep(std::time::Duration::from_millis((8 - i as u64) * 2));
            i * 10
        });
        assert_eq!(out, (0..8).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn serial_path_spawns_nothing_and_matches_parallel() {
        let task = |i: usize| (i, i as u64 * i as u64);
        let serial = run(1, 16, task);
        let parallel = run(4, 16, task);
        assert_eq!(serial, parallel);
        // jobs larger than the task count is fine
        assert_eq!(run(64, 3, task), run(1, 3, task));
        // empty submission
        assert_eq!(run(4, 0, task), vec![]);
    }

    #[test]
    fn serial_path_runs_on_the_calling_thread() {
        let caller = std::thread::current().id();
        let ids = run(1, 4, |_| std::thread::current().id());
        assert!(ids.iter().all(|&id| id == caller));
    }

    #[test]
    fn default_shards_knob_round_trips_and_clamps() {
        set_shards(4);
        assert_eq!(shards(), 4);
        set_shards(0); // clamped: a 0-domain run is meaningless
        assert_eq!(shards(), 1);
        set_shards(1);
        assert_eq!(shards(), 1);
    }

    #[test]
    fn default_jobs_knob_round_trips_and_clamps() {
        // global knob: other tests read it concurrently, but any value
        // yields bit-identical results, so the race is harmless
        set_jobs(4);
        assert_eq!(jobs(), 4);
        set_jobs(0); // clamped: 0 workers would deadlock a sweep
        assert_eq!(jobs(), 1);
        set_jobs(1);
        assert_eq!(jobs(), 1);
    }
}
