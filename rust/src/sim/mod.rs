//! Simulation assembly and driving: the time base, the builder that turns
//! a `SimConfig` into a wired coordinator + clients, and the run driver.

pub mod builder;
pub mod driver;
pub mod time;

pub use time::SimTime;
