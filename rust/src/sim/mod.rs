//! Simulation assembly and driving (paper §III-A): the time base, the
//! builder that turns a declarative `ServingSpec` (from a config
//! document or a scenario file) into a wired coordinator + clients, and
//! the run driver with its parallel rate sweeps.

pub mod builder;
pub mod driver;
pub mod parallel;
pub mod time;

pub use time::SimTime;
