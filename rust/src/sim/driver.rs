//! Run driver: spec + workload → metrics, with optional rate sweeps
//! (the "gradually increase the per-client request rate" methodology of
//! §V-A) fanned across the [`parallel`](super::parallel) worker pool —
//! serial by default (`--jobs 1`, the bit-exactness oracle), bounded by
//! the configured job count otherwise.

use anyhow::Result;

use super::builder::ServingSpec;
use super::parallel;
use crate::config::slo::SloLadder;
use crate::metrics::RunMetrics;
use crate::workload::request::Request;
use crate::workload::trace::{WorkloadMix, WorkloadSpec};

/// Build, inject, run, collect.
pub fn run(spec: &ServingSpec, workload: &WorkloadSpec, slo: &SloLadder) -> Result<RunMetrics> {
    let mut coord = spec.build()?;
    coord.inject(workload.generate(0));
    coord.run();
    Ok(RunMetrics::collect(&coord, slo))
}

/// One (rate → metrics) sample of a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub rate: f64,
    pub metrics: RunMetrics,
    pub slo_ok: bool,
}

/// Sweep per-client injection rates over a single-class workload: at
/// each rate the whole pool is injected at `rate × n_clients` (Poisson).
pub fn sweep_rates(
    spec: &ServingSpec,
    workload: &WorkloadSpec,
    slo: &SloLadder,
    rates: &[f64],
) -> Result<Vec<SweepPoint>> {
    sweep_rates_with(spec, slo, rates, |rate| {
        workload
            .clone()
            .with_arrival(crate::util::rng::Arrival::Poisson {
                rate: rate * spec.pool.n_clients() as f64,
            })
            .generate(0)
    })
}

/// Sweep per-client injection rates over a [`WorkloadMix`]: the total
/// rate (`rate × n_clients`) and request count are split across classes
/// by their fractions, each keeping its own arrival-process shape.
pub fn sweep_rates_mix(
    spec: &ServingSpec,
    mix: &WorkloadMix,
    slo: &SloLadder,
    rates: &[f64],
) -> Result<Vec<SweepPoint>> {
    let points = parallel::run(parallel::jobs(), rates.len(), |i| {
        sweep_point_mix(spec, mix, slo, rates[i])
    });
    points.into_iter().collect()
}

/// One (spec, mix, rate) point of a sweep — the unit of work every
/// sweep fan-out (rate ladders here, roster × rates in
/// `scenario::runner::sweep_at`) dispatches, so the per-point
/// computation cannot drift between the serial and parallel paths.
pub fn sweep_point_mix(
    spec: &ServingSpec,
    mix: &WorkloadMix,
    slo: &SloLadder,
    rate: f64,
) -> Result<SweepPoint> {
    let n = mix.n_total();
    run_point(spec, slo, rate, &|rate: f64| {
        mix.scaled(n, rate * spec.pool.n_clients() as f64).generate()
    })
}

/// Build, inject, run, collect one sweep point. The coordinator is
/// constructed *inside* the calling worker — PJRT handles and the
/// builder's shared predictor cache are `Rc`-based and never cross a
/// thread boundary; only the plain-data inputs do.
fn run_point<F>(
    spec: &ServingSpec,
    slo: &SloLadder,
    rate: f64,
    make_requests: &F,
) -> Result<SweepPoint>
where
    F: Fn(f64) -> Vec<Request>,
{
    let mut coord = spec.build()?;
    coord.inject(make_requests(rate));
    coord.run();
    let metrics = RunMetrics::collect(&coord, slo);
    let slo_ok = metrics.slo_satisfied(slo);
    Ok(SweepPoint { rate, metrics, slo_ok })
}

/// Generic rate sweep; each point is an independent simulation,
/// dispatched on the configured worker pool ([`parallel::jobs`],
/// default 1 = inline serial) and collected in rate order.
/// `make_requests` maps a per-client rate to the full request stream
/// for that point.
pub fn sweep_rates_with<F>(
    spec: &ServingSpec,
    slo: &SloLadder,
    rates: &[f64],
    make_requests: F,
) -> Result<Vec<SweepPoint>>
where
    F: Fn(f64) -> Vec<Request> + Sync,
{
    let points = parallel::run(parallel::jobs(), rates.len(), |i| {
        run_point(spec, slo, rates[i], &make_requests)
    });
    points.into_iter().collect()
}

/// The paper's headline sweep statistic: among SLO-satisfying points,
/// the highest-throughput point, with throughput ties broken by
/// throughput/energy (`tok_per_joule`, used by Figs 10–12). The
/// comparison is total: a NaN metric sorts below every real value
/// instead of panicking (or winning the max).
pub fn best_under_slo(points: &[SweepPoint]) -> Option<&SweepPoint> {
    fn key(x: f64) -> f64 {
        if x.is_nan() {
            f64::NEG_INFINITY
        } else {
            x
        }
    }
    points.iter().filter(|p| p.slo_ok).max_by(|a, b| {
        key(a.metrics.throughput_tok_s)
            .total_cmp(&key(b.metrics.throughput_tok_s))
            .then_with(|| key(a.metrics.tok_per_joule).total_cmp(&key(b.metrics.tok_per_joule)))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::npu::H100;
    use crate::scheduler::BatchingKind;
    use crate::sim::builder::PoolSpec;
    use crate::workload::trace::TraceKind;

    #[test]
    fn sweep_runs_all_rates_and_degrades() {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 },
        );
        let w = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 30, 1.0).with_seed(2);
        let slo = SloLadder::standard();
        let points = sweep_rates(&spec, &w, &slo, &[0.5, 2.0, 16.0]).unwrap();
        assert_eq!(points.len(), 3);
        // higher injection → worse (or equal) tail TTFT
        let t0 = points[0].metrics.ttft.p99;
        let t2 = points[2].metrics.ttft.p99;
        assert!(t2 >= t0, "t0={t0} t2={t2}");
    }

    #[test]
    fn best_under_slo_total_order_and_energy_tie_break() {
        let mk = |thr: f64, tpj: f64| SweepPoint {
            rate: 1.0,
            metrics: RunMetrics {
                throughput_tok_s: thr,
                tok_per_joule: tpj,
                ..Default::default()
            },
            slo_ok: true,
        };
        // NaN throughput must neither panic nor win the max; equal
        // throughputs are settled by throughput/energy
        let points = vec![mk(f64::NAN, 99.0), mk(100.0, 1.0), mk(100.0, 5.0), mk(50.0, 50.0)];
        let best = best_under_slo(&points).unwrap();
        assert_eq!(best.metrics.throughput_tok_s, 100.0);
        assert_eq!(best.metrics.tok_per_joule, 5.0);
    }

    #[test]
    fn best_under_slo_ignores_violators() {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 },
        );
        let w = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 25, 1.0).with_seed(9);
        let slo = SloLadder::standard();
        let points = sweep_rates(&spec, &w, &slo, &[0.25, 0.5, 64.0]).unwrap();
        if let Some(best) = best_under_slo(&points) {
            assert!(best.slo_ok);
        }
    }
}
