//! Simulation assembly: turn a declarative `ServingSpec` into a wired
//! `Coordinator`. Every experiment — benches, examples, CLI configs —
//! goes through this builder, so serving topologies are described in one
//! place.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::client::{Client, KvRetrievalClient, LlmClient, PrePostClient, RagClient};
use crate::coordinator::{Coordinator, RoutePolicy, Router};
use crate::hardware::roofline::LlmCluster;
use crate::hardware::{model_lookup, npu, ModelSpec, NpuSpec};
use crate::memory::hierarchy::{CacheLevel, Hierarchy};
use crate::memory::storage::{KvScenario, KvStore, StorageConfig};
use crate::model::ModelId;
use crate::model::policy::ModelPolicy;
use crate::network::link::LinkSpec;
use crate::network::{Granularity, Location, Network, NetworkKind};
use crate::perfmodel::memo::Memoized;
use crate::perfmodel::pjrt::PjrtPerfModel;
use crate::perfmodel::poly::PolyPerfModel;
use crate::perfmodel::{PerfModel, RooflinePerfModel};
use crate::rag::ivfpq::{IvfPq, IvfPqConfig};
use crate::rag::RagEngine;
use crate::runtime::{ArtifactBundle, Runtime};
use crate::scheduler::{BatchingKind, LlmSched, Packing, SchedConfig};

/// Which predictor backend prices LLM engine steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PerfBackend {
    /// analytical GenZ-like model (no artifacts needed)
    Roofline,
    /// native evaluation of the fitted coefficients (artifacts/coefficients.json)
    Poly,
    /// AOT Pallas/JAX executable via PJRT (artifacts/*.hlo.txt)
    Pjrt,
    /// PJRT behind the quantized memo cache (production default)
    PjrtMemo,
}

/// LLM serving pool shape.
#[derive(Debug, Clone, PartialEq)]
pub enum PoolSpec {
    /// n identical combined clients running `kind` batching
    Combined { kind: BatchingKind, n: usize },
    /// disaggregated prefill/decode pools (Splitwise/DistServe)
    Disaggregated {
        prefill: usize,
        decode: usize,
        local: bool,
    },
    /// heterogeneous pool: one client per entry, each with its own
    /// batching policy (the "per-client policy selection" the scenario
    /// registry exposes)
    PerClient { kinds: Vec<BatchingKind> },
}

impl PoolSpec {
    pub fn n_clients(&self) -> usize {
        match self {
            PoolSpec::Combined { n, .. } => *n,
            PoolSpec::Disaggregated { prefill, decode, .. } => prefill + decode,
            PoolSpec::PerClient { kinds } => kinds.len(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            PoolSpec::Combined { kind, .. } => kind.name().to_string(),
            PoolSpec::Disaggregated { prefill, decode, local } => format!(
                "disagg-{}{}P/{}D",
                if *local { "local-" } else { "" },
                prefill,
                decode
            ),
            PoolSpec::PerClient { kinds } => {
                let mut names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
                names.dedup();
                format!("per-client[{}]", names.join("+"))
            }
        }
    }
}

/// Auxiliary RAG clients.
#[derive(Debug, Clone)]
pub struct RagSpec {
    pub count: usize,
    pub embed_model: ModelSpec,
    pub embed_npu: NpuSpec,
    pub retrieval_npu: NpuSpec,
    pub ivf: IvfPqConfig,
    pub max_batch: usize,
}

/// Auxiliary KV-retrieval clients.
#[derive(Debug, Clone)]
pub struct KvRetrievalSpec {
    pub count: usize,
    pub storage: StorageConfig,
    pub scenario: KvScenario,
    pub max_batch: usize,
    /// client connections aggregated per store (per-connection tier
    /// bandwidth × ports = aggregate; see memory::storage::KvStore)
    pub ports: usize,
}

/// Auxiliary pre/post-processing clients.
#[derive(Debug, Clone)]
pub struct PrePostSpec {
    pub count: usize,
    pub cores: usize,
    pub guard_npu: Option<NpuSpec>,
}

/// Explicit KV-migration pricing for `Pipeline::Disagg` hand-offs
/// (docs/disaggregation.md): how the prefill→decode KV transfer is
/// sliced on the link and where it lands on the decode side.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MigrationSpec {
    /// granularity override for migration hops (None = the serving
    /// default): `Full` models a blocking hand-off, `Layerwise` the
    /// overlapped per-layer migration
    pub granularity: Option<Granularity>,
    /// staging-tier stack on the decode side, nearest first (resolved
    /// from preset names — hbm / cxl / dram / nvme — at config parse
    /// time; empty = the KV streams straight into HBM at no extra cost)
    pub pool: Vec<CacheLevel>,
}

/// Network shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NetSpec {
    SinglePlatform,
    Hierarchy { per_platform: usize, per_rack: usize },
    /// splitwise-sim-style single link (Fig 5 baseline)
    Dummy(LinkSpec),
}

/// Declarative serving-system specification.
#[derive(Debug, Clone)]
pub struct ServingSpec {
    /// primary model (and the single model when `co_models` is empty)
    pub model: &'static str,
    pub npu: NpuSpec,
    pub tp: usize,
    pub pool: PoolSpec,
    pub sched: SchedConfig,
    pub packing: Packing,
    pub perf: PerfBackend,
    pub route: RoutePolicy,
    /// additional models co-resident on EVERY LLM client (multi-model
    /// serving, docs/models.md); the primary is always hosted and
    /// duplicates are ignored
    pub co_models: Vec<ModelId>,
    /// dynamic model-selection policy for `Stage::ModelRoute` pipelines
    pub model_policy: Option<ModelPolicy>,
    pub rag: Option<RagSpec>,
    pub kv_retrieval: Option<KvRetrievalSpec>,
    pub prepost: Option<PrePostSpec>,
    pub net: NetSpec,
    pub granularity: Granularity,
    /// explicit KV-migration pricing for `Pipeline::Disagg` pipelines
    /// (None = migrations use the serving defaults at zero staging cost)
    pub migration: Option<MigrationSpec>,
    /// router bias toward cheap links: candidate key = load + weight ×
    /// estimated transfer seconds ([`Router::with_transfer_weight`])
    pub transfer_weight: f64,
    /// fault-injection schedule (docs/robustness.md): compiled into a
    /// [`FaultPlan`](crate::fault::FaultPlan) at build time. None — the
    /// default and the `--faults off` override — builds a coordinator
    /// byte-identical to a pre-fault one.
    pub faults: Option<crate::fault::FaultSpec>,
    pub seed: u64,
}

impl ServingSpec {
    /// A sensible default: continuous batching on H100 TP-sharded clients.
    pub fn new(model: &'static str, npu: NpuSpec, tp: usize, pool: PoolSpec) -> ServingSpec {
        ServingSpec {
            model,
            npu,
            tp,
            pool,
            sched: SchedConfig::default(),
            packing: Packing::Fcfs,
            perf: PerfBackend::Roofline,
            route: RoutePolicy::LoadBased(crate::coordinator::LoadMetric::TokensLeft),
            co_models: Vec::new(),
            model_policy: None,
            rag: None,
            kv_retrieval: None,
            prepost: None,
            net: NetSpec::SinglePlatform,
            granularity: Granularity::Layerwise { layers: 80 },
            migration: None,
            transfer_weight: 0.0,
            faults: None,
            seed: 0,
        }
    }

    /// Attach a fault-injection schedule.
    pub fn with_faults(mut self, f: crate::fault::FaultSpec) -> ServingSpec {
        self.faults = Some(f);
        self
    }

    pub fn with_perf(mut self, p: PerfBackend) -> ServingSpec {
        self.perf = p;
        self
    }

    pub fn with_route(mut self, r: RoutePolicy) -> ServingSpec {
        self.route = r;
        self
    }

    pub fn with_rag(mut self, r: RagSpec) -> ServingSpec {
        self.rag = Some(r);
        self
    }

    pub fn with_kv_retrieval(mut self, k: KvRetrievalSpec) -> ServingSpec {
        self.kv_retrieval = Some(k);
        self
    }

    pub fn with_prepost(mut self, p: PrePostSpec) -> ServingSpec {
        self.prepost = Some(p);
        self
    }

    pub fn with_net(mut self, n: NetSpec) -> ServingSpec {
        self.net = n;
        self
    }

    /// Configure explicit KV-migration pricing (`Pipeline::Disagg`).
    pub fn with_migration(mut self, m: MigrationSpec) -> ServingSpec {
        self.migration = Some(m);
        self
    }

    /// Bias routing toward cheap links (0 = pure load balancing).
    pub fn with_transfer_weight(mut self, w: f64) -> ServingSpec {
        self.transfer_weight = w;
        self
    }

    /// Co-host additional models on every LLM client.
    pub fn with_co_models(mut self, models: Vec<ModelId>) -> ServingSpec {
        self.co_models = models;
        self
    }

    /// Set the dynamic model-selection policy.
    pub fn with_model_policy(mut self, p: ModelPolicy) -> ServingSpec {
        self.model_policy = Some(p);
        self
    }

    pub fn with_sched(mut self, s: SchedConfig) -> ServingSpec {
        self.sched = s;
        self
    }

    pub fn with_seed(mut self, s: u64) -> ServingSpec {
        self.seed = s;
        self
    }

    /// Swap the LLM pool shape (the scenario runner applies each roster
    /// entry through this).
    pub fn with_pool(mut self, p: PoolSpec) -> ServingSpec {
        self.pool = p;
        self
    }

    /// Build the step-time predictor for one client. Every non-roofline
    /// backend degrades to the analytical roofline when its inputs are
    /// missing — an un-fitted configuration, an absent artifact bundle
    /// (`make artifacts` not run), or an unavailable PJRT runtime — so a
    /// fresh checkout can run every experiment without the AOT toolchain.
    /// The degradation is announced once per process on stderr so a run
    /// labeled `poly`/`pjrt` never silently reports roofline numbers.
    fn make_perf(
        &self,
        cluster: &LlmCluster,
        shared_exe: &mut HashMap<String, std::rc::Rc<crate::runtime::PredictorExe>>,
    ) -> Result<Box<dyn PerfModel>> {
        fn warn_fallback(reason: &str) {
            static ONCE: std::sync::Once = std::sync::Once::new();
            let msg = reason.to_string();
            ONCE.call_once(move || {
                eprintln!(
                    "hermes: {msg}; using the analytical roofline perf model \
                     (run `make artifacts` for the fitted predictor)"
                );
            });
        }
        let key = ArtifactBundle::variant_key(cluster.model.name, cluster.npu.name, cluster.tp);
        let roofline = || -> Box<dyn PerfModel> { Box::new(RooflinePerfModel::new(cluster.clone())) };
        Ok(match self.perf {
            PerfBackend::Roofline => roofline(),
            PerfBackend::Poly => {
                match ArtifactBundle::open(&ArtifactBundle::default_dir()) {
                    Ok(bundle) => match PolyPerfModel::from_coefficients(&bundle.coefficients, &key)
                    {
                        Ok(m) => Box::new(m),
                        // un-fitted configuration: analytical fallback
                        // (the paper's LLMCompass/GenZ role)
                        Err(_) => {
                            warn_fallback(&format!("no fitted coefficients for {key}"));
                            roofline()
                        }
                    },
                    Err(e) => {
                        warn_fallback(&format!("artifact bundle unavailable ({e})"));
                        roofline()
                    }
                }
            }
            PerfBackend::Pjrt | PerfBackend::PjrtMemo => {
                let dir = ArtifactBundle::default_dir();
                let bundle = match ArtifactBundle::open(&dir) {
                    Ok(b) => b,
                    Err(e) => {
                        warn_fallback(&format!("artifact bundle unavailable ({e})"));
                        return Ok(roofline());
                    }
                };
                if !bundle.has_variant(&key) {
                    warn_fallback(&format!("no AOT variant for {key}"));
                    return Ok(roofline());
                }
                // compile each (model, npu, tp) variant once, share the
                // executable across the pool — co-resident models get
                // their own entries in the per-key map
                if !shared_exe.contains_key(&key) {
                    let rt = match Runtime::cpu() {
                        Ok(rt) => rt,
                        Err(e) => {
                            // offline build: the vendored xla stub has no PJRT
                            warn_fallback(&format!("PJRT unavailable ({e})"));
                            return Ok(roofline());
                        }
                    };
                    match bundle.load_predictor(&rt, &key) {
                        Ok(exe) => {
                            shared_exe.insert(key.clone(), std::rc::Rc::new(exe));
                        }
                        Err(e) => {
                            warn_fallback(&format!("loading AOT predictor failed ({e})"));
                            return Ok(roofline());
                        }
                    }
                }
                let exe = shared_exe[&key].clone();
                if self.perf == PerfBackend::Pjrt {
                    Box::new(PjrtPerfModel::new(exe))
                } else {
                    Box::new(Memoized::new(PjrtPerfModel::new(exe)))
                }
            }
        })
    }

    /// One LLM client hosting the full co-resident model set (a single
    /// entry degenerates to the classic single-model client).
    fn make_llm_client(
        &self,
        id: usize,
        kind: BatchingKind,
        model_ids: &[ModelId],
        shared_exe: &mut HashMap<String, std::rc::Rc<crate::runtime::PredictorExe>>,
    ) -> Result<LlmClient> {
        let mut entries = Vec::with_capacity(model_ids.len());
        for m in model_ids {
            let cluster = LlmCluster::new(m.spec().clone(), self.npu.clone(), self.tp);
            let perf = self.make_perf(&cluster, shared_exe)?;
            entries.push((cluster, perf, kind));
        }
        Ok(LlmClient::with_models(id, entries, self.packing, self.sched))
    }

    /// Wire everything into a ready-to-inject coordinator.
    pub fn build(&self) -> Result<Coordinator> {
        let model_spec = model_lookup(self.model)?;

        // co-resident model set hosted by every LLM client: primary
        // first, then the deduped co_models
        let mut model_ids = vec![ModelId::of_spec(&model_spec)];
        for m in &self.co_models {
            if !model_ids.contains(m) {
                model_ids.push(*m);
            }
        }
        // a model policy may only name hosted models — catch dangling
        // references at build time, not mid-simulation
        if let Some(p) = &self.model_policy {
            for m in p.models() {
                if !model_ids.contains(&m) {
                    bail!(
                        "model policy references '{m}' but the pool hosts only [{}]",
                        model_ids
                            .iter()
                            .map(|m| m.name())
                            .collect::<Vec<_>>()
                            .join(", ")
                    );
                }
            }
        }

        let mut clients: Vec<Box<dyn Client>> = Vec::new();
        // `Rc`, deliberately: the predictor cache is build-local and the
        // built coordinator never crosses a thread boundary — parallel
        // sweeps (`sim::parallel`) call `build()` *inside* each worker,
        // so only this plain-data spec needs to be `Sync`
        let mut shared_exe: HashMap<String, std::rc::Rc<crate::runtime::PredictorExe>> =
            HashMap::new();
        match &self.pool {
            PoolSpec::Combined { kind, n } => {
                let (kind, n) = (*kind, *n);
                if n == 0 {
                    bail!("empty client pool");
                }
                for i in 0..n {
                    clients.push(Box::new(
                        self.make_llm_client(i, kind, &model_ids, &mut shared_exe)?
                            .with_group(i),
                    ));
                }
            }
            PoolSpec::PerClient { kinds } => {
                if kinds.is_empty() {
                    bail!("empty client pool");
                }
                for (i, kind) in kinds.iter().enumerate() {
                    clients.push(Box::new(
                        self.make_llm_client(i, *kind, &model_ids, &mut shared_exe)?
                            .with_group(i),
                    ));
                }
            }
            PoolSpec::Disaggregated { prefill, decode, local } => {
                let (prefill, decode, local) = (*prefill, *decode, *local);
                if prefill == 0 || decode == 0 {
                    bail!("disaggregated pools need both roles");
                }
                // local mode pairs P/D into groups round-robin
                let groups = prefill.min(decode);
                for i in 0..prefill {
                    clients.push(Box::new(
                        self.make_llm_client(
                            i,
                            BatchingKind::PrefillOnly,
                            &model_ids,
                            &mut shared_exe,
                        )?
                        .with_group(if local { i % groups } else { 0 }),
                    ));
                }
                for j in 0..decode {
                    let id = prefill + j;
                    clients.push(Box::new(
                        self.make_llm_client(
                            id,
                            BatchingKind::DecodeOnly,
                            &model_ids,
                            &mut shared_exe,
                        )?
                        .with_group(if local { j % groups } else { 0 }),
                    ));
                }
            }
        }

        if let Some(r) = &self.rag {
            for k in 0..r.count {
                let id = clients.len();
                clients.push(Box::new(RagClient::new(
                    id,
                    RagEngine::new(
                        LlmCluster::new(r.embed_model.clone(), r.embed_npu.clone(), 1),
                        IvfPq::new(r.retrieval_npu.clone(), r.ivf),
                    ),
                    r.max_batch,
                ).with_group(k)));
            }
        }

        if let Some(k) = &self.kv_retrieval {
            for i in 0..k.count {
                let id = clients.len();
                clients.push(Box::new(
                    KvRetrievalClient::new(
                        id,
                        KvStore::with_ports(k.storage, k.scenario, k.ports),
                        model_spec.kv_bytes_per_token(),
                        k.max_batch,
                        self.seed.wrapping_add(i as u64),
                    )
                    .with_group(i),
                ));
            }
        }

        if let Some(p) = &self.prepost {
            for _ in 0..p.count {
                let id = clients.len();
                let guard = p.guard_npu.as_ref().map(|n| {
                    LlmCluster::new(crate::hardware::models::GUARD_2B, n.clone(), 1)
                });
                clients.push(Box::new(PrePostClient::new(id, p.cores, guard)));
            }
        }

        let n = clients.len();
        let network = match self.net {
            NetSpec::SinglePlatform => Network::single_platform(n),
            NetSpec::Hierarchy { per_platform, per_rack } => {
                Network::hierarchy(n, per_platform, per_rack)
            }
            NetSpec::Dummy(spec) => Network::new(
                NetworkKind::DummyLink(spec),
                (0..n).map(|i| Location { rack: i, platform: i }).collect(),
            ),
        };

        let mut coord = Coordinator::new(
            clients,
            Router::new(self.route).with_transfer_weight(self.transfer_weight),
            network,
        );
        coord.granularity = self.granularity;
        if let Some(m) = &self.migration {
            coord.migration_granularity = m.granularity;
            if !m.pool.is_empty() {
                coord.migration_pool = Some(Hierarchy::new(m.pool.clone()));
            }
        }
        coord.model_policy = self.model_policy.clone();
        coord.model_seed = self.seed;
        if matches!(self.pool, PoolSpec::Disaggregated { local: true, .. }) {
            coord.local_disagg = true;
        }
        if let Some(f) = &self.faults {
            let n_clients = coord.clients.len();
            let n_racks = coord
                .network
                .locations
                .iter()
                .map(|l| l.rack)
                .max()
                .map_or(0, |m| m + 1);
            coord.faults =
                Some(crate::fault::FaultPlan::compile(f, n_clients, n_racks)?);
        }
        Ok(coord)
    }
}

/// Lookup helper mirroring `hardware::npu` for config files.
pub fn npu_by_name(name: &str) -> Result<NpuSpec> {
    npu(name).with_context(|| format!("unknown npu '{name}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::slo::SloLadder;
    use crate::hardware::npu::H100;
    use crate::metrics::RunMetrics;
    use crate::workload::trace::{TraceKind, WorkloadSpec};

    fn small_workload(n: usize) -> Vec<crate::workload::request::Request> {
        WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, 3.0)
            .with_seed(5)
            .generate(0)
    }

    #[test]
    fn builds_combined_pool_and_runs() {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        );
        let mut coord = spec.build().unwrap();
        coord.inject(small_workload(20));
        coord.run();
        let m = RunMetrics::collect(&coord, &SloLadder::standard());
        assert_eq!(m.n_serviced, 20);
    }

    #[test]
    fn builds_disaggregated_pool() {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Disaggregated { prefill: 2, decode: 1, local: false },
        );
        let mut coord = spec.build().unwrap();
        assert_eq!(coord.clients.len(), 3);
        coord.inject(small_workload(12));
        coord.run();
        assert!(coord.all_serviced());
        assert!(coord.stats.transfers >= 12);
    }

    #[test]
    fn builds_disagg_migration_spec() {
        use crate::memory::hierarchy::{TIER_DRAM, TIER_HBM};
        use crate::workload::trace::Pipeline;

        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
        )
        .with_migration(MigrationSpec {
            granularity: Some(Granularity::Full),
            pool: vec![TIER_HBM, TIER_DRAM],
        })
        .with_transfer_weight(0.5);
        let mut coord = spec.build().unwrap();
        assert_eq!(coord.migration_granularity, Some(Granularity::Full));
        assert!(coord.migration_pool.is_some());
        assert_eq!(coord.router.transfer_weight, 0.5);
        let reqs = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 10, 3.0)
            .with_seed(5)
            .with_pipeline(Pipeline::Disagg)
            .generate(0);
        coord.inject(reqs);
        coord.run();
        assert!(coord.all_serviced());
        assert_eq!(coord.stats.transfers, 10, "one migration hop per request");
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(ServingSpec::new(
            "no-such-model",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 }
        )
        .build()
        .is_err());
        assert!(ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Disaggregated { prefill: 0, decode: 2, local: false }
        )
        .build()
        .is_err());
    }

    #[test]
    fn builds_multi_model_pool_and_validates_policy() {
        use crate::model::ModelId;
        use crate::model::policy::ModelPolicy;

        let small = ModelId::named("llama3-8b");
        let large = ModelId::named("llama3-70b");
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        )
        .with_co_models(vec![small]);
        let coord = spec
            .clone()
            .with_model_policy(ModelPolicy::Cascade { small, large, escalate: 0.3 })
            .build()
            .unwrap();
        // every LLM client hosts both models
        for c in &coord.clients {
            assert_eq!(c.served_models(), &[large, small]);
        }
        // dangling policy reference is a build error
        let err = spec
            .with_model_policy(ModelPolicy::Static {
                choices: vec![(ModelId::named("bloom-176b"), 1.0)],
            })
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("bloom-176b"), "{err}");
    }

    #[test]
    fn multi_model_cascade_build_runs_end_to_end() {
        use crate::model::ModelId;
        use crate::model::policy::ModelPolicy;
        use crate::workload::trace::Pipeline;

        let small = ModelId::named("llama3-8b");
        let large = ModelId::named("llama3-70b");
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        )
        .with_co_models(vec![small])
        .with_model_policy(ModelPolicy::Cascade { small, large, escalate: 0.4 })
        .with_seed(31);
        let mut coord = spec.build().unwrap();
        let reqs = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 24, 3.0)
            .with_seed(31)
            .with_pipeline(Pipeline::Cascade)
            .generate(0);
        coord.inject(reqs);
        coord.run();
        assert!(coord.all_serviced(), "serviced {}", coord.serviced.len());
        // the cascade touched both models on the shared clients
        let finished_large = coord
            .serviced
            .iter()
            .filter(|id| coord.pool[*id].model == large)
            .count();
        let finished_small = coord.serviced.len() - finished_large;
        assert!(finished_large > 0, "some requests must escalate");
        assert!(finished_small > 0, "some requests must finish small");
    }

    #[test]
    fn pool_labels() {
        assert_eq!(
            PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 512 }, n: 4 }.label(),
            "chunked"
        );
        assert_eq!(
            PoolSpec::Disaggregated { prefill: 20, decode: 12, local: false }.label(),
            "disagg-20P/12D"
        );
    }
}
