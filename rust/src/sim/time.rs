//! Simulation time: u64 nanoseconds. Integer time keeps the event queue
//! ordering exact and runs bit-reproducible across platforms (no float
//! accumulation drift over millions of events).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    pub const MAX: SimTime = SimTime(u64::MAX);

    pub fn from_secs(s: f64) -> SimTime {
        assert!(s >= 0.0 && s.is_finite(), "bad time {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn from_nanos(ns: u64) -> SimTime {
        SimTime(ns)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn as_micros(self) -> f64 {
        self.0 as f64 / 1e3
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("negative SimTime"))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_secs() {
        let t = SimTime::from_secs(1.25);
        assert_eq!(t.0, 1_250_000_000);
        assert_eq!(t.as_secs(), 1.25);
    }

    #[test]
    fn arithmetic_and_ordering() {
        let a = SimTime::from_secs(1.0);
        let b = SimTime::from_secs(2.5);
        assert_eq!((a + b).as_secs(), 3.5);
        assert_eq!((b - a).as_secs(), 1.5);
        assert!(a < b);
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "negative SimTime")]
    fn negative_subtraction_panics() {
        let _ = SimTime::from_secs(1.0) - SimTime::from_secs(2.0);
    }

    #[test]
    #[should_panic(expected = "bad time")]
    fn nan_rejected() {
        SimTime::from_secs(f64::NAN);
    }
}
