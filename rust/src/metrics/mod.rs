//! Output metrics collection (paper §III-F.2): per-request, scheduler,
//! client and global metrics; latency breakdowns (mean/T50/T90/T99);
//! goodput vs the Table II SLO ladder; energy and throughput/energy.

pub mod trace_export;

use crate::config::slo::SloLadder;
use crate::coordinator::shard::ShardOutcome;
use crate::coordinator::{CoordStats, Coordinator};
use crate::util::json::Json;
use crate::util::stats::{QuantileSketch, Summary, SKETCH_ALPHA};
use crate::workload::request::CompletionRecord;

/// Streaming metrics accumulator for `--metrics sketch` runs: the
/// coordinator folds each [`CompletionRecord`] into this at retirement
/// time instead of growing `coord.records`, so whole-run metrics memory
/// is O(sketch bins) — constant in request count — rather than O(total
/// trace). Percentiles come from mergeable [`QuantileSketch`]es with a
/// relative-error contract of [`SKETCH_ALPHA`]; counts, token sums and
/// goodput are exact.
///
/// Sharded runs give every domain its own sink; the outcome merge folds
/// them in ascending domain order (see
/// [`crate::coordinator::shard::ShardOutcome`]), which pins the one
/// order-sensitive f64 (the mean's running sum) to a deterministic
/// order. Quantiles are bit-identical at any shard count because the
/// sketch bins are integers.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSink {
    pub slo: SloLadder,
    ttft: QuantileSketch,
    tpot: QuantileSketch,
    e2e: QuantileSketch,
    /// exact: generated tokens are integers, so this f64 sum is
    /// order-independent below 2^53 total tokens
    tokens: f64,
    slo_ok: u64,
    /// non-failed records folded (the goodput denominator)
    n_completed: u64,
    /// non-failed records with no first token (excluded from TTFT/E2E
    /// samples instead of poisoning them with ∞ — see `fold_records`)
    n_no_first_token: u64,
}

impl MetricsSink {
    pub fn new(slo: SloLadder) -> MetricsSink {
        Self::with_alpha(slo, SKETCH_ALPHA)
    }

    pub fn with_alpha(slo: SloLadder, alpha: f64) -> MetricsSink {
        MetricsSink {
            slo,
            ttft: QuantileSketch::new(alpha),
            tpot: QuantileSketch::new(alpha),
            e2e: QuantileSketch::new(alpha),
            tokens: 0.0,
            slo_ok: 0,
            n_completed: 0,
            n_no_first_token: 0,
        }
    }

    /// Fold one completion record — the streaming mirror of the
    /// per-record body of `RunMetrics::fold_records`. Failed requests
    /// carry no latency samples (they are counted by
    /// `CoordStats::failed`), exactly as the exact path filters them.
    pub fn fold(&mut self, r: &CompletionRecord) {
        if r.failed {
            return;
        }
        self.n_completed += 1;
        let tp = r.tpot();
        match r.ttft() {
            Some(t1) => {
                self.ttft.insert(t1);
                if self.slo.request_ok(t1, tp) {
                    self.slo_ok += 1;
                }
            }
            // no first token ⇒ never SLO-ok (request_ok(∞, _) is false)
            None => self.n_no_first_token += 1,
        }
        if let Some(tp) = tp {
            self.tpot.insert(tp);
        }
        if let Some(te) = r.e2e_latency() {
            self.e2e.insert(te);
        }
        self.tokens += r.generated_tokens() as f64;
    }

    /// Fold another domain's sink into this one. Exact for every count
    /// and quantile; the mean's f64 sum takes `other` after `self`, so
    /// callers merge in a fixed (domain-ascending) order.
    pub fn merge(&mut self, other: &MetricsSink) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        self.tokens += other.tokens;
        self.slo_ok += other.slo_ok;
        self.n_completed += other.n_completed;
        self.n_no_first_token += other.n_no_first_token;
    }

    pub fn n_completed(&self) -> u64 {
        self.n_completed
    }

    /// Estimated resident bytes of the whole sink — the bench column
    /// that proves metrics memory is O(1) in request count.
    pub fn bytes_est(&self) -> usize {
        self.ttft.bytes_est() + self.tpot.bytes_est() + self.e2e.bytes_est() + 64
    }
}

/// Aggregated results of one simulation run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub n_requests: usize,
    pub n_serviced: usize,
    pub n_failed: usize,
    /// makespan: last completion, seconds
    pub makespan: f64,
    pub ttft: Summary,
    pub tpot: Summary,
    pub e2e: Summary,
    /// generated tokens per second over the makespan (incl. branches)
    pub throughput_tok_s: f64,
    /// fraction of serviced requests meeting the per-request SLO
    pub goodput_frac: f64,
    /// requests/s that completed within SLO
    pub goodput_req_s: f64,
    pub energy_joules: f64,
    /// tokens per joule — the paper's throughput/energy axis
    pub tok_per_joule: f64,
    pub events: u64,
    pub transfers: u64,
    pub transfer_bytes: f64,
    /// total exposed inter-client transfer time
    pub transfer_seconds: f64,
    pub recomputes: u64,
    /// re-queued attempts under the fault retry policy (0 without faults)
    pub retries: u64,
    /// requests failed by their deadline expiring
    pub timeouts: u64,
    /// requests dropped by load shedding instead of retried
    pub shed: u64,
    /// in-flight requests evicted by a client crash and re-routed
    pub orphaned: u64,
    /// fraction of client-seconds the fleet was up over the makespan —
    /// 1.0 when no fault plan is installed (see
    /// [`crate::fault::FaultPlan::availability`])
    pub availability: f64,
    /// non-failed requests that never produced a first token; counted
    /// here instead of contributing ∞ TTFT/E2E samples
    pub n_no_first_token: u64,
    /// true when collected from retained records (exact percentiles and
    /// raw samples); false for the streaming sketch path, whose sample
    /// vecs are never allocated
    pub exact: bool,
    /// raw per-request samples for CDFs (Fig 15) — exact mode only
    pub e2e_samples: Vec<f64>,
    pub ttft_samples: Vec<f64>,
    pub tpot_samples: Vec<f64>,
}

/// Intermediate result of one exact-mode pass over completion records.
#[derive(Debug, Default)]
struct RecordFold {
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
    tokens: f64,
    slo_ok: usize,
    n_no_first_token: u64,
}

impl RunMetrics {
    /// Collect from a drained coordinator, consuming the
    /// [`CompletionRecord`](crate::workload::request::CompletionRecord)s
    /// the coordinator folded each finished request into. Works
    /// identically with request retirement on or off — the records (not
    /// the possibly-recycled pool) carry every sample, in serviced
    /// order, so the output is bit-identical to the legacy
    /// retained-pool scan ([`RunMetrics::collect_from_pool`], pinned by
    /// `rust/tests/retirement_equivalence.rs`).
    pub fn collect(coord: &Coordinator, slo: &SloLadder) -> RunMetrics {
        if let Some(sink) = &coord.sink {
            debug_assert_eq!(sink.slo, *slo, "sink was installed with a different SLO ladder");
            return Self::from_sink(
                sink,
                coord.stats.injected as usize,
                coord.stats.serviced as usize,
                coord.stats.failed as usize,
                coord.clock.as_secs(),
                coord.clients.iter().map(|c| c.stats().energy_joules).sum(),
                &coord.stats,
                coord.faults.as_ref().map_or(1.0, |p| p.availability(coord.clock)),
            );
        }
        let fold = Self::fold_records(&coord.records, slo);
        Self::assemble(coord, coord.stats.injected as usize, fold)
    }

    /// Collect from a sharded run's merged outcome
    /// ([`crate::coordinator::shard::run_sharded`]). The outcome's
    /// records are interleaved in global completion order at the merge,
    /// so the fold — and every f64 accumulation inside it — runs in the
    /// exact order [`RunMetrics::collect`] would see on the equivalent
    /// serial coordinator.
    pub fn collect_outcome(out: &ShardOutcome, slo: &SloLadder) -> RunMetrics {
        let avail = out.faults.as_ref().map_or(1.0, |p| p.availability(out.clock));
        if let Some(sink) = &out.sink {
            debug_assert_eq!(sink.slo, *slo, "sink was installed with a different SLO ladder");
            return Self::from_sink(
                sink,
                out.stats.injected as usize,
                out.stats.serviced as usize,
                out.stats.failed as usize,
                out.clock.as_secs(),
                out.energy_joules,
                &out.stats,
                avail,
            );
        }
        let fold = Self::fold_records(&out.records, slo);
        Self::assemble_parts(
            out.stats.injected as usize,
            out.serviced.len(),
            out.failed.len(),
            out.clock.as_secs(),
            out.energy_joules,
            &out.stats,
            avail,
            fold,
        )
    }

    /// Assemble run metrics from a streaming [`MetricsSink`] plus the
    /// coordinator's counters. No sample vecs are allocated; summaries
    /// come from the sketches under the [`SKETCH_ALPHA`] error
    /// contract. `exact` is false so downstream consumers that need raw
    /// CDF samples (fig15) can refuse loudly instead of reading empty
    /// vecs.
    #[allow(clippy::too_many_arguments)]
    fn from_sink(
        sink: &MetricsSink,
        n_requests: usize,
        n_serviced: usize,
        n_failed: usize,
        makespan: f64,
        energy: f64,
        stats: &CoordStats,
        availability: f64,
    ) -> RunMetrics {
        let tokens = sink.tokens;
        RunMetrics {
            n_requests,
            n_serviced,
            n_failed,
            makespan,
            ttft: sink.ttft.summary(),
            tpot: sink.tpot.summary(),
            e2e: sink.e2e.summary(),
            throughput_tok_s: if makespan > 0.0 { tokens / makespan } else { 0.0 },
            goodput_frac: if n_serviced > 0 {
                sink.slo_ok as f64 / n_serviced as f64
            } else {
                0.0
            },
            goodput_req_s: if makespan > 0.0 {
                sink.slo_ok as f64 / makespan
            } else {
                0.0
            },
            energy_joules: energy,
            tok_per_joule: if energy > 0.0 { tokens / energy } else { 0.0 },
            events: stats.events,
            transfers: stats.transfers,
            transfer_bytes: stats.transfer_bytes,
            transfer_seconds: stats.transfer_seconds,
            recomputes: stats.recomputes,
            retries: stats.retries,
            timeouts: stats.timeouts,
            shed: stats.shed,
            orphaned: stats.orphaned,
            availability,
            n_no_first_token: sink.n_no_first_token,
            exact: false,
            e2e_samples: Vec::new(),
            ttft_samples: Vec::new(),
            tpot_samples: Vec::new(),
        }
    }

    /// One pass over the non-failed completion records, in completion
    /// order — the per-request sample fold shared by the serial and
    /// sharded collection paths. The f64 accumulation order is part of
    /// the contract: callers hand records in serviced order.
    fn fold_records(records: &[CompletionRecord], slo: &SloLadder) -> RecordFold {
        let mut fold = RecordFold::default();
        // non-failed records are pushed at the same instant a request
        // joins `serviced`, so this iterates in serviced order — f64
        // accumulation order matches the pool-scan path exactly
        for r in records.iter().filter(|r| !r.failed) {
            let tp = r.tpot();
            match r.ttft() {
                Some(t1) => {
                    fold.ttft.push(t1);
                    if slo.request_ok(t1, tp) {
                        fold.slo_ok += 1;
                    }
                }
                // a request that completed without ever emitting a first
                // token gets counted, not an ∞ sample poisoning the mean
                // and sketch bins; it can never be SLO-ok either way
                None => fold.n_no_first_token += 1,
            }
            // requests that decode ≤1 token have no TPOT; excluding them
            // keeps the percentiles honest instead of deflating the
            // distribution with 0.0 samples
            if let Some(tp) = tp {
                fold.tpot.push(tp);
            }
            if let Some(te) = r.e2e_latency() {
                fold.e2e.push(te);
            }
            // includes superseded cascade-pass tokens: escalations did
            // that work (and paid its energy), so throughput counts it
            fold.tokens += r.generated_tokens() as f64;
        }
        fold
    }

    /// Legacy collection path: scan the retained request pool via the
    /// serviced list. Requires a run with retirement off (the default);
    /// kept verbatim as the ground truth the record-based
    /// [`RunMetrics::collect`] is differentially tested against.
    pub fn collect_from_pool(coord: &Coordinator, slo: &SloLadder) -> RunMetrics {
        let mut fold = RecordFold::default();
        for id in &coord.serviced {
            let r = &coord.pool[id];
            let t1 = r.ttft().unwrap_or(f64::INFINITY);
            let tp = r.tpot();
            let te = r.e2e_latency().unwrap_or(f64::INFINITY);
            fold.ttft.push(t1);
            if let Some(tp) = tp {
                fold.tpot.push(tp);
            }
            fold.e2e.push(te);
            fold.tokens += r.generated_tokens() as f64;
            if slo.request_ok(t1, tp) {
                fold.slo_ok += 1;
            }
        }
        Self::assemble(coord, coord.pool.len(), fold)
    }

    fn assemble(coord: &Coordinator, n_requests: usize, fold: RecordFold) -> RunMetrics {
        Self::assemble_parts(
            n_requests,
            coord.serviced.len(),
            coord.failed.len(),
            coord.clock.as_secs(),
            coord.clients.iter().map(|c| c.stats().energy_joules).sum(),
            &coord.stats,
            coord.faults.as_ref().map_or(1.0, |p| p.availability(coord.clock)),
            fold,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble_parts(
        n_requests: usize,
        n: usize,
        n_failed: usize,
        makespan: f64,
        energy: f64,
        stats: &CoordStats,
        availability: f64,
        fold: RecordFold,
    ) -> RunMetrics {
        let RecordFold { ttft, tpot, e2e, tokens, slo_ok, n_no_first_token } = fold;
        RunMetrics {
            n_requests,
            n_serviced: n,
            n_failed,
            makespan,
            ttft: Summary::of(&ttft),
            tpot: Summary::of(&tpot),
            e2e: Summary::of(&e2e),
            throughput_tok_s: if makespan > 0.0 { tokens / makespan } else { 0.0 },
            goodput_frac: if n > 0 { slo_ok as f64 / n as f64 } else { 0.0 },
            goodput_req_s: if makespan > 0.0 {
                slo_ok as f64 / makespan
            } else {
                0.0
            },
            energy_joules: energy,
            tok_per_joule: if energy > 0.0 { tokens / energy } else { 0.0 },
            events: stats.events,
            transfers: stats.transfers,
            transfer_bytes: stats.transfer_bytes,
            transfer_seconds: stats.transfer_seconds,
            recomputes: stats.recomputes,
            retries: stats.retries,
            timeouts: stats.timeouts,
            shed: stats.shed,
            orphaned: stats.orphaned,
            availability,
            n_no_first_token,
            exact: true,
            e2e_samples: e2e,
            ttft_samples: ttft,
            tpot_samples: tpot,
        }
    }

    /// Does this run meet all six Table II SLOs?
    pub fn slo_satisfied(&self, slo: &SloLadder) -> bool {
        slo.satisfied(&self.ttft, &self.tpot)
    }

    /// JSON document for `hermes simulate --out`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let sum = |s: &Summary| {
            let mut o = Json::obj();
            o.set("mean", s.mean)
                .set("p50", s.p50)
                .set("p90", s.p90)
                .set("p99", s.p99)
                .set("max", s.max);
            o
        };
        j.set("n_requests", self.n_requests)
            .set("n_serviced", self.n_serviced)
            .set("n_failed", self.n_failed)
            .set("makespan_s", self.makespan)
            .set("ttft", sum(&self.ttft))
            .set("tpot", sum(&self.tpot))
            .set("e2e", sum(&self.e2e))
            .set("throughput_tok_s", self.throughput_tok_s)
            .set("goodput_frac", self.goodput_frac)
            .set("goodput_req_s", self.goodput_req_s)
            .set("energy_joules", self.energy_joules)
            .set("tok_per_joule", self.tok_per_joule)
            .set("events", self.events)
            .set("transfers", self.transfers)
            .set("transfer_bytes", self.transfer_bytes)
            .set("recomputes", self.recomputes)
            .set("retries", self.retries)
            .set("timeouts", self.timeouts)
            .set("shed", self.shed)
            .set("orphaned", self.orphaned)
            .set("availability", self.availability)
            .set("n_no_first_token", self.n_no_first_token)
            .set("metrics", if self.exact { "exact" } else { "sketch" });
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, LlmClient};
    use crate::coordinator::{RoutePolicy, Router};
    use crate::hardware::models::LLAMA3_70B;
    use crate::hardware::npu::H100;
    use crate::hardware::roofline::LlmCluster;
    use crate::network::Network;
    use crate::perfmodel::RooflinePerfModel;
    use crate::scheduler::{BatchingKind, LlmSched, Packing, SchedConfig};
    use crate::workload::trace::{TraceKind, WorkloadSpec};

    fn run_small_opts(sketch: bool) -> Coordinator {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        let clients: Vec<Box<dyn Client>> = vec![Box::new(LlmClient::new(
            0,
            cluster.clone(),
            LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        ))];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(1),
        );
        if sketch {
            coord.sink = Some(MetricsSink::new(SloLadder::standard()));
        }
        coord.inject(
            WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 15, 2.0)
                .with_seed(3)
                .generate(0),
        );
        coord.run();
        coord
    }

    fn run_small() -> Coordinator {
        run_small_opts(false)
    }

    #[test]
    fn collect_produces_consistent_metrics() {
        let coord = run_small();
        let m = RunMetrics::collect(&coord, &SloLadder::standard());
        assert_eq!(m.n_serviced, 15);
        assert_eq!(m.n_failed, 0);
        assert!(m.makespan > 0.0);
        assert!(m.throughput_tok_s > 0.0);
        assert!(m.ttft.p50 > 0.0);
        assert!(m.tpot.p50 > 0.0);
        assert!(m.e2e.p99 >= m.e2e.p50);
        assert!(m.energy_joules > 0.0);
        assert!(m.tok_per_joule > 0.0);
        assert!((0.0..=1.0).contains(&m.goodput_frac));
        assert_eq!(m.e2e_samples.len(), 15);
        // no fault plan installed: counters zero, fleet fully available
        assert_eq!(m.retries + m.timeouts + m.shed + m.orphaned, 0);
        assert_eq!(m.availability, 1.0);
    }

    #[test]
    fn single_token_outputs_excluded_from_tpot() {
        use crate::sim::SimTime;
        use crate::workload::request::{Request, Stage};

        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        let clients: Vec<Box<dyn Client>> = vec![Box::new(LlmClient::new(
            0,
            cluster.clone(),
            LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        ))];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(1),
        );
        // r1: real decode run (TPOT = 10ms); r2: 1-token output (no TPOT)
        let mut r1 = Request::new(1, "llama3-70b", SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode], 100, 101);
        r1.decoded = 101;
        r1.first_token_time = Some(SimTime::from_secs(0.1));
        r1.last_token_time = Some(SimTime::from_secs(1.1));
        r1.finished = Some(SimTime::from_secs(1.1));
        let mut r2 = Request::new(2, "llama3-70b", SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode], 100, 1);
        r2.decoded = 1;
        r2.first_token_time = Some(SimTime::from_secs(0.1));
        r2.last_token_time = Some(SimTime::from_secs(0.1));
        r2.finished = Some(SimTime::from_secs(0.1));
        // collect() consumes completion records, as the coordinator's
        // complete() would have produced them
        coord
            .records
            .push(crate::workload::request::CompletionRecord::of(&r1, false));
        coord
            .records
            .push(crate::workload::request::CompletionRecord::of(&r2, false));
        coord.pool.insert(1, r1);
        coord.pool.insert(2, r2);
        coord.serviced = vec![1, 2];
        coord.clock = SimTime::from_secs(1.1);

        let m = RunMetrics::collect(&coord, &SloLadder::standard());
        // the 1-token request must not contribute a 0.0 TPOT sample...
        assert_eq!(m.tpot_samples.len(), 1);
        assert!((m.tpot.p50 - 0.01).abs() < 1e-9, "p50={}", m.tpot.p50);
        // ...and it passes the per-request SLO check (TTFT ok, no TPOT)
        assert_eq!(m.goodput_frac, 1.0);
    }

    #[test]
    fn record_collection_matches_pool_scan() {
        // the record-based path must reproduce the legacy retained-pool
        // scan bit for bit (the full differential lives in
        // rust/tests/retirement_equivalence.rs)
        let coord = run_small();
        let slo = SloLadder::standard();
        let a = RunMetrics::collect(&coord, &slo);
        let b = RunMetrics::collect_from_pool(&coord, &slo);
        assert_eq!(a.n_requests, b.n_requests);
        assert_eq!(a.n_serviced, b.n_serviced);
        assert_eq!(a.ttft_samples, b.ttft_samples);
        assert_eq!(a.tpot_samples, b.tpot_samples);
        assert_eq!(a.e2e_samples, b.e2e_samples);
        assert_eq!(a.throughput_tok_s, b.throughput_tok_s);
        assert_eq!(a.goodput_frac, b.goodput_frac);
        assert_eq!(a.tok_per_joule, b.tok_per_joule);
    }

    #[test]
    fn sink_collection_matches_exact_within_alpha() {
        // identical run, streamed through the sink vs retained records:
        // counts/sums exact, percentiles within the sketch error bound,
        // and no sample vecs allocated on the streaming side
        let slo = SloLadder::standard();
        let exact = RunMetrics::collect(&run_small(), &slo);
        let coord = run_small_opts(true);
        assert!(coord.records.is_empty(), "sink mode must not retain records");
        assert!(coord.serviced.is_empty(), "sink mode collapses IDs to counters");
        let sk = RunMetrics::collect(&coord, &slo);
        assert!(exact.exact && !sk.exact);
        assert_eq!(sk.n_serviced, exact.n_serviced);
        assert_eq!(sk.n_failed, exact.n_failed);
        assert_eq!(sk.events, exact.events);
        assert_eq!(sk.makespan, exact.makespan);
        // token counts are integer-valued f64 sums — exactly equal
        assert_eq!(sk.throughput_tok_s, exact.throughput_tok_s);
        assert_eq!(sk.goodput_frac, exact.goodput_frac);
        assert_eq!(sk.energy_joules, exact.energy_joules);
        assert!(sk.e2e_samples.is_empty() && sk.ttft_samples.is_empty());
        for (s, e, name) in [
            (&sk.ttft, &exact.ttft, "ttft"),
            (&sk.tpot, &exact.tpot, "tpot"),
            (&sk.e2e, &exact.e2e, "e2e"),
        ] {
            assert_eq!(s.n, e.n, "{name} sample count");
            for (sv, ev, q) in [(s.p50, e.p50, "p50"), (s.p90, e.p90, "p90"), (s.p99, e.p99, "p99")] {
                assert!(
                    (sv - ev).abs() <= crate::util::stats::SKETCH_ALPHA * ev.abs() + 1e-12,
                    "{name} {q}: sketch={sv} exact={ev}"
                );
            }
            assert_eq!(s.min, e.min, "{name} min is tracked exactly");
            assert_eq!(s.max, e.max, "{name} max is tracked exactly");
        }
    }

    #[test]
    fn no_first_token_counted_not_poisoned() {
        use crate::sim::SimTime;
        use crate::workload::request::{Request, Stage};
        // r1 normal; r2 finished without ever emitting a first token
        let mut r1 = Request::new(1, "llama3-70b", SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode], 100, 10);
        r1.decoded = 10;
        r1.first_token_time = Some(SimTime::from_secs(0.1));
        r1.last_token_time = Some(SimTime::from_secs(0.5));
        r1.finished = Some(SimTime::from_secs(0.5));
        let mut r2 = Request::new(2, "llama3-70b", SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode], 100, 10);
        r2.finished = Some(SimTime::from_secs(0.2));
        let records = vec![
            CompletionRecord::of(&r1, false),
            CompletionRecord::of(&r2, false),
        ];
        let fold = RunMetrics::fold_records(&records, &SloLadder::standard());
        // regression: the ∞ sample is gone, the request is counted
        assert_eq!(fold.n_no_first_token, 1);
        assert_eq!(fold.ttft.len(), 1);
        assert!(fold.ttft[0].is_finite());
        assert!(fold.e2e.iter().all(|x| x.is_finite()));
        // the sink agrees
        let mut sink = MetricsSink::new(SloLadder::standard());
        for r in &records {
            sink.fold(r);
        }
        assert_eq!(sink.n_no_first_token, 1);
        assert_eq!(sink.ttft.count(), 1);
        assert_eq!(sink.n_completed(), 2);
        // and for normal runs (every record has a first token) the exact
        // path is pinned unchanged: no record drops out
        let m = RunMetrics::collect(&run_small(), &SloLadder::standard());
        assert_eq!(m.n_no_first_token, 0);
        assert_eq!(m.ttft_samples.len(), m.n_serviced);
    }

    #[test]
    fn json_roundtrips() {
        let coord = run_small();
        let m = RunMetrics::collect(&coord, &SloLadder::standard());
        let j = m.to_json();
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.usize_or("n_serviced", 0), 15);
        assert!(parsed.at(&["ttft", "p99"]).unwrap().as_f64().unwrap() > 0.0);
    }
}
