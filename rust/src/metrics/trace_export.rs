//! Chrome-trace export (paper §III-F.2: "All request-level execution
//! details are encoded in JSON format … enables seamless integration
//! with visualization tools, such as Chrome Tracing").
//!
//! Format: Trace Event Format "X" (complete) events; pid = client id,
//! tid = request id, one event per completed stage. Load the file at
//! chrome://tracing or ui.perfetto.dev.

use crate::coordinator::Coordinator;
use crate::util::json::Json;

/// Build the Chrome-trace document for a drained coordinator. Scans
/// the retained request pool, so it requires a run with request
/// retirement off (the default) — retired runs keep only compact
/// completion records, which carry no per-stage spans.
pub fn chrome_trace(coord: &Coordinator) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (id, r) in &coord.pool {
        for rec in &r.records {
            let mut e = Json::obj();
            let stage_name = r
                .stages
                .get(rec.stage_idx)
                .map(|s| s.name())
                .unwrap_or("stage");
            e.set("name", format!("{stage_name} r{id}"))
                .set("cat", stage_name)
                .set("ph", "X")
                .set("ts", rec.start.as_micros())
                .set("dur", (rec.end.saturating_sub(rec.start)).as_micros().max(1.0))
                .set("pid", rec.client)
                .set("tid", *id);
            events.push(e);
        }
        // arrival marker
        let mut m = Json::obj();
        m.set("name", format!("arrive r{id}"))
            .set("cat", "arrival")
            .set("ph", "i")
            .set("ts", r.arrival.as_micros())
            .set("pid", 0u64)
            .set("tid", *id)
            .set("s", "g");
        events.push(m);
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", "ms");
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Client, LlmClient};
    use crate::coordinator::{RoutePolicy, Router};
    use crate::hardware::models::LLAMA3_70B;
    use crate::hardware::npu::H100;
    use crate::hardware::roofline::LlmCluster;
    use crate::network::Network;
    use crate::perfmodel::RooflinePerfModel;
    use crate::scheduler::{BatchingKind, LlmSched, Packing, SchedConfig};
    use crate::workload::trace::{TraceKind, WorkloadSpec};

    #[test]
    fn trace_has_events_for_every_request() {
        let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);
        let clients: Vec<Box<dyn Client>> = vec![Box::new(LlmClient::new(
            0,
            cluster.clone(),
            LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        ))];
        let mut coord = Coordinator::new(
            clients,
            Router::new(RoutePolicy::RoundRobin),
            Network::single_platform(1),
        );
        coord.inject(
            WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 5, 2.0).generate(0),
        );
        coord.run();
        let doc = chrome_trace(&coord);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // ≥ 1 stage span + 1 arrival marker per request
        assert!(events.len() >= 10, "events={}", events.len());
        // valid JSON that chrome can parse
        let text = doc.to_string();
        assert!(Json::parse(&text).is_ok());
        // every span has non-negative duration
        for e in events {
            if e.str_or("ph", "") == "X" {
                assert!(e.f64_or("dur", -1.0) > 0.0);
            }
        }
    }
}
