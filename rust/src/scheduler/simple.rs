//! Base schedulers for non-LLM clients (paper §III-D):
//!
//! * `Batched` — "for single step tasks like word lookup. Batching all
//!   requests in the engine parallelly will extract maximum reuse."
//!   (RAG and KV-retrieval clients.)
//! * `Sequential` — "for tasks without reuse possibility, e.g. padding
//!   and truncation" — available cores drain the queue linearly.
//!   (Pre/post-processing clients.)

use std::collections::VecDeque;

use crate::workload::request::ReqId;

/// Take-all batching: a step services every queued request at once.
#[derive(Debug, Default)]
pub struct Batched {
    queue: VecDeque<ReqId>,
    /// optional cap per step (0 = unbounded)
    pub max_batch: usize,
}

impl Batched {
    pub fn new(max_batch: usize) -> Batched {
        Batched {
            queue: VecDeque::new(),
            max_batch,
        }
    }

    pub fn enqueue(&mut self, id: ReqId) {
        self.queue.push_back(id);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Drain the next step's batch.
    pub fn take_batch(&mut self) -> Vec<ReqId> {
        let n = if self.max_batch == 0 {
            self.queue.len()
        } else {
            self.queue.len().min(self.max_batch)
        };
        self.queue.drain(..n).collect()
    }

    /// Drop a queued request (fault eviction). Returns whether it was
    /// queued; queue order of the others is preserved.
    pub fn remove(&mut self, id: ReqId) -> bool {
        match self.queue.iter().position(|&q| q == id) {
            Some(i) => {
                self.queue.remove(i);
                true
            }
            None => false,
        }
    }
}

/// Core-parallel sequential service: `cores` requests at a time, each
/// taking its own service time.
#[derive(Debug)]
pub struct Sequential {
    queue: VecDeque<ReqId>,
    pub cores: usize,
}

impl Sequential {
    pub fn new(cores: usize) -> Sequential {
        assert!(cores > 0);
        Sequential {
            queue: VecDeque::new(),
            cores,
        }
    }

    pub fn enqueue(&mut self, id: ReqId) {
        self.queue.push_back(id);
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Next wave of up to `cores` requests.
    pub fn take_wave(&mut self) -> Vec<ReqId> {
        let n = self.queue.len().min(self.cores);
        self.queue.drain(..n).collect()
    }

    /// Drop a queued request (fault eviction). Returns whether it was
    /// queued; queue order of the others is preserved.
    pub fn remove(&mut self, id: ReqId) -> bool {
        match self.queue.iter().position(|&q| q == id) {
            Some(i) => {
                self.queue.remove(i);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_takes_everything() {
        let mut b = Batched::new(0);
        for i in 0..10 {
            b.enqueue(i);
        }
        assert_eq!(b.take_batch().len(), 10);
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn batched_respects_cap() {
        let mut b = Batched::new(4);
        for i in 0..10 {
            b.enqueue(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1, 2, 3]);
        assert_eq!(b.queue_len(), 6);
    }

    #[test]
    fn remove_drops_only_the_target() {
        let mut b = Batched::new(0);
        for i in 0..5 {
            b.enqueue(i);
        }
        assert!(b.remove(2));
        assert!(!b.remove(2), "already gone");
        assert_eq!(b.take_batch(), vec![0, 1, 3, 4]);
        let mut s = Sequential::new(8);
        for i in 0..4 {
            s.enqueue(i);
        }
        assert!(s.remove(0));
        assert!(!s.remove(9));
        assert_eq!(s.take_wave(), vec![1, 2, 3]);
    }

    #[test]
    fn sequential_waves_by_cores() {
        let mut s = Sequential::new(3);
        for i in 0..7 {
            s.enqueue(i);
        }
        assert_eq!(s.take_wave(), vec![0, 1, 2]);
        assert_eq!(s.take_wave(), vec![3, 4, 5]);
        assert_eq!(s.take_wave(), vec![6]);
        assert!(s.take_wave().is_empty());
    }
}
