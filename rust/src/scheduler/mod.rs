//! Client-side schedulers (paper §III-D).
//!
//! The LLM scheduler is modeled after vLLM's: a pluggable batching
//! policy ([`policy::BatchPolicy`]: static / continuous / chunked /
//! mixed / disaggregated-role, or user-defined), a request packing
//! policy (FCFS / Least-Work-Left), user constraints (max batched
//! sequences, max batched tokens) and KV memory admission (no admission
//! when the KV manager is full; eviction on completion).
//!
//! Non-LLM clients use the two base schedulers in [`simple`]: `Batched`
//! (single-step tasks with reuse, e.g. RAG lookups) and `Sequential`
//! (no-reuse tasks, e.g. padding/truncation).

pub mod llm;
pub mod packing;
pub mod policy;
pub mod pool;
pub mod simple;

use crate::workload::request::ReqId;

pub use llm::{BatchingKind, LaneSpec, LlmSched, SchedConfig};
pub use packing::Packing;
pub use policy::BatchPolicy;
pub use pool::{PoolBackend, PoolOps, RequestPool};

/// What one engine step executes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepPlan {
    /// (request, prompt tokens prefilled this step)
    pub prefill: Vec<(ReqId, usize)>,
    /// requests generating one token per branch this step
    pub decode: Vec<ReqId>,
}

impl StepPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_empty() && self.decode.is_empty()
    }

    /// Empty the plan, keeping the allocated capacity — plans are
    /// reusable buffers on the per-step hot path (owned by the client,
    /// filled by [`LlmSched::plan_into`]).
    pub fn clear(&mut self) {
        self.prefill.clear();
        self.decode.clear();
    }

    /// Total new prefill tokens in the step.
    pub fn prefill_tokens(&self) -> usize {
        self.prefill.iter().map(|(_, n)| n).sum()
    }

    /// Step features for the perf model.
    pub fn features(&self, pool: &RequestPool) -> crate::perfmodel::StepFeatures {
        let mut f = crate::perfmodel::StepFeatures::default();
        for (id, n) in &self.prefill {
            let r = &pool[id];
            f.pf_new += *n as f64;
            // chunked prefill attends over past ctx + already-prefilled part
            f.pf_past += (r.past_tokens + r.prefilled) as f64;
            f.pf_items += 1.0;
        }
        for id in &self.decode {
            let r = &pool[id];
            f.dec_batch += r.decode_seqs() as f64;
            f.dec_kv += r.kv_tokens() + r.decode_seqs() as f64; // +1/seq this step
        }
        f
    }
}
