//! Pluggable batching policies (paper §III-D.1).
//!
//! [`BatchPolicy`] is the extension point behind the LLM scheduler: a
//! policy decides *when* waiting requests may join the admitted set and
//! *what* one engine step executes. [`LlmSched`](super::LlmSched) owns
//! the queue/KV-reservation bookkeeping that is common to every policy
//! and delegates these two decisions, so adding a batching strategy —
//! or selecting one per client from a scenario file — requires no
//! scheduler or coordinator changes.
//!
//! Six built-in policies mirror the paper's roster:
//!
//! * [`StaticBatching`] — FasterTransformer-style: fill a batch, run it
//!   to completion, only then admit the next batch.
//! * [`ContinuousBatching`] — Orca/vLLM: admit every step,
//!   prefill-prioritized (a pending prefill preempts decoding).
//! * [`ChunkedPrefill`] — Sarathi/DeepSpeed-FastGen hybrid: fixed
//!   per-step token budget; decodes ride along with prefill chunks.
//! * [`MixedBatching`] — Splitwise mixed pool: full prefills and
//!   decodes co-scheduled without a chunk budget.
//! * [`PrefillRole`] / [`DecodeRole`] — the two halves of disaggregated
//!   serving (Splitwise/DistServe); the coordinator moves KV between
//!   them.

use super::packing::Packing;
use super::{RequestPool, SchedConfig, StepPlan};
use crate::workload::request::{ReqId, Request};

/// Read-only view of the scheduler state a policy composes steps from.
pub struct PlanCtx<'a> {
    /// admitted requests (KV reserved), in admission order
    pub running: &'a [ReqId],
    pub cfg: &'a SchedConfig,
    pub packing: Packing,
}

impl PlanCtx<'_> {
    /// Admitted requests whose prompt is not fully prefilled.
    pub fn prefillers(&self, pool: &RequestPool) -> Vec<ReqId> {
        self.running
            .iter()
            .copied()
            .filter(|id| !pool[id].prefill_complete())
            .collect()
    }

    /// Admitted requests ready to generate (prefill done, decode not).
    pub fn decoders(&self, pool: &RequestPool) -> Vec<ReqId> {
        self.running
            .iter()
            .copied()
            .filter(|id| pool[id].prefill_complete() && !pool[id].decode_complete())
            .collect()
    }
}

/// A batching strategy for one LLM client.
pub trait BatchPolicy {
    /// Stable label used in pool labels and reports.
    fn name(&self) -> &'static str;

    /// May waiting requests be admitted while earlier admissions are
    /// still in flight? Static batching answers `false`: a new batch
    /// forms only once the previous one fully drains.
    fn admits_mid_batch(&self) -> bool {
        true
    }

    /// Role gates for disaggregated serving; the client's
    /// `can_serve`/hand-off behavior derives from these.
    fn serves_prefill(&self) -> bool {
        true
    }

    fn serves_decode(&self) -> bool {
        true
    }

    /// KV tokens to reserve when admitting `r`. Combined clients
    /// reserve the full decode-complete peak; a prefill-only client
    /// overrides this to the prefix footprint it actually holds.
    fn admit_tokens(&self, r: &Request) -> f64 {
        r.kv_tokens_peak()
    }

    /// Compose the next engine step from the admitted set; `None` (or an
    /// empty plan) when this policy has nothing to run.
    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan>;
}

/// FasterTransformer-style run-to-completion batching.
pub struct StaticBatching;

impl BatchPolicy for StaticBatching {
    fn name(&self) -> &'static str {
        "static"
    }

    fn admits_mid_batch(&self) -> bool {
        false
    }

    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
        if ctx.running.is_empty() {
            return None;
        }
        let pf = ctx.prefillers(pool);
        if !pf.is_empty() {
            // whole prompts, one step (FasterTransformer has no chunking)
            return Some(StepPlan {
                prefill: pf
                    .iter()
                    .map(|id| (*id, pool[id].prefill_remaining()))
                    .collect(),
                decode: Vec::new(),
            });
        }
        Some(StepPlan {
            prefill: Vec::new(),
            decode: ctx.decoders(pool),
        })
    }
}

/// Orca/vLLM continuous (in-flight) batching, prefill-prioritized.
pub struct ContinuousBatching;

impl BatchPolicy for ContinuousBatching {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
        if ctx.running.is_empty() {
            return None;
        }
        // prefill-prioritized: pending prefills preempt decode
        let mut pf = ctx.prefillers(pool);
        if !pf.is_empty() {
            ctx.packing.order(&mut pf, pool);
            let mut budget = ctx.cfg.max_batch_tokens;
            let mut prefill = Vec::new();
            for id in pf {
                if budget == 0 {
                    break;
                }
                let take = pool[&id].prefill_remaining().min(budget);
                // continuous batching does not split prompts: take all or
                // wait (unless a single prompt alone exceeds the budget)
                if take < pool[&id].prefill_remaining() && !prefill.is_empty() {
                    break;
                }
                budget -= take;
                prefill.push((id, take));
            }
            if !prefill.is_empty() {
                return Some(StepPlan {
                    prefill,
                    decode: Vec::new(),
                });
            }
        }
        let dec = ctx.decoders(pool);
        if dec.is_empty() {
            return None;
        }
        Some(StepPlan {
            prefill: Vec::new(),
            decode: dec,
        })
    }
}

/// Sarathi/DeepSpeed-FastGen chunked-prefill hybrid batching.
pub struct ChunkedPrefill {
    /// per-step token budget shared by decodes and prefill chunks
    pub chunk: usize,
}

impl BatchPolicy for ChunkedPrefill {
    fn name(&self) -> &'static str {
        "chunked"
    }

    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
        if ctx.running.is_empty() {
            return None;
        }
        // decodes ride in every step (1 token per branch-sequence)...
        let decode = ctx.decoders(pool);
        let dec_tokens: usize = decode.iter().map(|id| pool[id].decode_seqs()).sum();
        // ...and the remaining budget is filled with prefill chunks
        let mut budget = self.chunk.saturating_sub(dec_tokens);
        let mut pf = ctx.prefillers(pool);
        ctx.packing.order(&mut pf, pool);
        let mut prefill = Vec::new();
        for id in pf {
            if budget == 0 {
                break;
            }
            let take = pool[&id].prefill_remaining().min(budget);
            budget -= take;
            prefill.push((id, take));
        }
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(StepPlan { prefill, decode })
    }
}

/// Splitwise mixed pool: full prefills co-scheduled with decodes.
pub struct MixedBatching;

impl BatchPolicy for MixedBatching {
    fn name(&self) -> &'static str {
        "mixed"
    }

    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
        if ctx.running.is_empty() {
            return None;
        }
        let mut pf = ctx.prefillers(pool);
        ctx.packing.order(&mut pf, pool);
        let mut budget = ctx.cfg.max_batch_tokens;
        let mut prefill = Vec::new();
        for id in pf {
            let take = pool[&id].prefill_remaining().min(budget);
            if take == 0 {
                break;
            }
            budget -= take;
            prefill.push((id, take));
        }
        let decode = ctx.decoders(pool);
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(StepPlan { prefill, decode })
    }
}

/// Prefill half of a disaggregated deployment: prefills only, reserves
/// only the prefix KV it holds, hands finished prompts to the
/// coordinator for transfer to a decode client.
pub struct PrefillRole;

impl BatchPolicy for PrefillRole {
    fn name(&self) -> &'static str {
        "prefill-only"
    }

    fn serves_decode(&self) -> bool {
        false
    }

    fn admit_tokens(&self, r: &Request) -> f64 {
        (r.past_tokens + r.prompt_tokens) as f64
    }

    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
        let mut pf = ctx.prefillers(pool);
        if pf.is_empty() {
            return None;
        }
        ctx.packing.order(&mut pf, pool);
        let mut budget = ctx.cfg.max_batch_tokens;
        let mut prefill = Vec::new();
        for id in pf {
            if budget == 0 {
                break;
            }
            let take = pool[&id].prefill_remaining().min(budget);
            if take < pool[&id].prefill_remaining() && !prefill.is_empty() {
                break; // no chunking across steps beyond the head request
            }
            budget -= take;
            prefill.push((id, take));
        }
        Some(StepPlan {
            prefill,
            decode: Vec::new(),
        })
    }
}

/// Decode half of a disaggregated deployment: batches transferred-in
/// requests for generation only.
pub struct DecodeRole;

impl BatchPolicy for DecodeRole {
    fn name(&self) -> &'static str {
        "decode-only"
    }

    fn serves_prefill(&self) -> bool {
        false
    }

    fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
        let dec = ctx.decoders(pool);
        if dec.is_empty() {
            return None;
        }
        Some(StepPlan {
            prefill: Vec::new(),
            decode: dec,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BatchingKind, LlmSched};
    use super::*;
    use crate::memory::hierarchy::KvManager;
    use crate::sim::SimTime;
    use crate::workload::request::Stage;

    fn mk(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::from_secs(id as f64 * 0.01),
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    fn sched(kind: BatchingKind, reqs: Vec<Request>) -> (LlmSched, RequestPool, KvManager) {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(kind, Packing::Fcfs, SchedConfig::default());
        for r in reqs {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        (s, pool, KvManager::new(1e9))
    }

    fn apply(plan: &StepPlan, pool: &mut RequestPool) {
        for (id, n) in &plan.prefill {
            pool.get_mut(id).unwrap().prefilled += n;
        }
        for id in &plan.decode {
            pool.get_mut(id).unwrap().decoded += 1;
        }
    }

    /// The satellite's headline contract: continuous batching admits a
    /// request that arrives mid-iteration into the very next step, while
    /// static batching makes it wait for the in-flight batch to drain.
    #[test]
    fn continuous_admits_mid_iteration_static_does_not() {
        for (kind, admitted_next_step) in [
            (BatchingKind::Continuous, true),
            (BatchingKind::Static, false),
        ] {
            let (mut s, mut pool, mut kv) = sched(kind, vec![mk(1, 100, 4)]);
            apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill req 1
            apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode step

            // request 2 arrives while request 1 is mid-decode
            pool.insert(2, mk(2, 50, 4));
            s.enqueue(2);
            let p = s.plan(&pool, &mut kv).unwrap();
            let planned_for_2 = p.prefill.iter().any(|(id, _)| *id == 2);
            assert_eq!(
                planned_for_2, admitted_next_step,
                "{}: mid-iteration arrival",
                kind.name()
            );
            if !admitted_next_step {
                // static: request 2 still waiting, batch of 1 decodes on
                assert_eq!(s.queue_len(), 1);
                assert_eq!(p.decode, vec![1]);
            }
        }
    }

    #[test]
    fn policy_roles_gate_stages() {
        assert!(PrefillRole.serves_prefill() && !PrefillRole.serves_decode());
        assert!(!DecodeRole.serves_prefill() && DecodeRole.serves_decode());
        assert!(ContinuousBatching.serves_prefill() && ContinuousBatching.serves_decode());
        assert!(!StaticBatching.admits_mid_batch());
        assert!(ChunkedPrefill { chunk: 512 }.admits_mid_batch());
    }

    #[test]
    fn prefill_role_reserves_prefix_only() {
        let mut r = mk(1, 1000, 400);
        r.branches = 4;
        assert_eq!(PrefillRole.admit_tokens(&r), 1000.0);
        // combined policies reserve the decode-complete peak
        assert_eq!(ContinuousBatching.admit_tokens(&r), 1000.0 + 4.0 * 400.0);
    }

    #[test]
    fn chunked_budget_shared_between_decode_and_prefill() {
        let (mut s, mut pool, mut kv) = sched(
            BatchingKind::Chunked { chunk: 128 },
            vec![mk(1, 64, 8), mk(2, 1000, 8)],
        );
        // step 1: 64 (req1) + 64 (req2 chunk)
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill_tokens(), 128);
        apply(&p1, &mut pool);
        // step 2: req1 decodes (1 token), req2 gets 127 budget
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.decode, vec![1]);
        assert_eq!(p2.prefill, vec![(2, 127)]);
    }

    #[test]
    fn custom_policy_plugs_into_scheduler() {
        /// Decode-first toy policy: drains all decodes before any
        /// prefill — the inverse of continuous batching's priority.
        struct DecodeFirst;
        impl BatchPolicy for DecodeFirst {
            fn name(&self) -> &'static str {
                "decode-first"
            }
            fn compose(&self, ctx: &PlanCtx, pool: &RequestPool) -> Option<StepPlan> {
                let dec = ctx.decoders(pool);
                if !dec.is_empty() {
                    return Some(StepPlan { prefill: Vec::new(), decode: dec });
                }
                ContinuousBatching.compose(ctx, pool)
            }
        }

        let mut pool = RequestPool::new();
        let mut s = LlmSched::with_policy(
            Box::new(DecodeFirst),
            Packing::Fcfs,
            SchedConfig::default(),
        );
        let mut kv = KvManager::new(1e9);
        for r in [mk(1, 100, 4), mk(2, 100, 4)] {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        assert_eq!(s.policy().name(), "decode-first");
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill both
        pool.insert(3, mk(3, 100, 4));
        s.enqueue(3);
        // decode-first: the new prefill does NOT preempt
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.decode.len(), 2);
        assert!(p.prefill.is_empty());
    }
}
