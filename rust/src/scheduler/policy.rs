//! Pluggable batching policies (paper §III-D.1).
//!
//! [`BatchPolicy`] is the extension point behind the LLM scheduler: a
//! policy decides *when* waiting requests may join the admitted set and
//! *what* one engine step executes. [`LlmSched`](super::LlmSched) owns
//! the queue/KV-reservation bookkeeping that is common to every policy
//! and delegates these two decisions, so adding a batching strategy —
//! or selecting one per client from a scenario file — requires no
//! scheduler or coordinator changes.
//!
//! Composition is allocation-free on the hot path: policies *fill* a
//! caller-owned [`StepPlan`] buffer instead of returning a fresh one,
//! and the ordered-prefiller list lives in a scratch buffer owned by
//! the scheduler and lent out through [`PlanCtx`]. Scratch ownership
//! rules (docs/performance.md): the buffer is valid only inside one
//! `compose` call — [`PlanCtx::prefillers`] clears and refills it, so
//! policies must consume it before asking for it again.
//!
//! Six built-in policies mirror the paper's roster:
//!
//! * [`StaticBatching`] — FasterTransformer-style: fill a batch, run it
//!   to completion, only then admit the next batch.
//! * [`ContinuousBatching`] — Orca/vLLM: admit every step,
//!   prefill-prioritized (a pending prefill preempts decoding).
//! * [`ChunkedPrefill`] — Sarathi/DeepSpeed-FastGen hybrid: fixed
//!   per-step token budget; decodes ride along with prefill chunks.
//! * [`MixedBatching`] — Splitwise mixed pool: full prefills and
//!   decodes co-scheduled without a chunk budget.
//! * [`PrefillRole`] / [`DecodeRole`] — the two halves of disaggregated
//!   serving (Splitwise/DistServe); the coordinator moves KV between
//!   them.

use super::packing::Packing;
use super::{RequestPool, SchedConfig, StepPlan};
use crate::workload::request::{ReqId, Request};

/// View of the scheduler state a policy composes steps from, plus the
/// scheduler-owned scratch buffer behind [`PlanCtx::prefillers`].
pub struct PlanCtx<'a> {
    /// admitted requests (KV reserved), in admission order
    pub running: &'a [ReqId],
    pub cfg: &'a SchedConfig,
    pub packing: Packing,
    /// reusable id buffer (owned by the scheduler; overwritten by
    /// [`PlanCtx::prefillers`] on every call)
    pub scratch: &'a mut Vec<ReqId>,
}

impl PlanCtx<'_> {
    /// Admitted requests whose prompt is not fully prefilled, in
    /// admission order, filled into the reusable scratch buffer. The
    /// returned buffer is invalidated by the next `prefillers` call.
    pub fn prefillers(&mut self, pool: &RequestPool) -> &mut Vec<ReqId> {
        let running = self.running;
        let scratch = &mut *self.scratch;
        scratch.clear();
        scratch.extend(
            running
                .iter()
                .copied()
                .filter(|id| !pool[id].prefill_complete()),
        );
        scratch
    }

    /// Append the admitted requests ready to generate (prefill done,
    /// decode not) to `out`, in admission order.
    pub fn decoders_into(&self, pool: &RequestPool, out: &mut Vec<ReqId>) {
        out.extend(self.running.iter().copied().filter(|id| {
            let r = &pool[id];
            r.prefill_complete() && !r.decode_complete()
        }));
    }
}

/// A batching strategy for one LLM client.
pub trait BatchPolicy {
    /// Stable label used in pool labels and reports.
    fn name(&self) -> &'static str;

    /// May waiting requests be admitted while earlier admissions are
    /// still in flight? Static batching answers `false`: a new batch
    /// forms only once the previous one fully drains.
    fn admits_mid_batch(&self) -> bool {
        true
    }

    /// Role gates for disaggregated serving; the client's
    /// `can_serve`/hand-off behavior derives from these.
    fn serves_prefill(&self) -> bool {
        true
    }

    fn serves_decode(&self) -> bool {
        true
    }

    /// KV tokens to reserve when admitting `r`. Combined clients
    /// reserve the full decode-complete peak; a prefill-only client
    /// overrides this to the prefix footprint it actually holds.
    fn admit_tokens(&self, r: &Request) -> f64 {
        r.kv_tokens_peak()
    }

    /// Compose the next engine step from the admitted set into `plan`
    /// (handed over empty; left empty when there is nothing to run).
    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan);
}

/// FasterTransformer-style run-to-completion batching.
pub struct StaticBatching;

impl BatchPolicy for StaticBatching {
    fn name(&self) -> &'static str {
        "static"
    }

    fn admits_mid_batch(&self) -> bool {
        false
    }

    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
        if ctx.running.is_empty() {
            return;
        }
        // whole prompts, one step (FasterTransformer has no chunking)
        for id in ctx.running {
            let r = &pool[id];
            if !r.prefill_complete() {
                plan.prefill.push((*id, r.prefill_remaining()));
            }
        }
        if plan.prefill.is_empty() {
            ctx.decoders_into(pool, &mut plan.decode);
        }
    }
}

/// Orca/vLLM continuous (in-flight) batching, prefill-prioritized.
pub struct ContinuousBatching;

impl BatchPolicy for ContinuousBatching {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
        if ctx.running.is_empty() {
            return;
        }
        // prefill-prioritized: pending prefills preempt decode
        let packing = ctx.packing;
        let mut budget = ctx.cfg.max_batch_tokens;
        let pf = ctx.prefillers(pool);
        if !pf.is_empty() {
            packing.order(pf, pool);
            for id in pf.iter() {
                if budget == 0 {
                    break;
                }
                let take = pool[id].prefill_remaining().min(budget);
                // continuous batching does not split prompts: take all or
                // wait (unless a single prompt alone exceeds the budget)
                if take < pool[id].prefill_remaining() && !plan.prefill.is_empty() {
                    break;
                }
                budget -= take;
                plan.prefill.push((*id, take));
            }
            if !plan.prefill.is_empty() {
                return;
            }
        }
        ctx.decoders_into(pool, &mut plan.decode);
    }
}

/// Sarathi/DeepSpeed-FastGen chunked-prefill hybrid batching.
pub struct ChunkedPrefill {
    /// per-step token budget shared by decodes and prefill chunks
    pub chunk: usize,
}

impl BatchPolicy for ChunkedPrefill {
    fn name(&self) -> &'static str {
        "chunked"
    }

    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
        if ctx.running.is_empty() {
            return;
        }
        // decodes ride in every step (1 token per branch-sequence)...
        ctx.decoders_into(pool, &mut plan.decode);
        let dec_tokens: usize = plan.decode.iter().map(|id| pool[id].decode_seqs()).sum();
        // ...and the remaining budget is filled with prefill chunks
        let mut budget = self.chunk.saturating_sub(dec_tokens);
        let packing = ctx.packing;
        let pf = ctx.prefillers(pool);
        packing.order(pf, pool);
        for id in pf.iter() {
            if budget == 0 {
                break;
            }
            let take = pool[id].prefill_remaining().min(budget);
            budget -= take;
            plan.prefill.push((*id, take));
        }
    }
}

/// Splitwise mixed pool: full prefills co-scheduled with decodes.
pub struct MixedBatching;

impl BatchPolicy for MixedBatching {
    fn name(&self) -> &'static str {
        "mixed"
    }

    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
        if ctx.running.is_empty() {
            return;
        }
        let packing = ctx.packing;
        let mut budget = ctx.cfg.max_batch_tokens;
        let pf = ctx.prefillers(pool);
        packing.order(pf, pool);
        for id in pf.iter() {
            let take = pool[id].prefill_remaining().min(budget);
            if take == 0 {
                break;
            }
            budget -= take;
            plan.prefill.push((*id, take));
        }
        ctx.decoders_into(pool, &mut plan.decode);
    }
}

/// Prefill half of a disaggregated deployment: prefills only, reserves
/// only the prefix KV it holds, hands finished prompts to the
/// coordinator for transfer to a decode client.
pub struct PrefillRole;

impl BatchPolicy for PrefillRole {
    fn name(&self) -> &'static str {
        "prefill-only"
    }

    fn serves_decode(&self) -> bool {
        false
    }

    fn admit_tokens(&self, r: &Request) -> f64 {
        (r.past_tokens + r.prompt_tokens) as f64
    }

    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
        let packing = ctx.packing;
        let mut budget = ctx.cfg.max_batch_tokens;
        let pf = ctx.prefillers(pool);
        if pf.is_empty() {
            return;
        }
        packing.order(pf, pool);
        for id in pf.iter() {
            if budget == 0 {
                break;
            }
            let take = pool[id].prefill_remaining().min(budget);
            if take < pool[id].prefill_remaining() && !plan.prefill.is_empty() {
                break; // no chunking across steps beyond the head request
            }
            budget -= take;
            plan.prefill.push((*id, take));
        }
    }
}

/// Decode half of a disaggregated deployment: batches transferred-in
/// requests for generation only.
pub struct DecodeRole;

impl BatchPolicy for DecodeRole {
    fn name(&self) -> &'static str {
        "decode-only"
    }

    fn serves_prefill(&self) -> bool {
        false
    }

    fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
        ctx.decoders_into(pool, &mut plan.decode);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BatchingKind, LlmSched};
    use super::*;
    use crate::memory::hierarchy::KvManager;
    use crate::sim::SimTime;
    use crate::workload::request::Stage;

    fn mk(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::from_secs(id as f64 * 0.01),
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    fn sched(kind: BatchingKind, reqs: Vec<Request>) -> (LlmSched, RequestPool, KvManager) {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(kind, Packing::Fcfs, SchedConfig::default());
        for r in reqs {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        (s, pool, KvManager::new(1e9))
    }

    fn apply(plan: &StepPlan, pool: &mut RequestPool) {
        for (id, n) in &plan.prefill {
            pool.get_mut(id).unwrap().prefilled += n;
        }
        for id in &plan.decode {
            pool.get_mut(id).unwrap().decoded += 1;
        }
    }

    /// The satellite's headline contract: continuous batching admits a
    /// request that arrives mid-iteration into the very next step, while
    /// static batching makes it wait for the in-flight batch to drain.
    #[test]
    fn continuous_admits_mid_iteration_static_does_not() {
        for (kind, admitted_next_step) in [
            (BatchingKind::Continuous, true),
            (BatchingKind::Static, false),
        ] {
            let (mut s, mut pool, mut kv) = sched(kind, vec![mk(1, 100, 4)]);
            apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill req 1
            apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode step

            // request 2 arrives while request 1 is mid-decode
            pool.insert(2, mk(2, 50, 4));
            s.enqueue(2);
            let p = s.plan(&pool, &mut kv).unwrap();
            let planned_for_2 = p.prefill.iter().any(|(id, _)| *id == 2);
            assert_eq!(
                planned_for_2, admitted_next_step,
                "{}: mid-iteration arrival",
                kind.name()
            );
            if !admitted_next_step {
                // static: request 2 still waiting, batch of 1 decodes on
                assert_eq!(s.queue_len(), 1);
                assert_eq!(p.decode, vec![1]);
            }
        }
    }

    #[test]
    fn policy_roles_gate_stages() {
        assert!(PrefillRole.serves_prefill() && !PrefillRole.serves_decode());
        assert!(!DecodeRole.serves_prefill() && DecodeRole.serves_decode());
        assert!(ContinuousBatching.serves_prefill() && ContinuousBatching.serves_decode());
        assert!(!StaticBatching.admits_mid_batch());
        assert!(ChunkedPrefill { chunk: 512 }.admits_mid_batch());
    }

    #[test]
    fn prefill_role_reserves_prefix_only() {
        let mut r = mk(1, 1000, 400);
        r.branches = 4;
        assert_eq!(PrefillRole.admit_tokens(&r), 1000.0);
        // combined policies reserve the decode-complete peak
        assert_eq!(ContinuousBatching.admit_tokens(&r), 1000.0 + 4.0 * 400.0);
    }

    #[test]
    fn chunked_budget_shared_between_decode_and_prefill() {
        let (mut s, mut pool, mut kv) = sched(
            BatchingKind::Chunked { chunk: 128 },
            vec![mk(1, 64, 8), mk(2, 1000, 8)],
        );
        // step 1: 64 (req1) + 64 (req2 chunk)
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill_tokens(), 128);
        apply(&p1, &mut pool);
        // step 2: req1 decodes (1 token), req2 gets 127 budget
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.decode, vec![1]);
        assert_eq!(p2.prefill, vec![(2, 127)]);
    }

    #[test]
    fn custom_policy_plugs_into_scheduler() {
        /// Decode-first toy policy: drains all decodes before any
        /// prefill — the inverse of continuous batching's priority.
        struct DecodeFirst;
        impl BatchPolicy for DecodeFirst {
            fn name(&self) -> &'static str {
                "decode-first"
            }
            fn compose(&self, ctx: &mut PlanCtx, pool: &RequestPool, plan: &mut StepPlan) {
                ctx.decoders_into(pool, &mut plan.decode);
                if plan.decode.is_empty() {
                    ContinuousBatching.compose(ctx, pool, plan);
                }
            }
        }

        let mut pool = RequestPool::new();
        let mut s = LlmSched::with_policy(
            Box::new(DecodeFirst),
            Packing::Fcfs,
            SchedConfig::default(),
        );
        let mut kv = KvManager::new(1e9);
        for r in [mk(1, 100, 4), mk(2, 100, 4)] {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        assert_eq!(s.policy().name(), "decode-first");
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill both
        pool.insert(3, mk(3, 100, 4));
        s.enqueue(3);
        // decode-first: the new prefill does NOT preempt
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.decode.len(), 2);
        assert!(p.prefill.is_empty());
    }

    #[test]
    fn plan_buffer_reuse_is_clean_across_steps() {
        // plan_into must fully overwrite a dirty buffer
        let (mut s, mut pool, mut kv) =
            sched(BatchingKind::Continuous, vec![mk(1, 100, 3), mk(2, 200, 3)]);
        let mut plan = StepPlan::default();
        assert!(s.plan_into(&pool, &mut kv, &mut plan));
        assert_eq!(plan.prefill.len(), 2);
        apply(&plan, &mut pool);
        // same buffer, next step: prefill entries must be gone
        assert!(s.plan_into(&pool, &mut kv, &mut plan));
        assert!(plan.prefill.is_empty());
        assert_eq!(plan.decode, vec![1, 2]);
    }
}
