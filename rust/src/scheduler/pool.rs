//! Arena-backed request pool with slot recycling.
//!
//! The coordinator owns every live request and the hot loop touches the
//! pool on every event: scheduler admission, step planning, token
//! progress, load release, routing. The seed kept the pool as a
//! `HashMap<ReqId, Request>`, which pays a hash per access and
//! pointer-chases on iteration; worse, `recompute_load` (the full-scan
//! baseline and the debug-mode drift invariant) scanned the *entire*
//! pool per client.
//!
//! [`RequestPool`] replaces it with a dense arena plus a slot
//! indirection layer: request ids are assigned sequentially by the
//! workload generators (`WorkloadSpec::generate` / `WorkloadMix::
//! generate` / the streaming source hand out dense id ranges from 0),
//! so an `index: Vec<u32>` maps each id to its payload slot in O(1)
//! with no hashing. Retiring a request ([`RequestPool::remove`]) frees
//! its slot through a LIFO freelist, so under request retirement the
//! payload storage — the `Request` structs with their heap-allocated
//! `stages`/`records` — is **O(peak in-flight)**, not O(total
//! injected); only the 4-byte indirection entry per id ever seen
//! remains. A per-client *resident index* (`by_client` + per-slot
//! position) is maintained by [`RequestPool::assign`] /
//! [`RequestPool::unassign`] in O(1), so per-client recomputation
//! ([`RequestPool::iter_client`]) is O(resident on that client).
//!
//! Both backends reject duplicate ids with the same panic — the
//! coordinator's injection paths rely on ids being unique, and the
//! arena would otherwise corrupt its resident index where the map
//! would silently overwrite.
//!
//! The old map representation survives as [`PoolBackend::Map`] — a
//! reference implementation behind the same API, used by the
//! differential tests (`rust/tests/pool_equivalence.rs`,
//! `rust/tests/retirement_equivalence.rs`) and the `hermes bench`
//! hashmap baseline to prove the arena is behaviorally invisible and
//! measurably faster.
//!
//! Every access is counted (reads via a `Cell`, so `Index` can count
//! too); `hermes bench` reports the totals, the live/resident
//! high-water marks and a resident-bytes estimate (see [`PoolOps`]).

use std::cell::Cell;
use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::workload::request::{ReqId, Request};

/// Which storage backs the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolBackend {
    /// dense `Vec` slots behind an id→slot indirection — the shipping
    /// configuration
    Arena,
    /// `HashMap` reference implementation — differential tests and the
    /// `hermes bench` pre-arena baseline only
    Map,
}

impl PoolBackend {
    pub fn name(&self) -> &'static str {
        match self {
            PoolBackend::Arena => "arena",
            PoolBackend::Map => "hashmap",
        }
    }
}

/// `index` sentinel: the id has no payload slot (never inserted, or
/// retired).
const NO_SLOT: u32 = u32::MAX;

enum Backend {
    Arena {
        /// payload slots; capacity grows only when the freelist is
        /// empty, so `slots.len()` is the high-water mark of
        /// simultaneously live requests
        slots: Vec<Option<Request>>,
        /// position of each *slot* inside its client's resident list
        /// (`u32::MAX` = unassigned); parallel to `slots`
        pos: Vec<u32>,
        /// id → slot indirection (`NO_SLOT` = not stored); 4 bytes per
        /// id ever seen
        index: Vec<u32>,
        /// vacated slots awaiting reuse (LIFO — deterministic, and the
        /// warmest slot is reused first)
        free: Vec<u32>,
        len: usize,
    },
    Map {
        map: HashMap<ReqId, Request>,
    },
}

/// Pool operation counters for the bench harness (`hermes bench`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolOps {
    pub reads: u64,
    pub writes: u64,
    /// allocated payload slots (map backend: live entries) — under
    /// retirement this tracks peak in-flight, not total injected
    pub slots: usize,
    /// requests currently stored
    pub len: usize,
    /// high-water mark of `len` — `peak_resident_slots` in BENCH_core
    pub peak_live: usize,
    /// requests retired via [`RequestPool::remove`]
    pub retired: u64,
    /// estimated bytes of currently stored requests (struct + pipeline
    /// array; see `request_bytes_est`)
    pub bytes_est: usize,
    /// high-water mark of `bytes_est` — `resident_bytes_est` in BENCH_core
    pub peak_bytes_est: usize,
    /// requests currently resident on some client
    pub resident: usize,
    /// high-water mark of `resident` — the client-occupancy peak
    pub peak_resident: usize,
}

impl PoolOps {
    /// Fold another pool's counters into this one — the sharded
    /// coordinator ([`crate::coordinator::shard`]) merges its
    /// per-domain pools with this. Totals add exactly; peaks add too,
    /// so a merged peak *bounds* the equivalent serial run's peak
    /// (domains hit their high-water marks at different instants)
    /// rather than equaling it.
    pub fn absorb(&mut self, other: &PoolOps) {
        self.reads += other.reads;
        self.writes += other.writes;
        self.slots += other.slots;
        self.len += other.len;
        self.peak_live += other.peak_live;
        self.retired += other.retired;
        self.bytes_est += other.bytes_est;
        self.peak_bytes_est += other.peak_bytes_est;
        self.resident += other.resident;
        self.peak_resident += other.peak_resident;
    }
}

/// The requests a simulation run owns, indexed by their dense id.
pub struct RequestPool {
    backend: Backend,
    /// resident request ids per client (index = client id)
    by_client: Vec<Vec<ReqId>>,
    resident: usize,
    peak_resident: usize,
    peak_live: usize,
    retired: u64,
    live_bytes: usize,
    peak_bytes: usize,
    /// `Cell` so `Index`/`get` (shared-ref paths) can count too.
    /// Per-instance, not global: each coordinator owns its pool, so
    /// parallel sweep workers (`sim::parallel`) count independently —
    /// `Cell` is `Send` (the pool moves with its coordinator into a
    /// worker) and the pool is never shared *between* threads
    /// (`rust/tests/pool_counters.rs` pins the isolation).
    reads: Cell<u64>,
    writes: Cell<u64>,
}

// a coordinator (and thus its pool) is built inside one sweep worker
// and stays there; this assertion keeps the pool from ever growing a
// field (e.g. `Rc`) that would silently break that pattern
const _: fn() = || {
    fn assert_send<T: Send>() {}
    assert_send::<RequestPool>();
};

/// Rough resident footprint of one request. The pipeline array is a
/// fixed-capacity [`StageList`](crate::workload::request::StageList)
/// inline in the struct, so the struct size covers it. `records` is
/// excluded — it grows *during* residence, and using the same formula
/// at insert and remove keeps the running total drift-free. An estimate
/// for the bench columns, not an allocator measurement.
fn request_bytes_est(_r: &Request) -> usize {
    std::mem::size_of::<Request>()
}

impl Default for RequestPool {
    fn default() -> RequestPool {
        RequestPool::new()
    }
}

impl RequestPool {
    /// An empty arena-backed pool (the default everywhere).
    pub fn new() -> RequestPool {
        RequestPool::with_backend(PoolBackend::Arena)
    }

    /// The `HashMap` reference backend (differential tests / bench).
    pub fn map_backed() -> RequestPool {
        RequestPool::with_backend(PoolBackend::Map)
    }

    pub fn with_backend(backend: PoolBackend) -> RequestPool {
        let backend = match backend {
            PoolBackend::Arena => Backend::Arena {
                slots: Vec::new(),
                pos: Vec::new(),
                index: Vec::new(),
                free: Vec::new(),
                len: 0,
            },
            PoolBackend::Map => Backend::Map {
                map: HashMap::new(),
            },
        };
        RequestPool {
            backend,
            by_client: Vec::new(),
            resident: 0,
            peak_resident: 0,
            peak_live: 0,
            retired: 0,
            live_bytes: 0,
            peak_bytes: 0,
            reads: Cell::new(0),
            writes: Cell::new(0),
        }
    }

    pub fn backend(&self) -> PoolBackend {
        match self.backend {
            Backend::Arena { .. } => PoolBackend::Arena,
            Backend::Map { .. } => PoolBackend::Map,
        }
    }

    /// Store `r` under `id`. Ids must be dense-ish (the arena's
    /// indirection grows to the largest id seen) and unique among
    /// stored requests: inserting an id that is currently present
    /// panics — identically on both backends — while re-inserting an
    /// id whose previous payload was [`RequestPool::remove`]d is fine.
    pub fn insert(&mut self, id: ReqId, r: Request) {
        debug_assert_eq!(id, r.id, "pool key must equal the request id");
        self.writes.set(self.writes.get() + 1);
        self.live_bytes += request_bytes_est(&r);
        match &mut self.backend {
            Backend::Arena {
                slots,
                pos,
                index,
                free,
                len,
            } => {
                let i = id as usize;
                if i >= index.len() {
                    index.resize(i + 1, NO_SLOT);
                }
                assert!(index[i] == NO_SLOT, "pool: duplicate request id {id}");
                let slot = match free.pop() {
                    Some(s) => {
                        slots[s as usize] = Some(r);
                        s
                    }
                    None => {
                        slots.push(Some(r));
                        pos.push(u32::MAX);
                        (slots.len() - 1) as u32
                    }
                };
                index[i] = slot;
                *len += 1;
            }
            Backend::Map { map } => match map.entry(id) {
                Entry::Occupied(_) => panic!("pool: duplicate request id {id}"),
                Entry::Vacant(v) => {
                    v.insert(r);
                }
            },
        }
        self.peak_live = self.peak_live.max(self.len());
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
    }

    /// Retire `id`: take its payload out and (arena) recycle the slot
    /// through the freelist; the id's indirection entry is cleared, so
    /// later `get(id)` returns `None`. Panics on an unknown id; the
    /// request must not be client-resident.
    pub fn remove(&mut self, id: ReqId) -> Request {
        self.writes.set(self.writes.get() + 1);
        let r = match &mut self.backend {
            Backend::Arena {
                slots,
                pos,
                index,
                free,
                len,
            } => {
                let i = id as usize;
                let slot = index.get(i).copied().unwrap_or(NO_SLOT);
                assert!(slot != NO_SLOT, "pool: remove of unknown request id {id}");
                index[i] = NO_SLOT;
                debug_assert_eq!(
                    pos[slot as usize],
                    u32::MAX,
                    "pool: removed a client-resident request"
                );
                let r = slots[slot as usize].take().expect("pool: index/slot drift");
                free.push(slot);
                *len -= 1;
                r
            }
            Backend::Map { map } => map
                .remove(&id)
                .unwrap_or_else(|| panic!("pool: remove of unknown request id {id}")),
        };
        debug_assert!(r.client.is_none(), "pool: removed a client-resident request");
        self.retired += 1;
        self.live_bytes = self.live_bytes.saturating_sub(request_bytes_est(&r));
        r
    }

    /// Arena slot currently backing `id` (`None`: map backend, or not
    /// stored). Exposed so the freelist-reuse determinism tests can pin
    /// slot assignment across identical runs.
    pub fn slot_of(&self, id: ReqId) -> Option<usize> {
        match &self.backend {
            Backend::Arena { index, .. } => index
                .get(id as usize)
                .copied()
                .filter(|s| *s != NO_SLOT)
                .map(|s| s as usize),
            Backend::Map { .. } => None,
        }
    }

    #[inline]
    fn request(&self, id: ReqId) -> &Request {
        match &self.backend {
            Backend::Arena { slots, index, .. } => {
                let slot = index.get(id as usize).copied().unwrap_or(NO_SLOT);
                assert!(slot != NO_SLOT, "pool: unknown request id");
                slots[slot as usize].as_ref().expect("pool: index/slot drift")
            }
            Backend::Map { map } => map.get(&id).expect("pool: unknown request id"),
        }
    }

    #[inline]
    pub fn get(&self, id: &ReqId) -> Option<&Request> {
        self.reads.set(self.reads.get() + 1);
        match &self.backend {
            Backend::Arena { slots, index, .. } => index
                .get(*id as usize)
                .copied()
                .filter(|s| *s != NO_SLOT)
                .and_then(|s| slots[s as usize].as_ref()),
            Backend::Map { map } => map.get(id),
        }
    }

    #[inline]
    pub fn get_mut(&mut self, id: &ReqId) -> Option<&mut Request> {
        self.writes.set(self.writes.get() + 1);
        match &mut self.backend {
            Backend::Arena { slots, index, .. } => {
                match index.get(*id as usize).copied() {
                    Some(s) if s != NO_SLOT => slots[s as usize].as_mut(),
                    _ => None,
                }
            }
            Backend::Map { map } => map.get_mut(id),
        }
    }

    /// Requests currently stored (live, not retired).
    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Arena { len, .. } => *len,
            Backend::Map { map } => map.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate `(id, request)` pairs over the *live* requests (arena:
    /// slot order — id order until the first retirement recycles a
    /// slot; map: unordered). Callers must not depend on the order:
    /// every in-tree consumer either sums order-independent
    /// integer-valued loads or sorts afterwards.
    pub fn iter(&self) -> PoolIter<'_> {
        let inner = match &self.backend {
            Backend::Arena { slots, .. } => PoolIterInner::Arena(slots.iter()),
            Backend::Map { map } => PoolIterInner::Map(map.iter()),
        };
        PoolIter {
            inner,
            reads: &self.reads,
        }
    }

    pub fn values(&self) -> impl Iterator<Item = &Request> + '_ {
        self.iter().map(|(_, r)| r)
    }

    // ---- per-client resident index ----------------------------------------

    /// Hand the request to `client`: sets `Request::client` and records
    /// the request in the client's resident list. O(1). All ownership
    /// changes must go through `assign`/[`RequestPool::unassign`] — the
    /// resident index backs `Client::recompute_load` and drifts if the
    /// `client` field is mutated directly.
    pub fn assign(&mut self, id: ReqId, client: usize) {
        self.writes.set(self.writes.get() + 1);
        if client >= self.by_client.len() {
            self.by_client.resize_with(client + 1, Vec::new);
        }
        let p = self.by_client[client].len() as u32;
        match &mut self.backend {
            Backend::Arena {
                slots, pos, index, ..
            } => {
                let slot = index.get(id as usize).copied().unwrap_or(NO_SLOT);
                assert!(slot != NO_SLOT, "assign: unknown request id {id}");
                let r = slots[slot as usize]
                    .as_mut()
                    .expect("assign: unknown request id");
                debug_assert!(r.client.is_none(), "assign over a live assignment");
                r.client = Some(client);
                pos[slot as usize] = p;
            }
            Backend::Map { map } => {
                let r = map.get_mut(&id).expect("assign: unknown request id");
                debug_assert!(r.client.is_none(), "assign over a live assignment");
                r.client = Some(client);
            }
        }
        self.by_client[client].push(id);
        self.resident += 1;
        self.peak_resident = self.peak_resident.max(self.resident);
    }

    /// The request left its client (stage done / failed): clears
    /// `Request::client` and drops it from the resident list. O(1) on
    /// the arena (positional swap-remove); no-op when unassigned.
    pub fn unassign(&mut self, id: ReqId) {
        self.writes.set(self.writes.get() + 1);
        match &mut self.backend {
            Backend::Arena {
                slots, pos, index, ..
            } => {
                let slot = index.get(id as usize).copied().unwrap_or(NO_SLOT);
                assert!(slot != NO_SLOT, "unassign: unknown request id {id}");
                let r = slots[slot as usize]
                    .as_mut()
                    .expect("unassign: unknown request id");
                let Some(c) = r.client.take() else { return };
                let p = pos[slot as usize] as usize;
                pos[slot as usize] = u32::MAX;
                let list = &mut self.by_client[c];
                debug_assert_eq!(list[p], id, "resident index corrupted");
                list.swap_remove(p);
                if p < list.len() {
                    let moved_slot = index[list[p] as usize];
                    debug_assert!(moved_slot != NO_SLOT, "resident index corrupted");
                    pos[moved_slot as usize] = p as u32;
                }
            }
            Backend::Map { map } => {
                let r = map.get_mut(&id).expect("unassign: unknown request id");
                let Some(c) = r.client.take() else { return };
                let list = &mut self.by_client[c];
                let p = list
                    .iter()
                    .position(|x| *x == id)
                    .expect("resident index corrupted");
                list.swap_remove(p);
            }
        }
        self.resident -= 1;
    }

    /// Requests currently resident on `client`, in index order
    /// (deterministic: insertion order perturbed only by swap-removes,
    /// which are themselves event-deterministic). O(resident).
    pub fn iter_client(&self, client: usize) -> impl Iterator<Item = &Request> + '_ {
        let ids: &[ReqId] = self
            .by_client
            .get(client)
            .map(|v| v.as_slice())
            .unwrap_or(&[]);
        ids.iter().map(move |id| {
            self.reads.set(self.reads.get() + 1);
            self.request(*id)
        })
    }

    /// Number of requests resident on `client`.
    pub fn resident_on(&self, client: usize) -> usize {
        self.by_client.get(client).map(|v| v.len()).unwrap_or(0)
    }

    /// Assert that the resident index exactly mirrors the `client`
    /// fields: every listed id points back at its client, and every
    /// assigned request is listed exactly once. O(pool) — debug
    /// invariant / differential tests only.
    pub fn validate_residency(&self) {
        let mut listed = vec![0usize; self.by_client.len()];
        for (c, list) in self.by_client.iter().enumerate() {
            for id in list {
                let r = self.request(*id);
                assert_eq!(
                    r.client,
                    Some(c),
                    "resident index lists request {id} under client {c} but the request says {:?}",
                    r.client
                );
                listed[c] += 1;
            }
        }
        let mut assigned = vec![0usize; self.by_client.len()];
        let mut total = 0usize;
        for (_, r) in self.iter() {
            if let Some(c) = r.client {
                assert!(
                    c < self.by_client.len(),
                    "request {} assigned to unindexed client {c}",
                    r.id
                );
                assigned[c] += 1;
                total += 1;
            }
        }
        assert_eq!(listed, assigned, "resident index drifted from request.client");
        assert_eq!(total, self.resident, "resident counter drifted");
    }

    // ---- op counters -------------------------------------------------------

    /// Snapshot of the operation counters and occupancy marks.
    pub fn ops(&self) -> PoolOps {
        PoolOps {
            reads: self.reads.get(),
            writes: self.writes.get(),
            slots: match &self.backend {
                Backend::Arena { slots, .. } => slots.len(),
                Backend::Map { map } => map.len(),
            },
            len: self.len(),
            peak_live: self.peak_live,
            retired: self.retired,
            bytes_est: self.live_bytes,
            peak_bytes_est: self.peak_bytes,
            resident: self.resident,
            peak_resident: self.peak_resident,
        }
    }

    /// Zero the read/write counters (occupancy marks are kept) — the
    /// bench harness calls this after injection so the counters cover
    /// exactly the event loop.
    pub fn reset_ops(&self) {
        self.reads.set(0);
        self.writes.set(0);
    }
}

impl std::ops::Index<&ReqId> for RequestPool {
    type Output = Request;
    #[inline]
    fn index(&self, id: &ReqId) -> &Request {
        self.reads.set(self.reads.get() + 1);
        self.request(*id)
    }
}

impl std::ops::Index<ReqId> for RequestPool {
    type Output = Request;
    #[inline]
    fn index(&self, id: ReqId) -> &Request {
        self.reads.set(self.reads.get() + 1);
        self.request(id)
    }
}

/// Iterator over `(id, request)` pairs of either backend. Each yielded
/// request counts as one pool read, so the op counters also cover the
/// whole-pool scans (`Client::full_scan_load`, trace export).
pub struct PoolIter<'a> {
    inner: PoolIterInner<'a>,
    reads: &'a Cell<u64>,
}

enum PoolIterInner<'a> {
    Arena(std::slice::Iter<'a, Option<Request>>),
    Map(std::collections::hash_map::Iter<'a, ReqId, Request>),
}

impl<'a> Iterator for PoolIter<'a> {
    type Item = (&'a ReqId, &'a Request);

    fn next(&mut self) -> Option<Self::Item> {
        let item = match &mut self.inner {
            PoolIterInner::Arena(it) => loop {
                match it.next() {
                    Some(Some(r)) => break Some((&r.id, r)),
                    Some(None) => continue,
                    None => break None,
                }
            },
            PoolIterInner::Map(it) => it.next(),
        };
        if item.is_some() {
            self.reads.set(self.reads.get() + 1);
        }
        item
    }
}

impl<'a> IntoIterator for &'a RequestPool {
    type Item = (&'a ReqId, &'a Request);
    type IntoIter = PoolIter<'a>;
    fn into_iter(self) -> PoolIter<'a> {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::Stage;

    fn req(id: u64) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode],
            100,
            10,
        )
    }

    fn both() -> [RequestPool; 2] {
        [RequestPool::new(), RequestPool::map_backed()]
    }

    #[test]
    fn insert_get_index_len() {
        for mut pool in both() {
            assert!(pool.is_empty());
            for id in [0u64, 3, 1] {
                pool.insert(id, req(id));
            }
            assert_eq!(pool.len(), 3);
            assert_eq!(pool[&3].id, 3);
            assert_eq!(pool[1u64].id, 1);
            assert!(pool.get(&2).is_none());
            pool.get_mut(&0).unwrap().prefilled = 7;
            assert_eq!(pool[&0].prefilled, 7);
        }
    }

    #[test]
    #[should_panic(expected = "duplicate request id")]
    fn arena_rejects_duplicate_ids() {
        let mut pool = RequestPool::new();
        pool.insert(3, req(3));
        pool.insert(3, req(3));
    }

    #[test]
    #[should_panic(expected = "duplicate request id")]
    fn map_rejects_duplicate_ids() {
        let mut pool = RequestPool::map_backed();
        pool.insert(3, req(3));
        pool.insert(3, req(3));
    }

    #[test]
    fn iteration_covers_all_requests() {
        for mut pool in both() {
            for id in 0..5u64 {
                pool.insert(id, req(id));
            }
            let mut ids: Vec<u64> = pool.iter().map(|(id, _)| *id).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1, 2, 3, 4]);
            assert_eq!(pool.values().count(), 5);
            // for-loop sugar over &pool
            let mut n = 0;
            for (id, r) in &pool {
                assert_eq!(*id, r.id);
                n += 1;
            }
            assert_eq!(n, 5);
        }
    }

    #[test]
    fn remove_retires_and_freelist_recycles_slots() {
        for mut pool in both() {
            for id in 0..4u64 {
                pool.insert(id, req(id));
            }
            let slot1 = pool.slot_of(1);
            let r = pool.remove(1);
            assert_eq!(r.id, 1);
            assert_eq!(pool.len(), 3);
            assert!(pool.get(&1).is_none(), "retired id must not resolve");
            assert!(pool.slot_of(1).is_none());
            // a later insert reuses the vacated slot (arena: LIFO freelist)
            pool.insert(9, req(9));
            assert_eq!(pool.len(), 4);
            if pool.backend() == PoolBackend::Arena {
                assert_eq!(pool.slot_of(9), slot1, "freed slot must be recycled");
                assert_eq!(pool.ops().slots, 4, "no new slot allocated");
            }
            let ops = pool.ops();
            assert_eq!(ops.retired, 1);
            assert_eq!(ops.peak_live, 4);
            assert!(ops.bytes_est > 0);
            assert!(ops.peak_bytes_est >= ops.bytes_est);
            // iteration covers exactly the live set
            let mut ids: Vec<u64> = pool.iter().map(|(id, _)| *id).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 2, 3, 9]);
        }
    }

    #[test]
    fn retire_all_returns_bytes_to_zero() {
        for mut pool in both() {
            for id in 0..8u64 {
                pool.insert(id, req(id));
            }
            for id in 0..8u64 {
                pool.remove(id);
            }
            let ops = pool.ops();
            assert_eq!(ops.len, 0);
            assert_eq!(ops.bytes_est, 0, "symmetric estimate must drain to zero");
            assert_eq!(ops.retired, 8);
            assert_eq!(ops.peak_live, 8);
        }
    }

    #[test]
    fn retirement_bounds_slots_to_peak_live() {
        // a 1000-id stream with a 10-request live window must allocate
        // ~10 slots, not 1000 — the O(in-flight) arena property
        let mut pool = RequestPool::new();
        for id in 0..1000u64 {
            pool.insert(id, req(id));
            if id >= 10 {
                pool.remove(id - 10);
            }
        }
        let ops = pool.ops();
        assert_eq!(ops.peak_live, 11);
        assert_eq!(ops.slots, 11, "slots must track peak live, not total ids");
        assert_eq!(ops.retired, 990);
        assert_eq!(ops.len, 10);
    }

    #[test]
    fn resident_index_tracks_assignment() {
        for mut pool in both() {
            for id in 0..4u64 {
                pool.insert(id, req(id));
            }
            pool.assign(0, 2);
            pool.assign(1, 2);
            pool.assign(2, 2);
            pool.assign(3, 0);
            assert_eq!(pool.resident_on(2), 3);
            assert_eq!(pool.resident_on(0), 1);
            assert_eq!(pool.resident_on(7), 0);
            assert_eq!(pool[&1].client, Some(2));
            pool.validate_residency();

            // middle removal exercises the swap-remove position fix-up
            pool.unassign(1);
            assert_eq!(pool.resident_on(2), 2);
            assert_eq!(pool[&1].client, None);
            pool.validate_residency();
            let left: Vec<u64> = pool.iter_client(2).map(|r| r.id).collect();
            assert_eq!(left.len(), 2);
            assert!(left.contains(&0) && left.contains(&2));

            // unassigning an unassigned request is a no-op
            pool.unassign(1);
            pool.validate_residency();

            // re-assignment after release works (stage transitions)
            pool.assign(1, 0);
            assert_eq!(pool.resident_on(0), 2);
            pool.validate_residency();

            let ops = pool.ops();
            assert_eq!(ops.resident, 4);
            assert_eq!(ops.peak_resident, 4);
        }
    }

    #[test]
    fn residency_survives_slot_recycling() {
        // the resident position array is slot-indexed: retire a request,
        // recycle its slot for a new id, assign both old and new ids —
        // positions must not cross-talk
        let mut pool = RequestPool::new();
        for id in 0..3u64 {
            pool.insert(id, req(id));
        }
        pool.remove(1);
        pool.insert(5, req(5)); // reuses slot of id 1
        pool.assign(0, 0);
        pool.assign(5, 0);
        pool.assign(2, 0);
        pool.validate_residency();
        pool.unassign(5);
        pool.validate_residency();
        assert_eq!(pool.resident_on(0), 2);
        let left: Vec<u64> = pool.iter_client(0).map(|r| r.id).collect();
        assert!(left.contains(&0) && left.contains(&2));
    }

    #[test]
    fn op_counters_count_and_reset() {
        let mut pool = RequestPool::new();
        pool.insert(0, req(0));
        pool.insert(1, req(1));
        let w0 = pool.ops().writes;
        assert_eq!(w0, 2);
        let _ = &pool[&0];
        let _ = pool.get(&1);
        pool.get_mut(&1).unwrap().decoded = 1;
        let ops = pool.ops();
        assert_eq!(ops.reads, 2);
        assert_eq!(ops.writes, 3);
        assert_eq!(ops.slots, 2);
        assert_eq!(ops.len, 2);
        pool.reset_ops();
        assert_eq!(pool.ops().reads, 0);
        assert_eq!(pool.ops().writes, 0);
    }

    #[test]
    fn arena_handles_sparse_ids() {
        let mut pool = RequestPool::new();
        pool.insert(10, req(10));
        assert_eq!(pool.len(), 1);
        // the indirection grows to the max id; payload slots do not
        assert_eq!(pool.ops().slots, 1, "payload slots track live requests");
        assert!(pool.get(&4).is_none());
        assert_eq!(pool.iter().count(), 1);
        assert_eq!(pool.slot_of(10), Some(0));
    }

    #[test]
    fn backends_report_their_name() {
        assert_eq!(RequestPool::new().backend(), PoolBackend::Arena);
        assert_eq!(RequestPool::map_backed().backend(), PoolBackend::Map);
        assert_eq!(PoolBackend::Arena.name(), "arena");
        assert_eq!(PoolBackend::Map.name(), "hashmap");
    }
}
