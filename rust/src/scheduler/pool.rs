//! Arena-backed request pool.
//!
//! The coordinator owns every request for the lifetime of a run and the
//! hot loop touches the pool on every event: scheduler admission, step
//! planning, token progress, load release, routing. The seed kept the
//! pool as a `HashMap<ReqId, Request>`, which pays a hash per access and
//! pointer-chases on iteration; worse, `recompute_load` (the full-scan
//! baseline and the debug-mode drift invariant) scanned the *entire*
//! pool per client.
//!
//! [`RequestPool`] replaces it with a dense arena: request ids are
//! assigned sequentially by the workload generators
//! (`WorkloadSpec::generate` / `WorkloadMix::generate` hand out dense id
//! ranges from 0), so a `Vec<Option<Request>>` indexed directly by
//! `ReqId` gives O(1) hash-free access and cache-friendly linear
//! iteration. A per-client *resident index* (`by_client` + per-slot
//! position) is maintained by [`RequestPool::assign`] /
//! [`RequestPool::unassign`] in O(1), so per-client recomputation
//! ([`RequestPool::iter_client`]) is O(resident on that client) instead
//! of O(total pool).
//!
//! The old map representation survives as [`PoolBackend::Map`] — a
//! reference implementation behind the same API, used by the
//! differential tests (`rust/tests/pool_equivalence.rs`) and the
//! `hermes bench` hashmap baseline to prove the arena is behaviorally
//! invisible and measurably faster.
//!
//! Every access is counted (reads via a `Cell`, so `Index` can count
//! too); `hermes bench` reports the totals and the arena high-water
//! marks (see [`PoolOps`]).

use std::cell::Cell;
use std::collections::HashMap;

use crate::workload::request::{ReqId, Request};

/// Which storage backs the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolBackend {
    /// dense `Vec` slots indexed by `ReqId` — the shipping configuration
    Arena,
    /// `HashMap` reference implementation — differential tests and the
    /// `hermes bench` pre-arena baseline only
    Map,
}

impl PoolBackend {
    pub fn name(&self) -> &'static str {
        match self {
            PoolBackend::Arena => "arena",
            PoolBackend::Map => "hashmap",
        }
    }
}

enum Backend {
    Arena {
        /// slot i holds the request with id i (ids are dense)
        slots: Vec<Option<Request>>,
        /// position of each assigned id inside its client's resident
        /// list (`u32::MAX` = unassigned); parallel to `slots`
        pos: Vec<u32>,
        len: usize,
    },
    Map {
        map: HashMap<ReqId, Request>,
    },
}

/// Pool operation counters for the bench harness (`hermes bench`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolOps {
    pub reads: u64,
    pub writes: u64,
    /// allocated arena slots (map backend: live entries)
    pub slots: usize,
    /// requests currently stored
    pub len: usize,
    /// requests currently resident on some client
    pub resident: usize,
    /// high-water mark of `resident` — the arena occupancy peak
    pub peak_resident: usize,
}

/// The requests a simulation run owns, indexed by their dense id.
pub struct RequestPool {
    backend: Backend,
    /// resident request ids per client (index = client id)
    by_client: Vec<Vec<ReqId>>,
    resident: usize,
    peak_resident: usize,
    /// `Cell` so `Index`/`get` (shared-ref paths) can count too
    reads: Cell<u64>,
    writes: Cell<u64>,
}

impl Default for RequestPool {
    fn default() -> RequestPool {
        RequestPool::new()
    }
}

impl RequestPool {
    /// An empty arena-backed pool (the default everywhere).
    pub fn new() -> RequestPool {
        RequestPool::with_backend(PoolBackend::Arena)
    }

    /// The `HashMap` reference backend (differential tests / bench).
    pub fn map_backed() -> RequestPool {
        RequestPool::with_backend(PoolBackend::Map)
    }

    pub fn with_backend(backend: PoolBackend) -> RequestPool {
        let backend = match backend {
            PoolBackend::Arena => Backend::Arena {
                slots: Vec::new(),
                pos: Vec::new(),
                len: 0,
            },
            PoolBackend::Map => Backend::Map {
                map: HashMap::new(),
            },
        };
        RequestPool {
            backend,
            by_client: Vec::new(),
            resident: 0,
            peak_resident: 0,
            reads: Cell::new(0),
            writes: Cell::new(0),
        }
    }

    pub fn backend(&self) -> PoolBackend {
        match self.backend {
            Backend::Arena { .. } => PoolBackend::Arena,
            Backend::Map { .. } => PoolBackend::Map,
        }
    }

    /// Store `r` under `id` (replacing any previous occupant, HashMap
    /// semantics). Ids must be dense-ish: the arena allocates slots up
    /// to the largest id seen.
    pub fn insert(&mut self, id: ReqId, r: Request) {
        debug_assert_eq!(id, r.id, "pool key must equal the request id");
        self.writes.set(self.writes.get() + 1);
        match &mut self.backend {
            Backend::Arena { slots, pos, len } => {
                let i = id as usize;
                if i >= slots.len() {
                    slots.resize_with(i + 1, || None);
                    pos.resize(i + 1, u32::MAX);
                }
                match slots[i].replace(r) {
                    None => *len += 1,
                    Some(old) => debug_assert!(
                        old.client.is_none(),
                        "insert replaced a client-resident request"
                    ),
                }
            }
            Backend::Map { map } => {
                if let Some(old) = map.insert(id, r) {
                    debug_assert!(
                        old.client.is_none(),
                        "insert replaced a client-resident request"
                    );
                }
            }
        }
    }

    #[inline]
    fn request(&self, id: ReqId) -> &Request {
        match &self.backend {
            Backend::Arena { slots, .. } => slots[id as usize]
                .as_ref()
                .expect("pool: unknown request id"),
            Backend::Map { map } => map.get(&id).expect("pool: unknown request id"),
        }
    }

    #[inline]
    pub fn get(&self, id: &ReqId) -> Option<&Request> {
        self.reads.set(self.reads.get() + 1);
        match &self.backend {
            Backend::Arena { slots, .. } => {
                slots.get(*id as usize).and_then(|s| s.as_ref())
            }
            Backend::Map { map } => map.get(id),
        }
    }

    #[inline]
    pub fn get_mut(&mut self, id: &ReqId) -> Option<&mut Request> {
        self.writes.set(self.writes.get() + 1);
        match &mut self.backend {
            Backend::Arena { slots, .. } => {
                slots.get_mut(*id as usize).and_then(|s| s.as_mut())
            }
            Backend::Map { map } => map.get_mut(id),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Arena { len, .. } => *len,
            Backend::Map { map } => map.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate `(id, request)` pairs (arena: id order; map: unordered).
    pub fn iter(&self) -> PoolIter<'_> {
        let inner = match &self.backend {
            Backend::Arena { slots, .. } => PoolIterInner::Arena(slots.iter()),
            Backend::Map { map } => PoolIterInner::Map(map.iter()),
        };
        PoolIter {
            inner,
            reads: &self.reads,
        }
    }

    pub fn values(&self) -> impl Iterator<Item = &Request> + '_ {
        self.iter().map(|(_, r)| r)
    }

    // ---- per-client resident index ----------------------------------------

    /// Hand the request to `client`: sets `Request::client` and records
    /// the request in the client's resident list. O(1). All ownership
    /// changes must go through `assign`/[`RequestPool::unassign`] — the
    /// resident index backs `Client::recompute_load` and drifts if the
    /// `client` field is mutated directly.
    pub fn assign(&mut self, id: ReqId, client: usize) {
        self.writes.set(self.writes.get() + 1);
        if client >= self.by_client.len() {
            self.by_client.resize_with(client + 1, Vec::new);
        }
        let p = self.by_client[client].len() as u32;
        match &mut self.backend {
            Backend::Arena { slots, pos, .. } => {
                let r = slots[id as usize]
                    .as_mut()
                    .expect("assign: unknown request id");
                debug_assert!(r.client.is_none(), "assign over a live assignment");
                r.client = Some(client);
                pos[id as usize] = p;
            }
            Backend::Map { map } => {
                let r = map.get_mut(&id).expect("assign: unknown request id");
                debug_assert!(r.client.is_none(), "assign over a live assignment");
                r.client = Some(client);
            }
        }
        self.by_client[client].push(id);
        self.resident += 1;
        self.peak_resident = self.peak_resident.max(self.resident);
    }

    /// The request left its client (stage done / failed): clears
    /// `Request::client` and drops it from the resident list. O(1) on
    /// the arena (positional swap-remove); no-op when unassigned.
    pub fn unassign(&mut self, id: ReqId) {
        self.writes.set(self.writes.get() + 1);
        match &mut self.backend {
            Backend::Arena { slots, pos, .. } => {
                let r = slots[id as usize]
                    .as_mut()
                    .expect("unassign: unknown request id");
                let Some(c) = r.client.take() else { return };
                let p = pos[id as usize] as usize;
                pos[id as usize] = u32::MAX;
                let list = &mut self.by_client[c];
                debug_assert_eq!(list[p], id, "resident index corrupted");
                list.swap_remove(p);
                if p < list.len() {
                    pos[list[p] as usize] = p as u32;
                }
            }
            Backend::Map { map } => {
                let r = map.get_mut(&id).expect("unassign: unknown request id");
                let Some(c) = r.client.take() else { return };
                let list = &mut self.by_client[c];
                let p = list
                    .iter()
                    .position(|x| *x == id)
                    .expect("resident index corrupted");
                list.swap_remove(p);
            }
        }
        self.resident -= 1;
    }

    /// Requests currently resident on `client`, in index order
    /// (deterministic: insertion order perturbed only by swap-removes,
    /// which are themselves event-deterministic). O(resident).
    pub fn iter_client(&self, client: usize) -> impl Iterator<Item = &Request> + '_ {
        let ids: &[ReqId] = self
            .by_client
            .get(client)
            .map(|v| v.as_slice())
            .unwrap_or(&[]);
        ids.iter().map(move |id| {
            self.reads.set(self.reads.get() + 1);
            self.request(*id)
        })
    }

    /// Number of requests resident on `client`.
    pub fn resident_on(&self, client: usize) -> usize {
        self.by_client.get(client).map(|v| v.len()).unwrap_or(0)
    }

    /// Assert that the resident index exactly mirrors the `client`
    /// fields: every listed id points back at its client, and every
    /// assigned request is listed exactly once. O(pool) — debug
    /// invariant / differential tests only.
    pub fn validate_residency(&self) {
        let mut listed = vec![0usize; self.by_client.len()];
        for (c, list) in self.by_client.iter().enumerate() {
            for id in list {
                let r = self.request(*id);
                assert_eq!(
                    r.client,
                    Some(c),
                    "resident index lists request {id} under client {c} but the request says {:?}",
                    r.client
                );
                listed[c] += 1;
            }
        }
        let mut assigned = vec![0usize; self.by_client.len()];
        let mut total = 0usize;
        for (_, r) in self.iter() {
            if let Some(c) = r.client {
                assert!(
                    c < self.by_client.len(),
                    "request {} assigned to unindexed client {c}",
                    r.id
                );
                assigned[c] += 1;
                total += 1;
            }
        }
        assert_eq!(listed, assigned, "resident index drifted from request.client");
        assert_eq!(total, self.resident, "resident counter drifted");
    }

    // ---- op counters -------------------------------------------------------

    /// Snapshot of the operation counters and occupancy marks.
    pub fn ops(&self) -> PoolOps {
        PoolOps {
            reads: self.reads.get(),
            writes: self.writes.get(),
            slots: match &self.backend {
                Backend::Arena { slots, .. } => slots.len(),
                Backend::Map { map } => map.len(),
            },
            len: self.len(),
            resident: self.resident,
            peak_resident: self.peak_resident,
        }
    }

    /// Zero the read/write counters (occupancy marks are kept) — the
    /// bench harness calls this after injection so the counters cover
    /// exactly the event loop.
    pub fn reset_ops(&self) {
        self.reads.set(0);
        self.writes.set(0);
    }
}

impl std::ops::Index<&ReqId> for RequestPool {
    type Output = Request;
    #[inline]
    fn index(&self, id: &ReqId) -> &Request {
        self.reads.set(self.reads.get() + 1);
        self.request(*id)
    }
}

impl std::ops::Index<ReqId> for RequestPool {
    type Output = Request;
    #[inline]
    fn index(&self, id: ReqId) -> &Request {
        self.reads.set(self.reads.get() + 1);
        self.request(id)
    }
}

/// Iterator over `(id, request)` pairs of either backend. Each yielded
/// request counts as one pool read, so the op counters also cover the
/// whole-pool scans (`Client::full_scan_load`, trace export).
pub struct PoolIter<'a> {
    inner: PoolIterInner<'a>,
    reads: &'a Cell<u64>,
}

enum PoolIterInner<'a> {
    Arena(std::slice::Iter<'a, Option<Request>>),
    Map(std::collections::hash_map::Iter<'a, ReqId, Request>),
}

impl<'a> Iterator for PoolIter<'a> {
    type Item = (&'a ReqId, &'a Request);

    fn next(&mut self) -> Option<Self::Item> {
        let item = match &mut self.inner {
            PoolIterInner::Arena(it) => loop {
                match it.next() {
                    Some(Some(r)) => break Some((&r.id, r)),
                    Some(None) => continue,
                    None => break None,
                }
            },
            PoolIterInner::Map(it) => it.next(),
        };
        if item.is_some() {
            self.reads.set(self.reads.get() + 1);
        }
        item
    }
}

impl<'a> IntoIterator for &'a RequestPool {
    type Item = (&'a ReqId, &'a Request);
    type IntoIter = PoolIter<'a>;
    fn into_iter(self) -> PoolIter<'a> {
        self.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::Stage;

    fn req(id: u64) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::Prefill, Stage::Decode],
            100,
            10,
        )
    }

    fn both() -> [RequestPool; 2] {
        [RequestPool::new(), RequestPool::map_backed()]
    }

    #[test]
    fn insert_get_index_len() {
        for mut pool in both() {
            assert!(pool.is_empty());
            for id in [0u64, 3, 1] {
                pool.insert(id, req(id));
            }
            assert_eq!(pool.len(), 3);
            assert_eq!(pool[&3].id, 3);
            assert_eq!(pool[1u64].id, 1);
            assert!(pool.get(&2).is_none());
            pool.get_mut(&0).unwrap().prefilled = 7;
            assert_eq!(pool[&0].prefilled, 7);
            // replacement keeps the length (HashMap semantics)
            pool.insert(3, req(3));
            assert_eq!(pool.len(), 3);
        }
    }

    #[test]
    fn iteration_covers_all_requests() {
        for mut pool in both() {
            for id in 0..5u64 {
                pool.insert(id, req(id));
            }
            let mut ids: Vec<u64> = pool.iter().map(|(id, _)| *id).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1, 2, 3, 4]);
            assert_eq!(pool.values().count(), 5);
            // for-loop sugar over &pool
            let mut n = 0;
            for (id, r) in &pool {
                assert_eq!(*id, r.id);
                n += 1;
            }
            assert_eq!(n, 5);
        }
    }

    #[test]
    fn resident_index_tracks_assignment() {
        for mut pool in both() {
            for id in 0..4u64 {
                pool.insert(id, req(id));
            }
            pool.assign(0, 2);
            pool.assign(1, 2);
            pool.assign(2, 2);
            pool.assign(3, 0);
            assert_eq!(pool.resident_on(2), 3);
            assert_eq!(pool.resident_on(0), 1);
            assert_eq!(pool.resident_on(7), 0);
            assert_eq!(pool[&1].client, Some(2));
            pool.validate_residency();

            // middle removal exercises the swap-remove position fix-up
            pool.unassign(1);
            assert_eq!(pool.resident_on(2), 2);
            assert_eq!(pool[&1].client, None);
            pool.validate_residency();
            let left: Vec<u64> = pool.iter_client(2).map(|r| r.id).collect();
            assert_eq!(left.len(), 2);
            assert!(left.contains(&0) && left.contains(&2));

            // unassigning an unassigned request is a no-op
            pool.unassign(1);
            pool.validate_residency();

            // re-assignment after release works (stage transitions)
            pool.assign(1, 0);
            assert_eq!(pool.resident_on(0), 2);
            pool.validate_residency();

            let ops = pool.ops();
            assert_eq!(ops.resident, 4);
            assert_eq!(ops.peak_resident, 4);
        }
    }

    #[test]
    fn op_counters_count_and_reset() {
        let mut pool = RequestPool::new();
        pool.insert(0, req(0));
        pool.insert(1, req(1));
        let w0 = pool.ops().writes;
        assert_eq!(w0, 2);
        let _ = &pool[&0];
        let _ = pool.get(&1);
        pool.get_mut(&1).unwrap().decoded = 1;
        let ops = pool.ops();
        assert_eq!(ops.reads, 2);
        assert_eq!(ops.writes, 3);
        assert_eq!(ops.slots, 2);
        assert_eq!(ops.len, 2);
        pool.reset_ops();
        assert_eq!(pool.ops().reads, 0);
        assert_eq!(pool.ops().writes, 0);
    }

    #[test]
    fn arena_handles_sparse_ids() {
        let mut pool = RequestPool::new();
        pool.insert(10, req(10));
        assert_eq!(pool.len(), 1);
        assert_eq!(pool.ops().slots, 11, "slots allocated up to max id");
        assert!(pool.get(&4).is_none());
        assert_eq!(pool.iter().count(), 1);
    }

    #[test]
    fn backends_report_their_name() {
        assert_eq!(RequestPool::new().backend(), PoolBackend::Arena);
        assert_eq!(RequestPool::map_backed().backend(), PoolBackend::Map);
        assert_eq!(PoolBackend::Arena.name(), "arena");
        assert_eq!(PoolBackend::Map.name(), "hashmap");
    }
}
