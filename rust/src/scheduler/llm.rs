//! The LLM scheduler: five batching strategies behind one planner
//! (paper §III-D.1), with KV admission control and token/sequence caps.
//!
//!   Static        — FasterTransformer-style: fill a batch, run it to
//!                   completion, only then admit the next batch.
//!   Continuous    — Orca/vLLM: admit every step; prefill-prioritized
//!                   (a pending prefill preempts decoding).
//!   Chunked       — Sarathi/DeepSpeed-FastGen: fixed per-step token
//!                   budget; decodes ride along with prefill chunks.
//!   Mixed         — Splitwise mixed pool: full prefills and decodes
//!                   co-scheduled without a chunk budget.
//!   PrefillOnly / — the two halves of disaggregated serving
//!   DecodeOnly      (Splitwise/DistServe); the coordinator moves KV
//!                   between them.

use std::collections::{HashMap, VecDeque};

use super::packing::Packing;
use super::{RequestPool, StepPlan};
use crate::memory::hierarchy::KvManager;
use crate::workload::request::ReqId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchingKind {
    Static,
    Continuous,
    Chunked { chunk: usize },
    Mixed,
    PrefillOnly,
    DecodeOnly,
}

impl BatchingKind {
    pub fn name(&self) -> &'static str {
        match self {
            BatchingKind::Static => "static",
            BatchingKind::Continuous => "continuous",
            BatchingKind::Chunked { .. } => "chunked",
            BatchingKind::Mixed => "mixed",
            BatchingKind::PrefillOnly => "prefill-only",
            BatchingKind::DecodeOnly => "decode-only",
        }
    }
}

/// User constraints (paper: "maximum number of batched tokens or batch
/// size").
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// maximum decode sequences co-batched in a step
    pub max_batch_seqs: usize,
    /// maximum new prefill tokens in a step
    pub max_batch_tokens: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            max_batch_seqs: 256,
            max_batch_tokens: 8192,
        }
    }
}

/// vLLM-like scheduler state for one LLM client.
pub struct LlmSched {
    pub kind: BatchingKind,
    pub packing: Packing,
    pub cfg: SchedConfig,
    /// arrived but not yet admitted (no KV reservation)
    waiting: VecDeque<ReqId>,
    /// admitted: KV reserved, being prefilled/decoded
    running: Vec<ReqId>,
    /// KV tokens reserved per admitted request (released via `remove`)
    reserved: HashMap<ReqId, f64>,
    /// queue-length samples for scheduler metrics
    pub admissions: u64,
}

impl LlmSched {
    pub fn new(kind: BatchingKind, packing: Packing, cfg: SchedConfig) -> LlmSched {
        LlmSched {
            kind,
            packing,
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            reserved: HashMap::new(),
            admissions: 0,
        }
    }

    pub fn enqueue(&mut self, id: ReqId) {
        self.waiting.push_back(id);
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running.is_empty()
    }

    /// Remove a completed / transferred-out request. Returns the KV
    /// tokens that were reserved for it (the caller releases them from
    /// the KvManager), or `None` if it was never admitted.
    pub fn remove(&mut self, id: ReqId) -> Option<f64> {
        if let Some(i) = self.running.iter().position(|r| *r == id) {
            self.running.swap_remove(i);
            self.reserved.remove(&id)
        } else {
            self.waiting.retain(|r| *r != id);
            None
        }
    }

    /// KV tokens to reserve at admission, by role: a prefill-only client
    /// never holds decode KV; everyone else reserves the full peak.
    fn admit_tokens(&self, pool: &RequestPool, id: ReqId) -> f64 {
        let r = &pool[&id];
        match self.kind {
            BatchingKind::PrefillOnly => (r.past_tokens + r.prompt_tokens) as f64,
            _ => r.kv_tokens_peak(),
        }
    }

    /// Admit from `waiting` in packing order while KV + seq caps allow.
    fn admit(&mut self, pool: &RequestPool, kv: &mut KvManager) {
        if self.waiting.is_empty() {
            return;
        }
        let mut cand: Vec<ReqId> = self.waiting.iter().copied().collect();
        self.packing.order(&mut cand, pool);
        for id in cand {
            let seqs: usize = self
                .running
                .iter()
                .map(|r| pool[r].decode_seqs())
                .sum::<usize>();
            if seqs + pool[&id].decode_seqs() > self.cfg.max_batch_seqs {
                break;
            }
            let tokens = self.admit_tokens(pool, id);
            if kv.admit(tokens) {
                self.waiting.retain(|r| *r != id);
                self.running.push(id);
                self.reserved.insert(id, tokens);
                self.admissions += 1;
            } else {
                // FCFS head-of-line blocking: stop at the first request
                // that does not fit (vLLM semantics)
                break;
            }
        }
    }

    /// Build the next step plan; `None` when there is nothing to run.
    pub fn plan(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        match self.kind {
            BatchingKind::Static => self.plan_static(pool, kv),
            BatchingKind::Continuous => self.plan_continuous(pool, kv),
            BatchingKind::Chunked { chunk } => self.plan_chunked(pool, kv, chunk),
            BatchingKind::Mixed => self.plan_mixed(pool, kv),
            BatchingKind::PrefillOnly => self.plan_prefill_only(pool, kv),
            BatchingKind::DecodeOnly => self.plan_decode_only(pool, kv),
        }
    }

    fn prefillers(&self, pool: &RequestPool) -> Vec<ReqId> {
        self.running
            .iter()
            .copied()
            .filter(|id| !pool[id].prefill_complete())
            .collect()
    }

    fn decoders(&self, pool: &RequestPool) -> Vec<ReqId> {
        self.running
            .iter()
            .copied()
            .filter(|id| pool[id].prefill_complete() && !pool[id].decode_complete())
            .collect()
    }

    fn plan_static(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        // admit only when the previous batch fully drained
        if self.running.is_empty() {
            self.admit(pool, kv);
        }
        if self.running.is_empty() {
            return None;
        }
        let pf = self.prefillers(pool);
        if !pf.is_empty() {
            // whole prompts, one step (FasterTransformer has no chunking)
            return Some(StepPlan {
                prefill: pf
                    .iter()
                    .map(|id| (*id, pool[id].prefill_remaining()))
                    .collect(),
                decode: Vec::new(),
            });
        }
        Some(StepPlan {
            prefill: Vec::new(),
            decode: self.decoders(pool),
        })
    }

    fn plan_continuous(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        self.admit(pool, kv);
        if self.running.is_empty() {
            return None;
        }
        // prefill-prioritized: pending prefills preempt decode
        let mut pf = self.prefillers(pool);
        if !pf.is_empty() {
            self.packing.order(&mut pf, pool);
            let mut budget = self.cfg.max_batch_tokens;
            let mut prefill = Vec::new();
            for id in pf {
                if budget == 0 {
                    break;
                }
                let take = pool[&id].prefill_remaining().min(budget);
                // continuous batching does not split prompts: take all or
                // wait (unless a single prompt alone exceeds the budget)
                if take < pool[&id].prefill_remaining() && !prefill.is_empty() {
                    break;
                }
                budget -= take;
                prefill.push((id, take));
            }
            if !prefill.is_empty() {
                return Some(StepPlan {
                    prefill,
                    decode: Vec::new(),
                });
            }
        }
        let dec = self.decoders(pool);
        if dec.is_empty() {
            return None;
        }
        Some(StepPlan {
            prefill: Vec::new(),
            decode: dec,
        })
    }

    fn plan_chunked(
        &mut self,
        pool: &RequestPool,
        kv: &mut KvManager,
        chunk: usize,
    ) -> Option<StepPlan> {
        self.admit(pool, kv);
        if self.running.is_empty() {
            return None;
        }
        // decodes ride in every step (1 token per branch-sequence)...
        let decode = self.decoders(pool);
        let dec_tokens: usize = decode.iter().map(|id| pool[id].decode_seqs()).sum();
        // ...and the remaining budget is filled with prefill chunks
        let mut budget = chunk.saturating_sub(dec_tokens);
        let mut pf = self.prefillers(pool);
        self.packing.order(&mut pf, pool);
        let mut prefill = Vec::new();
        for id in pf {
            if budget == 0 {
                break;
            }
            let take = pool[&id].prefill_remaining().min(budget);
            budget -= take;
            prefill.push((id, take));
        }
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(StepPlan { prefill, decode })
    }

    fn plan_mixed(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        self.admit(pool, kv);
        if self.running.is_empty() {
            return None;
        }
        let mut pf = self.prefillers(pool);
        self.packing.order(&mut pf, pool);
        let mut budget = self.cfg.max_batch_tokens;
        let mut prefill = Vec::new();
        for id in pf {
            let take = pool[&id].prefill_remaining().min(budget);
            if take == 0 {
                break;
            }
            budget -= take;
            prefill.push((id, take));
        }
        let decode = self.decoders(pool);
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(StepPlan { prefill, decode })
    }

    fn plan_prefill_only(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        self.admit(pool, kv);
        let mut pf = self.prefillers(pool);
        if pf.is_empty() {
            return None;
        }
        self.packing.order(&mut pf, pool);
        let mut budget = self.cfg.max_batch_tokens;
        let mut prefill = Vec::new();
        for id in pf {
            if budget == 0 {
                break;
            }
            let take = pool[&id].prefill_remaining().min(budget);
            if take < pool[&id].prefill_remaining() && !prefill.is_empty() {
                break; // no chunking across steps beyond the head request
            }
            budget -= take;
            prefill.push((id, take));
        }
        Some(StepPlan {
            prefill,
            decode: Vec::new(),
        })
    }

    fn plan_decode_only(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        self.admit(pool, kv);
        let dec = self.decoders(pool);
        if dec.is_empty() {
            return None;
        }
        Some(StepPlan {
            prefill: Vec::new(),
            decode: dec,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::{Request, Stage};

    fn mk(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::from_secs(id as f64 * 0.01),
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    fn setup(kind: BatchingKind, reqs: Vec<Request>) -> (LlmSched, RequestPool, KvManager) {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(kind, Packing::Fcfs, SchedConfig::default());
        for r in reqs {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        (s, pool, KvManager::new(1e9))
    }

    /// apply a plan the way a client would: progress tokens
    fn apply(plan: &StepPlan, pool: &mut RequestPool) {
        for (id, n) in &plan.prefill {
            pool.get_mut(id).unwrap().prefilled += n;
        }
        for id in &plan.decode {
            pool.get_mut(id).unwrap().decoded += 1;
        }
    }

    #[test]
    fn continuous_prioritizes_prefill_then_batches_decode() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 100, 3), mk(2, 200, 3)]);
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill.len(), 2);
        assert_eq!(p1.prefill_tokens(), 300);
        assert!(p1.decode.is_empty());
        apply(&p1, &mut pool);
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert!(p2.prefill.is_empty());
        assert_eq!(p2.decode.len(), 2);
    }

    #[test]
    fn continuous_prefill_preempts_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::Continuous, vec![mk(1, 100, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill 1
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode 1
        // request 2 arrives — its prefill must preempt
        pool.insert(2, mk(2, 50, 5));
        s.enqueue(2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 50)]);
        assert!(p.decode.is_empty());
    }

    #[test]
    fn chunked_mixes_decode_and_prefill_within_budget() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Chunked { chunk: 512 }, vec![mk(1, 100, 5), mk(2, 2000, 5)]);
        // step 1: no decoders yet; chunk filled with prefill
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill_tokens(), 512);
        assert_eq!(p1.prefill, vec![(1, 100), (2, 412)]);
        apply(&p1, &mut pool);
        // step 2: req 1 decodes (1 token), req 2 continues prefill
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.decode, vec![1]);
        assert_eq!(p2.prefill, vec![(2, 511)]);
        apply(&p2, &mut pool);
        assert_eq!(pool[&2].prefilled, 923);
    }

    #[test]
    fn static_admits_only_when_drained() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Static, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill both
        // late arrival must NOT join the in-flight batch
        pool.insert(3, mk(3, 10, 2));
        s.enqueue(3);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.decode.len(), 2);
        assert!(p.prefill.is_empty());
        apply(&p, &mut pool);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode to done
        // drain completed
        for id in [1u64, 2] {
            assert!(pool[&id].decode_complete());
            let res = s.remove(id).expect("was admitted");
            kv.release(res);
        }
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(3, 10)]);
    }

    #[test]
    fn mixed_coschedules_full_prefill_with_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::Mixed, vec![mk(1, 100, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool);
        pool.insert(2, mk(2, 300, 5));
        s.enqueue(2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 300)]);
        assert_eq!(p.decode, vec![1]);
    }

    #[test]
    fn kv_admission_blocks_and_releases() {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(
            BatchingKind::Continuous,
            Packing::Fcfs,
            SchedConfig::default(),
        );
        // capacity for exactly one request's peak (100 prompt + 10 out)
        let mut kv = KvManager::new(115.0);
        for r in [mk(1, 100, 10), mk(2, 100, 10)] {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill.len(), 1, "second request must not fit");
        assert_eq!(s.queue_len(), 1);
        // completion releases memory → the waiter is admitted
        kv.release(s.remove(1).unwrap());
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.prefill, vec![(2, 100)]);
    }

    #[test]
    fn seq_cap_respected_with_branches() {
        let mut r1 = mk(1, 10, 5);
        r1.branches = 6;
        let mut r2 = mk(2, 10, 5);
        r2.branches = 6;
        let (mut s, pool, mut kv) = setup(BatchingKind::Continuous, vec![r1, r2]);
        s.cfg.max_batch_seqs = 8;
        s.plan(&pool, &mut kv).unwrap();
        // only one 6-branch request fits under the 8-seq cap
        assert_eq!(s.running_len(), 1);
    }

    #[test]
    fn prefill_only_role_ignores_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::PrefillOnly, vec![mk(1, 100, 5)]);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(1, 100)]);
        apply(&p, &mut pool);
        assert!(s.plan(&pool, &mut kv).is_none(), "prefill done -> idle");
        // and its reservation was prefix-only
        assert_eq!(kv.used_tokens, 100.0);
    }

    #[test]
    fn decode_only_role_batches_arrivals() {
        let mut r1 = mk(1, 100, 3);
        r1.prefilled = 100; // arrives with prefill done (KV transferred in)
        let mut r2 = mk(2, 50, 3);
        r2.prefilled = 50;
        let (mut s, pool, mut kv) = setup(BatchingKind::DecodeOnly, vec![r1, r2]);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert!(p.prefill.is_empty());
        assert_eq!(p.decode.len(), 2);
    }

    #[test]
    fn plan_features_aggregate_correctly() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Chunked { chunk: 256 }, vec![mk(1, 100, 5), mk(2, 400, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // (1,100),(2,156)
        let p = s.plan(&pool, &mut kv).unwrap();
        let f = p.features(&pool);
        assert_eq!(f.dec_batch, 1.0);
        assert!(f.pf_new > 0.0);
        assert_eq!(f.pf_items, 1.0);
        assert!((f.pf_past - 156.0).abs() < 1e-9);
    }

    #[test]
    fn remove_unadmitted_request_from_waiting() {
        let (mut s, pool, _kv) = setup(BatchingKind::Continuous, vec![mk(1, 10, 2)]);
        let _ = pool;
        assert!(s.remove(1).is_none(), "still waiting -> no KV to release");
        assert_eq!(s.queue_len(), 0);
    }
}
