//! The LLM scheduler: queue + KV-admission bookkeeping in front of a
//! pluggable [`BatchPolicy`] (paper §III-D.1).
//!
//! `LlmSched` owns what every batching strategy shares — the waiting
//! queue, the admitted set, per-request KV reservations, and the
//! admission loop with its sequence/KV caps — and delegates the two
//! policy decisions (when to admit, what a step executes) to a
//! [`BatchPolicy`]. The paper's strategy roster is the [`BatchingKind`]
//! enum, which maps 1:1 onto the built-in policies in
//! [`policy`](super::policy); custom policies plug in through
//! [`LlmSched::with_policy`].
//!
//! **Model lanes.** A scheduler hosts one *lane* per co-resident model
//! (multi-model serving, docs/models.md): each lane keeps its own
//! waiting/running queues, KV reservations and `BatchPolicy` instance,
//! because an engine step executes exactly one model's weights — lanes
//! are never co-batched. Steps are granted round-robin across lanes
//! with work. All lanes draw admissions from the *shared* per-client
//! [`KvManager`]: a lane's `kv_scale` converts its token reservations
//! into the manager's units (1.0 for a single-model client whose
//! manager counts tokens; bytes/token for co-resident models sharing an
//! HBM byte budget). The single-lane case is the exact pre-lane
//! scheduler: one lane, cursor pinned at 0, `kv_scale = 1.0`.
//!
//! Hot-loop design (docs/performance.md): the waiting queue supports
//! O(1) logical removal — a membership set plus tombstones that the
//! next admission pass compacts away — instead of the old O(queue)
//! `retain` per admitted request; the admitted sequence count is
//! maintained incrementally instead of re-summed per candidate; and
//! candidate/prefiller lists live in reusable scratch buffers, so
//! steady-state planning performs no allocations.

use std::collections::{HashMap, HashSet, VecDeque};

use super::packing::Packing;
use super::policy::{
    BatchPolicy, ChunkedPrefill, ContinuousBatching, DecodeRole, MixedBatching, PlanCtx,
    PrefillRole, StaticBatching,
};
use super::{RequestPool, StepPlan};
use crate::memory::hierarchy::KvManager;
use crate::model::ModelId;
use crate::workload::request::ReqId;

/// Declarative name for one of the built-in batching policies; the
/// config / scenario layers and pool labels speak this enum, the
/// scheduler speaks [`BatchPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchingKind {
    Static,
    Continuous,
    Chunked { chunk: usize },
    Mixed,
    PrefillOnly,
    DecodeOnly,
}

impl BatchingKind {
    pub fn name(&self) -> &'static str {
        match self {
            BatchingKind::Static => "static",
            BatchingKind::Continuous => "continuous",
            BatchingKind::Chunked { .. } => "chunked",
            BatchingKind::Mixed => "mixed",
            BatchingKind::PrefillOnly => "prefill-only",
            BatchingKind::DecodeOnly => "decode-only",
        }
    }

    /// Instantiate the built-in policy this kind names.
    pub fn policy(&self) -> Box<dyn BatchPolicy> {
        match *self {
            BatchingKind::Static => Box::new(StaticBatching),
            BatchingKind::Continuous => Box::new(ContinuousBatching),
            BatchingKind::Chunked { chunk } => Box::new(ChunkedPrefill { chunk }),
            BatchingKind::Mixed => Box::new(MixedBatching),
            BatchingKind::PrefillOnly => Box::new(PrefillRole),
            BatchingKind::DecodeOnly => Box::new(DecodeRole),
        }
    }
}

/// User constraints (paper: "maximum number of batched tokens or batch
/// size").
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// maximum decode sequences co-batched in a step
    pub max_batch_seqs: usize,
    /// maximum new prefill tokens in a step
    pub max_batch_tokens: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            max_batch_seqs: 256,
            max_batch_tokens: 8192,
        }
    }
}

/// Per-admission bookkeeping: the reservation charged to the shared
/// [`KvManager`] (in manager units), the same reservation in tokens
/// (per-model load reporting), and the request's decode-sequence
/// contribution to the batch-size cap.
#[derive(Debug, Clone, Copy)]
struct Reservation {
    kv_units: f64,
    tokens: f64,
    seqs: usize,
}

/// One model's scheduling state inside an [`LlmSched`].
struct Lane {
    /// `None` = the model-agnostic single lane of a classic scheduler
    model: Option<ModelId>,
    policy: Box<dyn BatchPolicy>,
    /// KvManager units per reserved token (1.0 single-model; the
    /// model's KV bytes/token on a shared-byte-budget client)
    kv_scale: f64,
    /// arrived but not yet admitted, in arrival order; may contain
    /// tombstoned ids (see `gone`) that the next admission compacts out
    waiting: VecDeque<ReqId>,
    /// ids logically in `waiting` — O(1) membership and removal
    waiting_set: HashSet<ReqId>,
    /// tombstone counts: how many stale copies of each id are still
    /// physically in `waiting` (an id can be removed, re-enqueued and
    /// removed again before a compaction runs, leaving several stale
    /// copies)
    gone: HashMap<ReqId, u32>,
    /// admitted: KV reserved, being prefilled/decoded
    running: Vec<ReqId>,
    /// Σ decode sequences over `running` — kept incrementally so the
    /// admission loop is O(candidates), not O(candidates × running)
    running_seqs: usize,
    /// KV/seq reservation per admitted request (released via `remove`)
    reserved: HashMap<ReqId, Reservation>,
    /// reusable candidate buffer for the admission pass
    cand: Vec<ReqId>,
    /// Σ reserved tokens — the per-model `kv_tokens` the router sees
    kv_held_tokens: f64,
}

impl Lane {
    fn new(model: Option<ModelId>, policy: Box<dyn BatchPolicy>, kv_scale: f64) -> Lane {
        Lane {
            model,
            policy,
            kv_scale,
            waiting: VecDeque::new(),
            waiting_set: HashSet::new(),
            gone: HashMap::new(),
            running: Vec::new(),
            running_seqs: 0,
            reserved: HashMap::new(),
            cand: Vec::new(),
            kv_held_tokens: 0.0,
        }
    }
}

/// One lane of a multi-model scheduler, as built by the simulation
/// assembler: the model it serves, its batching policy, and the token →
/// KvManager-unit scale for shared-budget admission.
pub struct LaneSpec {
    pub model: ModelId,
    pub policy: Box<dyn BatchPolicy>,
    pub kv_scale: f64,
}

/// vLLM-like scheduler state for one LLM client.
pub struct LlmSched {
    lanes: Vec<Lane>,
    pub packing: Packing,
    pub cfg: SchedConfig,
    /// reusable prefiller buffer lent to policies via [`PlanCtx`]
    scratch: Vec<ReqId>,
    /// round-robin start lane for the next planning pass
    cursor: usize,
    /// lane of the most recently composed plan
    planned: usize,
    pub admissions: u64,
}

impl LlmSched {
    /// Scheduler running one of the built-in batching strategies.
    pub fn new(kind: BatchingKind, packing: Packing, cfg: SchedConfig) -> LlmSched {
        LlmSched::with_policy(kind.policy(), packing, cfg)
    }

    /// Scheduler running a custom [`BatchPolicy`] (single model lane).
    pub fn with_policy(
        policy: Box<dyn BatchPolicy>,
        packing: Packing,
        cfg: SchedConfig,
    ) -> LlmSched {
        LlmSched {
            lanes: vec![Lane::new(None, policy, 1.0)],
            packing,
            cfg,
            scratch: Vec::new(),
            cursor: 0,
            planned: 0,
            admissions: 0,
        }
    }

    /// Scheduler with one lane per co-resident model.
    pub fn multi_model(lanes: Vec<LaneSpec>, packing: Packing, cfg: SchedConfig) -> LlmSched {
        assert!(!lanes.is_empty(), "scheduler needs at least one lane");
        LlmSched {
            lanes: lanes
                .into_iter()
                .map(|l| Lane::new(Some(l.model), l.policy, l.kv_scale))
                .collect(),
            packing,
            cfg,
            scratch: Vec::new(),
            cursor: 0,
            planned: 0,
            admissions: 0,
        }
    }

    pub fn policy(&self) -> &dyn BatchPolicy {
        &*self.lanes[0].policy
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Model of lane `i` (`None` for the model-agnostic single lane).
    pub fn lane_model(&self, i: usize) -> Option<ModelId> {
        self.lanes[i].model
    }

    /// Can any lane's policy execute prompt processing?
    pub fn serves_prefill(&self) -> bool {
        self.lanes.iter().any(|l| l.policy.serves_prefill())
    }

    /// Can any lane's policy execute token generation?
    pub fn serves_decode(&self) -> bool {
        self.lanes.iter().any(|l| l.policy.serves_decode())
    }

    pub fn lane_serves_prefill(&self, i: usize) -> bool {
        self.lanes[i].policy.serves_prefill()
    }

    pub fn lane_serves_decode(&self, i: usize) -> bool {
        self.lanes[i].policy.serves_decode()
    }

    pub fn enqueue(&mut self, id: ReqId) {
        self.enqueue_lane(0, id);
    }

    pub fn enqueue_lane(&mut self, lane: usize, id: ReqId) {
        let l = &mut self.lanes[lane];
        let fresh = l.waiting_set.insert(id);
        debug_assert!(fresh, "request {id} enqueued twice");
        l.waiting.push_back(id);
    }

    pub fn queue_len(&self) -> usize {
        self.lanes.iter().map(|l| l.waiting_set.len()).sum()
    }

    pub fn running_len(&self) -> usize {
        self.lanes.iter().map(|l| l.running.len()).sum()
    }

    pub fn lane_queue_len(&self, i: usize) -> usize {
        self.lanes[i].waiting_set.len()
    }

    pub fn lane_running_len(&self, i: usize) -> usize {
        self.lanes[i].running.len()
    }

    /// Σ reserved KV tokens of lane `i` — the per-model `kv_tokens`
    /// feeding `Client::load_for_model`. O(1) incremental counter.
    pub fn lane_kv_held(&self, i: usize) -> f64 {
        self.lanes[i].kv_held_tokens
    }

    /// Recompute lane `i`'s reserved KV tokens from the reservation map
    /// — ground truth for [`LlmSched::lane_kv_held`] in the per-model
    /// drift invariant and the full-scan baseline. O(running); exact
    /// regardless of iteration order because reservations are
    /// integer-valued token counts.
    pub fn lane_kv_recompute(&self, i: usize) -> f64 {
        self.lanes[i].reserved.values().map(|r| r.tokens).sum()
    }

    pub fn is_idle(&self) -> bool {
        self.lanes
            .iter()
            .all(|l| l.waiting_set.is_empty() && l.running.is_empty())
    }

    /// Remove a completed / transferred-out request. Returns the KV
    /// reservation (in shared-KvManager units) the caller must release,
    /// or `None` if it was never admitted. O(1) for waiting requests
    /// (tombstoned, compacted lazily); O(running) for admitted ones
    /// (bounded by the seq cap). Searches lanes in order — a request
    /// lives in exactly one lane.
    pub fn remove(&mut self, id: ReqId) -> Option<f64> {
        for l in &mut self.lanes {
            if let Some(i) = l.running.iter().position(|r| *r == id) {
                l.running.swap_remove(i);
                let rsv = l
                    .reserved
                    .remove(&id)
                    .expect("running request without a reservation");
                l.running_seqs -= rsv.seqs;
                l.kv_held_tokens -= rsv.tokens;
                return Some(rsv.kv_units);
            }
            if l.waiting_set.remove(&id) {
                *l.gone.entry(id).or_insert(0) += 1;
                return None;
            }
        }
        None
    }

    /// Admit from a lane's `waiting` in packing order while the shared
    /// KV pool and the per-lane seq cap allow. Compacts tombstones out
    /// of the deque as a side effect.
    fn admit(
        lane: &mut Lane,
        packing: Packing,
        cfg: &SchedConfig,
        pool: &RequestPool,
        kv: &mut KvManager,
        admissions: &mut u64,
    ) {
        if lane.waiting_set.is_empty() {
            if !lane.waiting.is_empty() {
                // only tombstones left — drop them
                lane.waiting.clear();
                lane.gone.clear();
            }
            return;
        }
        let mut cand = std::mem::take(&mut lane.cand);
        cand.clear();
        if lane.gone.is_empty() {
            cand.extend(lane.waiting.iter().copied());
        } else {
            // drop stale entries while collecting the live ones; a
            // re-enqueued id keeps its fresh entry because its stale
            // copies sit earlier in the FIFO and each consumes one
            // tombstone count
            let gone = &mut lane.gone;
            let waiting = &mut lane.waiting;
            waiting.retain(|id| {
                if let Some(n) = gone.get_mut(id) {
                    *n -= 1;
                    let drained = *n == 0;
                    if drained {
                        gone.remove(id);
                    }
                    false
                } else {
                    cand.push(*id);
                    true
                }
            });
        }
        packing.order(&mut cand, pool);
        for id in cand.iter().copied() {
            let seqs = pool[&id].decode_seqs();
            if lane.running_seqs + seqs > cfg.max_batch_seqs {
                break;
            }
            let tokens = lane.policy.admit_tokens(&pool[&id]);
            if kv.admit(tokens * lane.kv_scale) {
                lane.waiting_set.remove(&id);
                // tombstone the (single, live) deque entry
                *lane.gone.entry(id).or_insert(0) += 1;
                lane.running.push(id);
                lane.running_seqs += seqs;
                lane.reserved.insert(
                    id,
                    Reservation {
                        kv_units: tokens * lane.kv_scale,
                        tokens,
                        seqs,
                    },
                );
                lane.kv_held_tokens += tokens;
                *admissions += 1;
            } else {
                // FCFS head-of-line blocking: stop at the first request
                // that does not fit (vLLM semantics)
                break;
            }
        }
        lane.cand = cand;
    }

    /// Fill `plan` with the next step; returns `false` (and leaves the
    /// plan empty) when there is nothing to run. Lanes are visited
    /// round-robin starting at the cursor; the first lane that composes
    /// a non-empty step wins it and the cursor moves past it (fairness
    /// across co-resident models). The plan is a reusable caller-owned
    /// buffer — no allocations in steady state.
    pub fn plan_into(
        &mut self,
        pool: &RequestPool,
        kv: &mut KvManager,
        plan: &mut StepPlan,
    ) -> bool {
        plan.clear();
        let n = self.lanes.len();
        for k in 0..n {
            let i = (self.cursor + k) % n;
            let lane = &mut self.lanes[i];
            if lane.policy.admits_mid_batch() || lane.running.is_empty() {
                Self::admit(lane, self.packing, &self.cfg, pool, kv, &mut self.admissions);
            }
            let mut ctx = PlanCtx {
                running: &lane.running,
                cfg: &self.cfg,
                packing: self.packing,
                scratch: &mut self.scratch,
            };
            lane.policy.compose(&mut ctx, pool, plan);
            if !plan.is_empty() {
                self.planned = i;
                self.cursor = (i + 1) % n;
                return true;
            }
        }
        false
    }

    /// Lane of the plan most recently composed by
    /// [`LlmSched::plan_into`] — the client prices and accounts the
    /// step against this lane's model.
    pub fn planned_lane(&self) -> usize {
        self.planned
    }

    /// Allocating convenience wrapper around [`LlmSched::plan_into`]
    /// (tests and exploratory code; the client hot path reuses its own
    /// buffer).
    pub fn plan(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        let mut plan = StepPlan::default();
        if self.plan_into(pool, kv, &mut plan) {
            Some(plan)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::{Request, Stage};

    fn mk(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::from_secs(id as f64 * 0.01),
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    fn setup(kind: BatchingKind, reqs: Vec<Request>) -> (LlmSched, RequestPool, KvManager) {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(kind, Packing::Fcfs, SchedConfig::default());
        for r in reqs {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        (s, pool, KvManager::new(1e9))
    }

    /// apply a plan the way a client would: progress tokens
    fn apply(plan: &StepPlan, pool: &mut RequestPool) {
        for (id, n) in &plan.prefill {
            pool.get_mut(id).unwrap().prefilled += n;
        }
        for id in &plan.decode {
            pool.get_mut(id).unwrap().decoded += 1;
        }
    }

    #[test]
    fn continuous_prioritizes_prefill_then_batches_decode() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 100, 3), mk(2, 200, 3)]);
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill.len(), 2);
        assert_eq!(p1.prefill_tokens(), 300);
        assert!(p1.decode.is_empty());
        apply(&p1, &mut pool);
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert!(p2.prefill.is_empty());
        assert_eq!(p2.decode.len(), 2);
    }

    #[test]
    fn continuous_prefill_preempts_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::Continuous, vec![mk(1, 100, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill 1
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode 1
        // request 2 arrives — its prefill must preempt
        pool.insert(2, mk(2, 50, 5));
        s.enqueue(2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 50)]);
        assert!(p.decode.is_empty());
    }

    #[test]
    fn chunked_mixes_decode_and_prefill_within_budget() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Chunked { chunk: 512 }, vec![mk(1, 100, 5), mk(2, 2000, 5)]);
        // step 1: no decoders yet; chunk filled with prefill
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill_tokens(), 512);
        assert_eq!(p1.prefill, vec![(1, 100), (2, 412)]);
        apply(&p1, &mut pool);
        // step 2: req 1 decodes (1 token), req 2 continues prefill
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.decode, vec![1]);
        assert_eq!(p2.prefill, vec![(2, 511)]);
        apply(&p2, &mut pool);
        assert_eq!(pool[&2].prefilled, 923);
    }

    #[test]
    fn static_admits_only_when_drained() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Static, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill both
        // late arrival must NOT join the in-flight batch
        pool.insert(3, mk(3, 10, 2));
        s.enqueue(3);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.decode.len(), 2);
        assert!(p.prefill.is_empty());
        apply(&p, &mut pool);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode to done
        // drain completed
        for id in [1u64, 2] {
            assert!(pool[&id].decode_complete());
            let res = s.remove(id).expect("was admitted");
            kv.release(res);
        }
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(3, 10)]);
    }

    #[test]
    fn mixed_coschedules_full_prefill_with_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::Mixed, vec![mk(1, 100, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool);
        pool.insert(2, mk(2, 300, 5));
        s.enqueue(2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 300)]);
        assert_eq!(p.decode, vec![1]);
    }

    #[test]
    fn kv_admission_blocks_and_releases() {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(
            BatchingKind::Continuous,
            Packing::Fcfs,
            SchedConfig::default(),
        );
        // capacity for exactly one request's peak (100 prompt + 10 out)
        let mut kv = KvManager::new(115.0);
        for r in [mk(1, 100, 10), mk(2, 100, 10)] {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill.len(), 1, "second request must not fit");
        assert_eq!(s.queue_len(), 1);
        // completion releases memory → the waiter is admitted
        kv.release(s.remove(1).unwrap());
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.prefill, vec![(2, 100)]);
    }

    #[test]
    fn seq_cap_respected_with_branches() {
        let mut r1 = mk(1, 10, 5);
        r1.branches = 6;
        let mut r2 = mk(2, 10, 5);
        r2.branches = 6;
        let (mut s, pool, mut kv) = setup(BatchingKind::Continuous, vec![r1, r2]);
        s.cfg.max_batch_seqs = 8;
        s.plan(&pool, &mut kv).unwrap();
        // only one 6-branch request fits under the 8-seq cap
        assert_eq!(s.running_len(), 1);
    }

    #[test]
    fn prefill_only_role_ignores_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::PrefillOnly, vec![mk(1, 100, 5)]);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(1, 100)]);
        apply(&p, &mut pool);
        assert!(s.plan(&pool, &mut kv).is_none(), "prefill done -> idle");
        // and its reservation was prefix-only
        assert_eq!(kv.used_tokens, 100.0);
    }

    #[test]
    fn decode_only_role_batches_arrivals() {
        let mut r1 = mk(1, 100, 3);
        r1.prefilled = 100; // arrives with prefill done (KV transferred in)
        let mut r2 = mk(2, 50, 3);
        r2.prefilled = 50;
        let (mut s, pool, mut kv) = setup(BatchingKind::DecodeOnly, vec![r1, r2]);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert!(p.prefill.is_empty());
        assert_eq!(p.decode.len(), 2);
    }

    #[test]
    fn plan_features_aggregate_correctly() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Chunked { chunk: 256 }, vec![mk(1, 100, 5), mk(2, 400, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // (1,100),(2,156)
        let p = s.plan(&pool, &mut kv).unwrap();
        let f = p.features(&pool);
        assert_eq!(f.dec_batch, 1.0);
        assert!(f.pf_new > 0.0);
        assert_eq!(f.pf_items, 1.0);
        assert!((f.pf_past - 156.0).abs() < 1e-9);
    }

    #[test]
    fn remove_unadmitted_request_from_waiting() {
        let (mut s, pool, _kv) = setup(BatchingKind::Continuous, vec![mk(1, 10, 2)]);
        let _ = pool;
        assert!(s.remove(1).is_none(), "still waiting -> no KV to release");
        assert_eq!(s.queue_len(), 0);
        assert!(s.is_idle());
    }

    #[test]
    fn tombstoned_entry_does_not_resurrect_on_reenqueue() {
        // remove a waiting request, re-enqueue the same id, and make
        // sure exactly one live entry survives the compaction
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        assert!(s.remove(1).is_none());
        assert_eq!(s.queue_len(), 1);
        // the pool rejects duplicate ids, so retire the old payload
        // before storing the fresh request under the same id
        pool.remove(1);
        pool.insert(1, mk(1, 30, 2));
        s.enqueue(1);
        assert_eq!(s.queue_len(), 2);
        let p = s.plan(&pool, &mut kv).unwrap();
        // both admitted, each exactly once, with the *fresh* request 1
        assert_eq!(s.running_len(), 2);
        assert_eq!(s.queue_len(), 0);
        let mut planned = p.prefill.clone();
        planned.sort_unstable();
        assert_eq!(planned, vec![(1, 30), (2, 10)]);
    }

    #[test]
    fn double_removed_waiting_id_stays_removed() {
        // two stale copies of the same id can sit in the deque before a
        // compaction runs; both must be dropped (tombstone counts)
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        assert!(s.remove(1).is_none()); // deque [1s, 2]
        pool.remove(1); // duplicate ids are rejected: retire, then re-insert
        pool.insert(1, mk(1, 30, 2));
        s.enqueue(1); // deque [1s, 2, 1]
        assert!(s.remove(1).is_none()); // deque [1s, 2, 1s]
        assert_eq!(s.queue_len(), 1);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 10)], "removed id must not be admitted");
        assert_eq!(s.running_len(), 1);
        assert_eq!(s.queue_len(), 0);
    }

    #[test]
    fn kind_maps_to_policy_names_and_roles() {
        for (kind, name) in [
            (BatchingKind::Static, "static"),
            (BatchingKind::Continuous, "continuous"),
            (BatchingKind::Chunked { chunk: 64 }, "chunked"),
            (BatchingKind::Mixed, "mixed"),
            (BatchingKind::PrefillOnly, "prefill-only"),
            (BatchingKind::DecodeOnly, "decode-only"),
        ] {
            let p = kind.policy();
            assert_eq!(p.name(), name);
            assert_eq!(p.name(), kind.name());
        }
        let s = LlmSched::new(BatchingKind::PrefillOnly, Packing::Fcfs, SchedConfig::default());
        assert!(s.serves_prefill() && !s.serves_decode());
    }

    // ---- multi-model lanes -------------------------------------------------

    use crate::model::ModelId;

    fn mk_model(id: u64, model: &str, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            model,
            SimTime::from_secs(id as f64 * 0.01),
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    fn two_lane() -> LlmSched {
        LlmSched::multi_model(
            vec![
                LaneSpec {
                    model: ModelId::named("llama3-8b"),
                    policy: BatchingKind::Continuous.policy(),
                    kv_scale: 1.0,
                },
                LaneSpec {
                    model: ModelId::named("llama3-70b"),
                    policy: BatchingKind::Continuous.policy(),
                    kv_scale: 2.0,
                },
            ],
            Packing::Fcfs,
            SchedConfig::default(),
        )
    }

    #[test]
    fn lanes_round_robin_and_never_cobatch() {
        let mut s = two_lane();
        let mut pool = RequestPool::new();
        let mut kv = KvManager::new(1e9);
        pool.insert(1, mk_model(1, "llama3-8b", 100, 3));
        pool.insert(2, mk_model(2, "llama3-70b", 200, 3));
        s.enqueue_lane(0, 1);
        s.enqueue_lane(1, 2);
        assert_eq!(s.queue_len(), 2);
        assert_eq!(s.lane_queue_len(0), 1);
        // first step: lane 0 (cursor start); only the 8B request
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(s.planned_lane(), 0);
        assert_eq!(p1.prefill, vec![(1, 100)]);
        apply(&p1, &mut pool);
        // next step goes to lane 1 even though lane 0 still has work
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(s.planned_lane(), 1);
        assert_eq!(p2.prefill, vec![(2, 200)]);
        apply(&p2, &mut pool);
        // back to lane 0 for its decode
        let p3 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(s.planned_lane(), 0);
        assert_eq!(p3.decode, vec![1]);
    }

    #[test]
    fn shared_kv_pool_admits_across_lanes_by_scale() {
        let mut s = two_lane();
        let mut pool = RequestPool::new();
        // pool of 500 units: lane0 peak = 110 units (scale 1), lane1
        // peak = 2*(200+10) = 420 units — together they exceed capacity
        let mut kv = KvManager::new(500.0);
        pool.insert(1, mk_model(1, "llama3-8b", 100, 10));
        pool.insert(2, mk_model(2, "llama3-70b", 200, 10));
        s.enqueue_lane(0, 1);
        s.enqueue_lane(1, 2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(1, 100)]);
        assert_eq!(kv.used_tokens, 110.0);
        // per-lane held tokens are reported unscaled
        assert_eq!(s.lane_kv_held(0), 110.0);
        assert_eq!(s.lane_kv_held(1), 0.0);
        apply(&p, &mut pool);
        // next pass visits lane 1 first: its scaled reservation does not
        // fit the shared pool, so the step falls back to lane 0's decode
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(s.planned_lane(), 0);
        assert_eq!(p2.decode, vec![1]);
        assert_eq!(kv.rejections, 1, "lane 1 blocked by the shared budget");
        assert_eq!(s.lane_running_len(1), 0);
        assert_eq!(s.lane_queue_len(1), 1);
        // releasing lane 0 frees the shared pool for lane 1
        kv.release(s.remove(1).unwrap());
        let p3 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p3.prefill, vec![(2, 200)]);
        assert_eq!(kv.used_tokens, 420.0, "scaled reservation");
        assert_eq!(s.lane_kv_held(1), 210.0, "token-denominated");
    }

    #[test]
    fn remove_finds_requests_across_lanes() {
        let mut s = two_lane();
        let mut pool = RequestPool::new();
        let mut kv = KvManager::new(1e9);
        pool.insert(1, mk_model(1, "llama3-8b", 100, 3));
        pool.insert(2, mk_model(2, "llama3-70b", 200, 3));
        s.enqueue_lane(0, 1);
        s.enqueue_lane(1, 2);
        // two planning passes: one step (and admission) per lane
        let p1 = s.plan(&pool, &mut kv).unwrap();
        apply(&p1, &mut pool);
        let p2 = s.plan(&pool, &mut kv).unwrap();
        apply(&p2, &mut pool);
        assert_eq!(s.running_len(), 2);
        let released = s.remove(2).expect("admitted in lane 1");
        assert_eq!(released, 2.0 * (200.0 + 3.0), "scaled units returned");
        assert_eq!(s.running_len(), 1);
        assert!(s.remove(99).is_none(), "unknown id is a no-op");
    }
}
