//! The LLM scheduler: queue + KV-admission bookkeeping in front of a
//! pluggable [`BatchPolicy`] (paper §III-D.1).
//!
//! `LlmSched` owns what every batching strategy shares — the waiting
//! queue, the admitted set, per-request KV reservations, and the
//! admission loop with its sequence/KV caps — and delegates the two
//! policy decisions (when to admit, what a step executes) to a
//! [`BatchPolicy`]. The paper's strategy roster is the [`BatchingKind`]
//! enum, which maps 1:1 onto the built-in policies in
//! [`policy`](super::policy); custom policies plug in through
//! [`LlmSched::with_policy`].
//!
//! Hot-loop design (docs/performance.md): the waiting queue supports
//! O(1) logical removal — a membership set plus tombstones that the
//! next admission pass compacts away — instead of the old O(queue)
//! `retain` per admitted request; the admitted sequence count is
//! maintained incrementally instead of re-summed per candidate; and
//! candidate/prefiller lists live in reusable scratch buffers, so
//! steady-state planning performs no allocations.

use std::collections::{HashMap, HashSet, VecDeque};

use super::packing::Packing;
use super::policy::{
    BatchPolicy, ChunkedPrefill, ContinuousBatching, DecodeRole, MixedBatching, PlanCtx,
    PrefillRole, StaticBatching,
};
use super::{RequestPool, StepPlan};
use crate::memory::hierarchy::KvManager;
use crate::workload::request::ReqId;

/// Declarative name for one of the built-in batching policies; the
/// config / scenario layers and pool labels speak this enum, the
/// scheduler speaks [`BatchPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchingKind {
    Static,
    Continuous,
    Chunked { chunk: usize },
    Mixed,
    PrefillOnly,
    DecodeOnly,
}

impl BatchingKind {
    pub fn name(&self) -> &'static str {
        match self {
            BatchingKind::Static => "static",
            BatchingKind::Continuous => "continuous",
            BatchingKind::Chunked { .. } => "chunked",
            BatchingKind::Mixed => "mixed",
            BatchingKind::PrefillOnly => "prefill-only",
            BatchingKind::DecodeOnly => "decode-only",
        }
    }

    /// Instantiate the built-in policy this kind names.
    pub fn policy(&self) -> Box<dyn BatchPolicy> {
        match *self {
            BatchingKind::Static => Box::new(StaticBatching),
            BatchingKind::Continuous => Box::new(ContinuousBatching),
            BatchingKind::Chunked { chunk } => Box::new(ChunkedPrefill { chunk }),
            BatchingKind::Mixed => Box::new(MixedBatching),
            BatchingKind::PrefillOnly => Box::new(PrefillRole),
            BatchingKind::DecodeOnly => Box::new(DecodeRole),
        }
    }
}

/// User constraints (paper: "maximum number of batched tokens or batch
/// size").
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// maximum decode sequences co-batched in a step
    pub max_batch_seqs: usize,
    /// maximum new prefill tokens in a step
    pub max_batch_tokens: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            max_batch_seqs: 256,
            max_batch_tokens: 8192,
        }
    }
}

/// Per-admission bookkeeping: the KV tokens reserved for the request
/// and its decode-sequence contribution to the batch-size cap.
#[derive(Debug, Clone, Copy)]
struct Reservation {
    kv: f64,
    seqs: usize,
}

/// vLLM-like scheduler state for one LLM client.
pub struct LlmSched {
    policy: Box<dyn BatchPolicy>,
    pub packing: Packing,
    pub cfg: SchedConfig,
    /// arrived but not yet admitted, in arrival order; may contain
    /// tombstoned ids (see `gone`) that the next admission compacts out
    waiting: VecDeque<ReqId>,
    /// ids logically in `waiting` — O(1) membership and removal
    waiting_set: HashSet<ReqId>,
    /// tombstone counts: how many stale copies of each id are still
    /// physically in `waiting` (an id can be removed, re-enqueued and
    /// removed again before a compaction runs, leaving several stale
    /// copies)
    gone: HashMap<ReqId, u32>,
    /// admitted: KV reserved, being prefilled/decoded
    running: Vec<ReqId>,
    /// Σ decode sequences over `running` — kept incrementally so the
    /// admission loop is O(candidates), not O(candidates × running)
    running_seqs: usize,
    /// KV/seq reservation per admitted request (released via `remove`)
    reserved: HashMap<ReqId, Reservation>,
    /// reusable candidate buffer for the admission pass
    cand: Vec<ReqId>,
    /// reusable prefiller buffer lent to policies via [`PlanCtx`]
    scratch: Vec<ReqId>,
    /// queue-length samples for scheduler metrics
    pub admissions: u64,
}

impl LlmSched {
    /// Scheduler running one of the built-in batching strategies.
    pub fn new(kind: BatchingKind, packing: Packing, cfg: SchedConfig) -> LlmSched {
        LlmSched::with_policy(kind.policy(), packing, cfg)
    }

    /// Scheduler running a custom [`BatchPolicy`].
    pub fn with_policy(
        policy: Box<dyn BatchPolicy>,
        packing: Packing,
        cfg: SchedConfig,
    ) -> LlmSched {
        LlmSched {
            policy,
            packing,
            cfg,
            waiting: VecDeque::new(),
            waiting_set: HashSet::new(),
            gone: HashMap::new(),
            running: Vec::new(),
            running_seqs: 0,
            reserved: HashMap::new(),
            cand: Vec::new(),
            scratch: Vec::new(),
            admissions: 0,
        }
    }

    pub fn policy(&self) -> &dyn BatchPolicy {
        &*self.policy
    }

    /// Can this scheduler's policy execute prompt processing?
    pub fn serves_prefill(&self) -> bool {
        self.policy.serves_prefill()
    }

    /// Can this scheduler's policy execute token generation?
    pub fn serves_decode(&self) -> bool {
        self.policy.serves_decode()
    }

    pub fn enqueue(&mut self, id: ReqId) {
        let fresh = self.waiting_set.insert(id);
        debug_assert!(fresh, "request {id} enqueued twice");
        self.waiting.push_back(id);
    }

    pub fn queue_len(&self) -> usize {
        self.waiting_set.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting_set.is_empty() && self.running.is_empty()
    }

    /// Remove a completed / transferred-out request. Returns the KV
    /// tokens that were reserved for it (the caller releases them from
    /// the KvManager), or `None` if it was never admitted. O(1) for
    /// waiting requests (tombstoned, compacted lazily); O(running) for
    /// admitted ones (bounded by the seq cap).
    pub fn remove(&mut self, id: ReqId) -> Option<f64> {
        if let Some(i) = self.running.iter().position(|r| *r == id) {
            self.running.swap_remove(i);
            let rsv = self
                .reserved
                .remove(&id)
                .expect("running request without a reservation");
            self.running_seqs -= rsv.seqs;
            Some(rsv.kv)
        } else {
            if self.waiting_set.remove(&id) {
                *self.gone.entry(id).or_insert(0) += 1;
            }
            None
        }
    }

    /// Admit from `waiting` in packing order while KV + seq caps allow.
    /// Compacts tombstones out of the deque as a side effect.
    fn admit(&mut self, pool: &RequestPool, kv: &mut KvManager) {
        if self.waiting_set.is_empty() {
            if !self.waiting.is_empty() {
                // only tombstones left — drop them
                self.waiting.clear();
                self.gone.clear();
            }
            return;
        }
        let mut cand = std::mem::take(&mut self.cand);
        cand.clear();
        if self.gone.is_empty() {
            cand.extend(self.waiting.iter().copied());
        } else {
            // drop stale entries while collecting the live ones; a
            // re-enqueued id keeps its fresh entry because its stale
            // copies sit earlier in the FIFO and each consumes one
            // tombstone count
            let gone = &mut self.gone;
            let waiting = &mut self.waiting;
            waiting.retain(|id| {
                if let Some(n) = gone.get_mut(id) {
                    *n -= 1;
                    let drained = *n == 0;
                    if drained {
                        gone.remove(id);
                    }
                    false
                } else {
                    cand.push(*id);
                    true
                }
            });
        }
        self.packing.order(&mut cand, pool);
        for id in cand.iter().copied() {
            let seqs = pool[&id].decode_seqs();
            if self.running_seqs + seqs > self.cfg.max_batch_seqs {
                break;
            }
            let tokens = self.policy.admit_tokens(&pool[&id]);
            if kv.admit(tokens) {
                self.waiting_set.remove(&id);
                // tombstone the (single, live) deque entry
                *self.gone.entry(id).or_insert(0) += 1;
                self.running.push(id);
                self.running_seqs += seqs;
                self.reserved.insert(id, Reservation { kv: tokens, seqs });
                self.admissions += 1;
            } else {
                // FCFS head-of-line blocking: stop at the first request
                // that does not fit (vLLM semantics)
                break;
            }
        }
        self.cand = cand;
    }

    /// Fill `plan` with the next step; returns `false` (and leaves the
    /// plan empty) when there is nothing to run. The plan is a reusable
    /// caller-owned buffer — no allocations in steady state.
    pub fn plan_into(
        &mut self,
        pool: &RequestPool,
        kv: &mut KvManager,
        plan: &mut StepPlan,
    ) -> bool {
        plan.clear();
        if self.policy.admits_mid_batch() || self.running.is_empty() {
            self.admit(pool, kv);
        }
        let mut ctx = PlanCtx {
            running: &self.running,
            cfg: &self.cfg,
            packing: self.packing,
            scratch: &mut self.scratch,
        };
        self.policy.compose(&mut ctx, pool, plan);
        !plan.is_empty()
    }

    /// Allocating convenience wrapper around [`LlmSched::plan_into`]
    /// (tests and exploratory code; the client hot path reuses its own
    /// buffer).
    pub fn plan(&mut self, pool: &RequestPool, kv: &mut KvManager) -> Option<StepPlan> {
        let mut plan = StepPlan::default();
        if self.plan_into(pool, kv, &mut plan) {
            Some(plan)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::{Request, Stage};

    fn mk(id: u64, prompt: usize, out: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::from_secs(id as f64 * 0.01),
            vec![Stage::Prefill, Stage::Decode],
            prompt,
            out,
        )
    }

    fn setup(kind: BatchingKind, reqs: Vec<Request>) -> (LlmSched, RequestPool, KvManager) {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(kind, Packing::Fcfs, SchedConfig::default());
        for r in reqs {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        (s, pool, KvManager::new(1e9))
    }

    /// apply a plan the way a client would: progress tokens
    fn apply(plan: &StepPlan, pool: &mut RequestPool) {
        for (id, n) in &plan.prefill {
            pool.get_mut(id).unwrap().prefilled += n;
        }
        for id in &plan.decode {
            pool.get_mut(id).unwrap().decoded += 1;
        }
    }

    #[test]
    fn continuous_prioritizes_prefill_then_batches_decode() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 100, 3), mk(2, 200, 3)]);
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill.len(), 2);
        assert_eq!(p1.prefill_tokens(), 300);
        assert!(p1.decode.is_empty());
        apply(&p1, &mut pool);
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert!(p2.prefill.is_empty());
        assert_eq!(p2.decode.len(), 2);
    }

    #[test]
    fn continuous_prefill_preempts_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::Continuous, vec![mk(1, 100, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill 1
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode 1
        // request 2 arrives — its prefill must preempt
        pool.insert(2, mk(2, 50, 5));
        s.enqueue(2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 50)]);
        assert!(p.decode.is_empty());
    }

    #[test]
    fn chunked_mixes_decode_and_prefill_within_budget() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Chunked { chunk: 512 }, vec![mk(1, 100, 5), mk(2, 2000, 5)]);
        // step 1: no decoders yet; chunk filled with prefill
        let p1 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p1.prefill_tokens(), 512);
        assert_eq!(p1.prefill, vec![(1, 100), (2, 412)]);
        apply(&p1, &mut pool);
        // step 2: req 1 decodes (1 token), req 2 continues prefill
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.decode, vec![1]);
        assert_eq!(p2.prefill, vec![(2, 511)]);
        apply(&p2, &mut pool);
        assert_eq!(pool[&2].prefilled, 923);
    }

    #[test]
    fn static_admits_only_when_drained() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Static, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // prefill both
        // late arrival must NOT join the in-flight batch
        pool.insert(3, mk(3, 10, 2));
        s.enqueue(3);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.decode.len(), 2);
        assert!(p.prefill.is_empty());
        apply(&p, &mut pool);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // decode to done
        // drain completed
        for id in [1u64, 2] {
            assert!(pool[&id].decode_complete());
            let res = s.remove(id).expect("was admitted");
            kv.release(res);
        }
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(3, 10)]);
    }

    #[test]
    fn mixed_coschedules_full_prefill_with_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::Mixed, vec![mk(1, 100, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool);
        pool.insert(2, mk(2, 300, 5));
        s.enqueue(2);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 300)]);
        assert_eq!(p.decode, vec![1]);
    }

    #[test]
    fn kv_admission_blocks_and_releases() {
        let mut pool = RequestPool::new();
        let mut s = LlmSched::new(
            BatchingKind::Continuous,
            Packing::Fcfs,
            SchedConfig::default(),
        );
        // capacity for exactly one request's peak (100 prompt + 10 out)
        let mut kv = KvManager::new(115.0);
        for r in [mk(1, 100, 10), mk(2, 100, 10)] {
            s.enqueue(r.id);
            pool.insert(r.id, r);
        }
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill.len(), 1, "second request must not fit");
        assert_eq!(s.queue_len(), 1);
        // completion releases memory → the waiter is admitted
        kv.release(s.remove(1).unwrap());
        let p2 = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p2.prefill, vec![(2, 100)]);
    }

    #[test]
    fn seq_cap_respected_with_branches() {
        let mut r1 = mk(1, 10, 5);
        r1.branches = 6;
        let mut r2 = mk(2, 10, 5);
        r2.branches = 6;
        let (mut s, pool, mut kv) = setup(BatchingKind::Continuous, vec![r1, r2]);
        s.cfg.max_batch_seqs = 8;
        s.plan(&pool, &mut kv).unwrap();
        // only one 6-branch request fits under the 8-seq cap
        assert_eq!(s.running_len(), 1);
    }

    #[test]
    fn prefill_only_role_ignores_decode() {
        let (mut s, mut pool, mut kv) = setup(BatchingKind::PrefillOnly, vec![mk(1, 100, 5)]);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(1, 100)]);
        apply(&p, &mut pool);
        assert!(s.plan(&pool, &mut kv).is_none(), "prefill done -> idle");
        // and its reservation was prefix-only
        assert_eq!(kv.used_tokens, 100.0);
    }

    #[test]
    fn decode_only_role_batches_arrivals() {
        let mut r1 = mk(1, 100, 3);
        r1.prefilled = 100; // arrives with prefill done (KV transferred in)
        let mut r2 = mk(2, 50, 3);
        r2.prefilled = 50;
        let (mut s, pool, mut kv) = setup(BatchingKind::DecodeOnly, vec![r1, r2]);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert!(p.prefill.is_empty());
        assert_eq!(p.decode.len(), 2);
    }

    #[test]
    fn plan_features_aggregate_correctly() {
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Chunked { chunk: 256 }, vec![mk(1, 100, 5), mk(2, 400, 5)]);
        apply(&s.plan(&pool, &mut kv).unwrap(), &mut pool); // (1,100),(2,156)
        let p = s.plan(&pool, &mut kv).unwrap();
        let f = p.features(&pool);
        assert_eq!(f.dec_batch, 1.0);
        assert!(f.pf_new > 0.0);
        assert_eq!(f.pf_items, 1.0);
        assert!((f.pf_past - 156.0).abs() < 1e-9);
    }

    #[test]
    fn remove_unadmitted_request_from_waiting() {
        let (mut s, pool, _kv) = setup(BatchingKind::Continuous, vec![mk(1, 10, 2)]);
        let _ = pool;
        assert!(s.remove(1).is_none(), "still waiting -> no KV to release");
        assert_eq!(s.queue_len(), 0);
        assert!(s.is_idle());
    }

    #[test]
    fn tombstoned_entry_does_not_resurrect_on_reenqueue() {
        // remove a waiting request, re-enqueue the same id, and make
        // sure exactly one live entry survives the compaction
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        assert!(s.remove(1).is_none());
        assert_eq!(s.queue_len(), 1);
        pool.insert(1, mk(1, 30, 2));
        s.enqueue(1);
        assert_eq!(s.queue_len(), 2);
        let p = s.plan(&pool, &mut kv).unwrap();
        // both admitted, each exactly once, with the *fresh* request 1
        assert_eq!(s.running_len(), 2);
        assert_eq!(s.queue_len(), 0);
        let mut planned = p.prefill.clone();
        planned.sort_unstable();
        assert_eq!(planned, vec![(1, 30), (2, 10)]);
    }

    #[test]
    fn double_removed_waiting_id_stays_removed() {
        // two stale copies of the same id can sit in the deque before a
        // compaction runs; both must be dropped (tombstone counts)
        let (mut s, mut pool, mut kv) =
            setup(BatchingKind::Continuous, vec![mk(1, 10, 2), mk(2, 10, 2)]);
        assert!(s.remove(1).is_none()); // deque [1s, 2]
        pool.insert(1, mk(1, 30, 2));
        s.enqueue(1); // deque [1s, 2, 1]
        assert!(s.remove(1).is_none()); // deque [1s, 2, 1s]
        assert_eq!(s.queue_len(), 1);
        let p = s.plan(&pool, &mut kv).unwrap();
        assert_eq!(p.prefill, vec![(2, 10)], "removed id must not be admitted");
        assert_eq!(s.running_len(), 1);
        assert_eq!(s.queue_len(), 0);
    }

    #[test]
    fn kind_maps_to_policy_names_and_roles() {
        for (kind, name) in [
            (BatchingKind::Static, "static"),
            (BatchingKind::Continuous, "continuous"),
            (BatchingKind::Chunked { chunk: 64 }, "chunked"),
            (BatchingKind::Mixed, "mixed"),
            (BatchingKind::PrefillOnly, "prefill-only"),
            (BatchingKind::DecodeOnly, "decode-only"),
        ] {
            let p = kind.policy();
            assert_eq!(p.name(), name);
            assert_eq!(p.name(), kind.name());
        }
        let s = LlmSched::new(BatchingKind::PrefillOnly, Packing::Fcfs, SchedConfig::default());
        assert!(s.serves_prefill() && !s.serves_decode());
    }
}
