//! Request packing policies (paper §III-D.1: "flexible request packing
//! policies such as First-Come-First-Serve and Least Work Left").

use super::RequestPool;
use crate::workload::request::ReqId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Packing {
    /// arrival order
    Fcfs,
    /// fewest remaining tokens first (SJF-like; reduces mean latency,
    /// can starve long requests)
    LeastWorkLeft,
}

impl Packing {
    /// Order a candidate id list in admission priority order.
    pub fn order(&self, ids: &mut Vec<ReqId>, pool: &RequestPool) {
        match self {
            Packing::Fcfs => {
                ids.sort_by_key(|id| (pool[id].arrival, *id));
            }
            Packing::LeastWorkLeft => {
                ids.sort_by(|a, b| {
                    let (wa, wb) = (pool[a].work_left_tokens(), pool[b].work_left_tokens());
                    wa.partial_cmp(&wb)
                        .unwrap()
                        .then_with(|| pool[a].arrival.cmp(&pool[b].arrival))
                        .then_with(|| a.cmp(b))
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::{Request, Stage};

    fn pool() -> RequestPool {
        let mut p = RequestPool::new();
        let mk = |id: u64, arr: f64, prompt: usize, out: usize| {
            Request::new(
                id,
                "llama3-70b",
                SimTime::from_secs(arr),
                vec![Stage::Prefill, Stage::Decode],
                prompt,
                out,
            )
        };
        p.insert(1, mk(1, 0.3, 100, 50)); // work 150
        p.insert(2, mk(2, 0.1, 5000, 10)); // work 5010
        p.insert(3, mk(3, 0.2, 50, 20)); // work 70
        p
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let p = pool();
        let mut ids = vec![1, 2, 3];
        Packing::Fcfs.order(&mut ids, &p);
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn least_work_left_orders_by_remaining_tokens() {
        let p = pool();
        let mut ids = vec![1, 2, 3];
        Packing::LeastWorkLeft.order(&mut ids, &p);
        assert_eq!(ids, vec![3, 1, 2]);
    }

    #[test]
    fn lwl_ties_broken_by_arrival_then_id() {
        let mut p = RequestPool::new();
        for id in [5u64, 4] {
            p.insert(
                id,
                Request::new(
                    id,
                    "llama3-70b",
                    SimTime::from_secs(1.0),
                    vec![Stage::Prefill, Stage::Decode],
                    100,
                    10,
                ),
            );
        }
        let mut ids = vec![5, 4];
        Packing::LeastWorkLeft.order(&mut ids, &p);
        assert_eq!(ids, vec![4, 5]);
    }
}
