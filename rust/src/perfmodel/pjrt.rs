//! PJRT-backed predictor: executes the AOT-compiled Pallas/JAX artifact on
//! the simulator hot path — the full three-layer composition. Candidate
//! step plans are packed into the executable's fixed `rows × 5` input
//! (padding rows are all-zero → both heads predict exactly 0).

use anyhow::Result;
use std::path::Path;
use std::rc::Rc;

use super::{PerfModel, StepFeatures, StepPrediction};
use crate::runtime::{ArtifactBundle, PredictorExe, Runtime};

pub struct PjrtPerfModel {
    /// shared across all clients of a build — PJRT client creation and
    /// HLO compilation happen once per variant, not once per client
    /// (EXPERIMENTS.md §Perf). `Rc` keeps this model `!Send`, which is
    /// correct: PJRT handles must not cross threads, so parallel sweeps
    /// (`sim::parallel`) construct the coordinator — and this model —
    /// inside the worker that runs it
    exe: Rc<PredictorExe>,
    name: String,
    /// reused input buffer (avoid per-call allocation on the hot path)
    buf: Vec<f32>,
    /// PJRT executions performed (perf accounting)
    pub calls: u64,
}

impl PjrtPerfModel {
    pub fn new(exe: Rc<PredictorExe>) -> PjrtPerfModel {
        let name = format!("pjrt:{}", exe.variant);
        let buf = vec![0.0; exe.rows * exe.n_raw];
        PjrtPerfModel {
            exe,
            name,
            buf,
            calls: 0,
        }
    }

    /// Convenience: open the bundle, spin up the CPU client and compile
    /// the variant in one call.
    pub fn load(artifacts_dir: &Path, key: &str) -> Result<PjrtPerfModel> {
        let rt = Runtime::cpu()?;
        let bundle = ArtifactBundle::open(artifacts_dir)?;
        Ok(PjrtPerfModel::new(Rc::new(bundle.load_predictor(&rt, key)?)))
    }

    pub fn rows(&self) -> usize {
        self.exe.rows
    }
}

impl PerfModel for PjrtPerfModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
        let rows = self.exe.rows;
        let n_raw = self.exe.n_raw;
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(rows) {
            self.buf.iter_mut().for_each(|v| *v = 0.0);
            for (i, f) in chunk.iter().enumerate() {
                self.buf[i * n_raw..(i + 1) * n_raw].copy_from_slice(&f.to_raw_f32());
            }
            let res = self
                .exe
                .run(&self.buf)
                .expect("PJRT predictor execution failed");
            self.calls += 1;
            for i in 0..chunk.len() {
                out.push(StepPrediction {
                    t_prefill: res[i * 3] as f64,
                    t_decode: res[i * 3 + 1] as f64,
                    t_step: res[i * 3 + 2] as f64,
                });
            }
        }
        out
    }
}

// End-to-end tests (require `make artifacts`) live in
// rust/tests/pjrt_parity.rs.
