//! Native evaluation of the fitted polynomial predictor — bit-for-bit the
//! same math as python/compile/kernels/ref.py, reading the coefficients
//! that `make artifacts` wrote to `artifacts/coefficients.json`.

use anyhow::{Context, Result};

use super::{PerfModel, StepFeatures, StepPrediction};
use crate::util::json::Json;

pub const N_FEATURES: usize = 6;

/// Fitted predictor for one (model, npu, tp) variant.
#[derive(Debug, Clone)]
pub struct PolyPerfModel {
    pub w_pf: [f64; N_FEATURES],
    pub w_dec: [f64; N_FEATURES],
    /// mixed-step cross terms (see python/compile/fit.py FitResult)
    pub c_dec_b: f64,
    pub c_dec_kv: f64,
    pub m_pf_tok: f64,
    pub scales: [f64; 5],
    name: String,
}

impl PolyPerfModel {
    pub fn new(
        w_pf: [f64; N_FEATURES],
        w_dec: [f64; N_FEATURES],
        mix: (f64, f64, f64),
        scales: [f64; 5],
        name: &str,
    ) -> PolyPerfModel {
        PolyPerfModel {
            w_pf,
            w_dec,
            c_dec_b: mix.0,
            c_dec_kv: mix.1,
            m_pf_tok: mix.2,
            scales,
            name: format!("poly:{name}"),
        }
    }

    /// Load one variant from the coefficients.json document.
    pub fn from_coefficients(coeffs: &Json, key: &str) -> Result<PolyPerfModel> {
        let c = coeffs
            .get(key)
            .with_context(|| format!("variant '{key}' not in coefficients.json"))?;
        let vecf = |field: &str| -> Result<Vec<f64>> {
            Ok(c.get(field)
                .and_then(Json::as_arr)
                .with_context(|| format!("missing '{field}'"))?
                .iter()
                .filter_map(Json::as_f64)
                .collect())
        };
        let to6 = |v: Vec<f64>| -> Result<[f64; N_FEATURES]> {
            v.try_into()
                .map_err(|v: Vec<f64>| anyhow::anyhow!("expected 6 coefficients, got {}", v.len()))
        };
        let scales_v = vecf("scales")?;
        let scales: [f64; 5] = scales_v
            .try_into()
            .map_err(|v: Vec<f64>| anyhow::anyhow!("expected 5 scales, got {}", v.len()))?;
        Ok(PolyPerfModel::new(
            to6(vecf("w_pf")?)?,
            to6(vecf("w_dec")?)?,
            (
                c.f64_or("c_dec_b", 0.0),
                c.f64_or("c_dec_kv", 0.0),
                c.f64_or("m_pf_tok", 0.0),
            ),
            scales,
            key,
        ))
    }

    #[inline]
    fn predict_one(&self, f: &StepFeatures) -> StepPrediction {
        // f32 throughout to mirror the Pallas kernel exactly.
        let s = &self.scales;
        let new = (f.pf_new / s[0]) as f32;
        let past = (f.pf_past / s[1]) as f32;
        let items = (f.pf_items / s[2]) as f32;
        let b = (f.dec_batch / s[3]) as f32;
        let kv = (f.dec_kv / s[4]) as f32;

        let phi_pf = [1.0f32, past, new, items, new * new, new * past];
        let phi_dec = [1.0f32, b, kv, b * kv, b * b, kv * kv];
        let dot = |phi: &[f32; N_FEATURES], w: &[f64; N_FEATURES]| -> f32 {
            phi.iter()
                .zip(w)
                .map(|(p, w)| p * (*w as f32))
                .sum::<f32>()
        };
        let mut t_pf = dot(&phi_pf, &self.w_pf).max(0.0);
        let mut t_dec = dot(&phi_dec, &self.w_dec).max(0.0);
        let has_pf = f.pf_new > 0.0;
        let has_dec = f.dec_batch > 0.0;
        if !has_pf {
            t_pf = 0.0;
        }
        if !has_dec {
            t_dec = 0.0;
        }
        let t_step = if has_pf && has_dec {
            // roofline-aware combination (mirrors kernels/ref.py)
            let compute_path = t_pf
                + (self.c_dec_b as f32) * (f.dec_batch as f32)
                + (self.c_dec_kv as f32) * (f.dec_kv as f32);
            let memory_path =
                t_dec + (self.m_pf_tok as f32) * ((f.pf_new + f.pf_past) as f32);
            compute_path.max(memory_path).max(t_pf.max(t_dec))
        } else {
            t_pf + t_dec
        };
        StepPrediction {
            t_prefill: t_pf as f64,
            t_decode: t_dec as f64,
            t_step: t_step as f64,
        }
    }
}

impl PerfModel for PolyPerfModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
        feats.iter().map(|f| self.predict_one(f)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> PolyPerfModel {
        PolyPerfModel::new(
            // t_pf = 0.01 + 0.05*new_scaled
            [0.01, 0.0, 0.05, 0.0, 0.0, 0.0],
            // t_dec = 0.002 + 0.01*kv_scaled
            [0.002, 0.0, 0.01, 0.0, 0.0, 0.0],
            // c_dec_b=1e-4/seq, c_dec_kv=0, m_pf_tok=1e-6/token
            (1e-4, 0.0, 1e-6),
            [4096.0, 4096.0, 8.0, 64.0, 262144.0],
            "toy",
        )
    }

    #[test]
    fn heads_gate_on_work_present() {
        let mut m = toy();
        let p = m.predict(StepFeatures::decode(8, 262144.0));
        assert_eq!(p.t_prefill, 0.0);
        assert!((p.t_decode - 0.012).abs() < 1e-6);
        assert!((p.t_step - p.t_decode).abs() < 1e-9);
    }

    #[test]
    fn mixed_takes_binding_roofline_path() {
        let mut m = toy();
        let p = m.predict(StepFeatures {
            pf_new: 4096.0,
            pf_past: 0.0,
            pf_items: 1.0,
            dec_batch: 8.0,
            dec_kv: 262144.0,
        });
        let expect_pf = 0.01 + 0.05; // 60ms compute-led
        let expect_dec = 0.012;
        assert!((p.t_prefill - expect_pf).abs() < 1e-6);
        assert!((p.t_decode - expect_dec).abs() < 1e-6);
        // compute path: t_pf + 8*1e-4 = 60.8ms; memory path:
        // t_dec + 4096*1e-6 = 16.1ms → compute-bound wins
        assert!((p.t_step - (expect_pf + 8.0 * 1e-4)).abs() < 1e-5, "{p:?}");
        // combined can never undercut its bigger half
        assert!(p.t_step >= p.t_prefill.max(p.t_decode));
    }

    #[test]
    fn negative_predictions_clamped() {
        let mut m = toy();
        m.w_dec = [-1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let p = m.predict(StepFeatures::decode(1, 100.0));
        assert_eq!(p.t_decode, 0.0);
    }

    #[test]
    fn parses_coefficients_json() {
        let doc = Json::parse(
            r#"{"m@h/tp8": {"w_pf": [1,2,3,4,5,6], "w_dec": [6,5,4,3,2,1],
                 "c_dec_b": 1e-4, "c_dec_kv": 1e-8, "m_pf_tok": 1e-6,
                 "scales": [4096, 4096, 8, 64, 262144]}}"#,
        )
        .unwrap();
        let m = PolyPerfModel::from_coefficients(&doc, "m@h/tp8").unwrap();
        assert_eq!(m.w_pf[5], 6.0);
        assert_eq!(m.w_dec[0], 6.0);
        assert_eq!(m.c_dec_b, 1e-4);
        assert_eq!(m.m_pf_tok, 1e-6);
        assert!(PolyPerfModel::from_coefficients(&doc, "missing").is_err());
    }
}
