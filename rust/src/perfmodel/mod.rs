//! ML-assisted LLM cluster runtime prediction (paper §III-E.1).
//!
//! Every engine step the scheduler prices candidate step plans through a
//! `PerfModel`. Three interchangeable backends:
//!
//! * [`poly::PolyPerfModel`] — native evaluation of the regression
//!   coefficients fitted by `python/compile/fit.py`
//!   (`artifacts/coefficients.json`).
//! * [`pjrt::PjrtPerfModel`] — executes the AOT-compiled Pallas/JAX
//!   predictor (`artifacts/*.hlo.txt`) via the PJRT CPU client: the
//!   three-layer hot path. Numerically identical to the native model
//!   modulo f32 rounding (asserted by `rust/tests/pjrt_parity.rs`).
//! * [`RooflinePerfModel`] — the GenZ-like analytical fallback for
//!   configurations without a fitted artifact (the paper's
//!   LLMCompass/GenZ role). 20–50× slower than the regression in the
//!   paper's telling; our microbench reproduces the gap vs memoized poly.
//!
//! [`memo::Memoized`] wraps any backend with a quantized-feature cache
//! (perf optimization; see EXPERIMENTS.md §Perf).

pub mod memo;
pub mod pjrt;
pub mod poly;

use crate::hardware::roofline::{LlmCluster, PrefillItem};

/// Raw step-plan features — the L1 kernel contract (see kernels/ref.py).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepFeatures {
    /// total new prefill tokens in the step
    pub pf_new: f64,
    /// total cached past tokens of the prefill items
    pub pf_past: f64,
    /// number of prefill items
    pub pf_items: f64,
    /// decode batch size (sequences)
    pub dec_batch: f64,
    /// total cached KV tokens across decode sequences
    pub dec_kv: f64,
}

impl StepFeatures {
    pub fn prefill(new: f64, past: f64, items: usize) -> StepFeatures {
        StepFeatures {
            pf_new: new,
            pf_past: past,
            pf_items: items as f64,
            ..Default::default()
        }
    }

    pub fn decode(batch: usize, kv: f64) -> StepFeatures {
        StepFeatures {
            dec_batch: batch as f64,
            dec_kv: kv,
            ..Default::default()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.pf_new <= 0.0 && self.dec_batch <= 0.0
    }

    pub fn to_raw_f32(&self) -> [f32; 5] {
        [
            self.pf_new as f32,
            self.pf_past as f32,
            self.pf_items as f32,
            self.dec_batch as f32,
            self.dec_kv as f32,
        ]
    }
}

/// Predicted step latencies (seconds).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepPrediction {
    pub t_prefill: f64,
    pub t_decode: f64,
    /// combined mixed-step time — what the scheduler uses
    pub t_step: f64,
}

/// A step-latency predictor for one (model, npu, tp) engine variant.
///
/// Deliberately NOT `Send`: the PJRT client wraps `Rc` internals. Parallel
/// sweeps spawn one simulation per thread and construct models inside the
/// worker thread.
pub trait PerfModel {
    fn name(&self) -> &str;

    /// Price a batch of candidate step plans.
    fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction>;

    fn predict(&mut self, f: StepFeatures) -> StepPrediction {
        self.predict_batch(std::slice::from_ref(&f))[0]
    }
}

/// Analytical roofline backend (fallback + data-generation ground truth).
pub struct RooflinePerfModel {
    pub cluster: LlmCluster,
    name: String,
}

impl RooflinePerfModel {
    pub fn new(cluster: LlmCluster) -> RooflinePerfModel {
        let name = format!(
            "roofline:{}@{}/tp{}",
            cluster.model.name, cluster.npu.name, cluster.tp
        );
        RooflinePerfModel { cluster, name }
    }

    fn predict_one(&self, f: &StepFeatures) -> StepPrediction {
        if f.is_empty() {
            return StepPrediction::default();
        }
        // Aggregate prefill features → evenly-spread items, matching the
        // python generator (hwspec.step_time).
        let items: Vec<PrefillItem> = if f.pf_new > 0.0 {
            let n = (f.pf_items.max(1.0)) as usize;
            vec![
                PrefillItem {
                    past: f.pf_past / n as f64,
                    new: f.pf_new / n as f64,
                };
                n
            ]
        } else {
            Vec::new()
        };
        let t_prefill = if items.is_empty() {
            0.0
        } else {
            self.cluster.prefill_time(&items)
        };
        let t_decode = if f.dec_batch > 0.0 {
            self.cluster.decode_time(f.dec_batch as usize, f.dec_kv)
        } else {
            0.0
        };
        let t_step = self
            .cluster
            .mixed_time(&items, f.dec_batch as usize, f.dec_kv);
        StepPrediction {
            t_prefill,
            t_decode,
            t_step,
        }
    }
}

impl PerfModel for RooflinePerfModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
        feats.iter().map(|f| self.predict_one(f)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::LLAMA3_70B;
    use crate::hardware::npu::H100;

    fn roofline() -> RooflinePerfModel {
        RooflinePerfModel::new(LlmCluster::new(LLAMA3_70B, H100, 8))
    }

    #[test]
    fn empty_features_are_free() {
        let mut m = roofline();
        let p = m.predict(StepFeatures::default());
        assert_eq!(p, StepPrediction::default());
    }

    #[test]
    fn decode_only_fills_decode_head() {
        let mut m = roofline();
        let p = m.predict(StepFeatures::decode(16, 16_000.0));
        assert_eq!(p.t_prefill, 0.0);
        assert!(p.t_decode > 0.0);
        assert!((p.t_step - p.t_decode).abs() < 1e-12);
    }

    #[test]
    fn mixed_step_between_halves_and_sum() {
        let mut m = roofline();
        let p = m.predict(StepFeatures {
            pf_new: 512.0,
            pf_past: 0.0,
            pf_items: 1.0,
            dec_batch: 16.0,
            dec_kv: 16_000.0,
        });
        assert!(p.t_step >= p.t_prefill.max(p.t_decode));
        assert!(p.t_step < p.t_prefill + p.t_decode);
    }

    #[test]
    fn batch_predict_matches_singles() {
        let mut m = roofline();
        let feats = [
            StepFeatures::decode(4, 4096.0),
            StepFeatures::prefill(1024.0, 0.0, 2),
        ];
        let batch = m.predict_batch(&feats);
        assert_eq!(batch[0], m.predict(feats[0]));
        assert_eq!(batch[1], m.predict(feats[1]));
    }
}
