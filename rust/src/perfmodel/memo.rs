//! Quantized-feature memoization for any `PerfModel`.
//!
//! Simulation step plans repeat heavily (decode batches grow one token at
//! a time), so caching predictions on a quantized feature key removes
//! most PJRT round-trips. Quantization granularity trades accuracy for
//! hit rate; defaults keep the latency error under ~1% while reaching
//! >90% hit rates in steady-state decode (EXPERIMENTS.md §Perf).

use std::collections::HashMap;

use super::{PerfModel, StepFeatures, StepPrediction};

/// Quantization scheme for the cache key.
#[derive(Debug, Clone, Copy)]
pub enum Quant {
    /// tokens rounded to multiples of `tok_q`, KV tokens to `kv_q`
    Absolute { tok_q: f64, kv_q: f64 },
    /// geometric bucketing: values land in the same cell when they are
    /// within `pct` relatively — bounded relative error AND high hit
    /// rates for steadily-growing features (decode KV grows ~B tokens
    /// per step, ≪1% of a large cache) — the perf-pass win recorded in
    /// EXPERIMENTS.md §Perf
    Relative { pct: f64 },
}

#[derive(Debug, Clone, Copy)]
pub struct MemoConfig {
    pub quant: Quant,
    pub max_entries: usize,
}

impl Default for MemoConfig {
    fn default() -> MemoConfig {
        MemoConfig {
            quant: Quant::Relative { pct: 0.01 },
            max_entries: 1 << 20,
        }
    }
}

impl Quant {
    #[inline]
    fn cell(self, v: f64, is_kv: bool) -> u64 {
        match self {
            Quant::Absolute { tok_q, kv_q } => {
                let g = if is_kv { kv_q } else { tok_q };
                (v / g).round() as u64
            }
            Quant::Relative { pct } => {
                // log-spaced buckets; exact for 0
                if v <= 0.0 {
                    0
                } else {
                    ((1.0 + v).ln() / (1.0 + pct).ln()).round() as u64
                }
            }
        }
    }

    #[inline]
    fn representative(self, cell: u64, is_kv: bool) -> f64 {
        match self {
            Quant::Absolute { tok_q, kv_q } => {
                cell as f64 * if is_kv { kv_q } else { tok_q }
            }
            Quant::Relative { pct } => {
                if cell == 0 {
                    0.0
                } else {
                    ((1.0 + pct).ln() * cell as f64).exp() - 1.0
                }
            }
        }
    }
}

pub struct Memoized<M: PerfModel> {
    pub inner: M,
    cfg: MemoConfig,
    cache: HashMap<[u64; 5], StepPrediction>,
    pub hits: u64,
    pub misses: u64,
    name: String,
}

impl<M: PerfModel> Memoized<M> {
    pub fn new(inner: M) -> Memoized<M> {
        Memoized::with_config(inner, MemoConfig::default())
    }

    pub fn with_config(inner: M, cfg: MemoConfig) -> Memoized<M> {
        let name = format!("memo({})", inner.name());
        Memoized {
            inner,
            cfg,
            cache: HashMap::new(),
            hits: 0,
            misses: 0,
            name,
        }
    }

    fn key(&self, f: &StepFeatures) -> [u64; 5] {
        let q = self.cfg.quant;
        [
            q.cell(f.pf_new, false),
            q.cell(f.pf_past, false),
            f.pf_items as u64,
            f.dec_batch as u64,
            q.cell(f.dec_kv, true),
        ]
    }

    /// Quantized features — what actually gets priced on a miss, so the
    /// cached value is exact *for the key* (no aliasing drift).
    fn quantized(&self, key: &[u64; 5]) -> StepFeatures {
        let q = self.cfg.quant;
        StepFeatures {
            pf_new: q.representative(key[0], false),
            pf_past: q.representative(key[1], false),
            pf_items: key[2] as f64,
            dec_batch: key[3] as f64,
            dec_kv: q.representative(key[4], true),
        }
    }

    /// Probe helper: identical to predict_batch (naming avoids trait
    /// ambiguity in diagnostics code).
    pub fn inner_calls_probe(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
        self.predict_batch(feats)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl<M: PerfModel> PerfModel for Memoized<M> {
    fn name(&self) -> &str {
        &self.name
    }

    fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
        // Collect misses, price them in ONE inner batch (one PJRT call),
        // then assemble results in order.
        let keys: Vec<[u64; 5]> = feats.iter().map(|f| self.key(f)).collect();
        let mut miss_keys: Vec<[u64; 5]> = Vec::new();
        for k in &keys {
            if !self.cache.contains_key(k) && !miss_keys.contains(k) {
                miss_keys.push(*k);
            }
        }
        if !miss_keys.is_empty() {
            let miss_feats: Vec<StepFeatures> =
                miss_keys.iter().map(|k| self.quantized(k)).collect();
            let preds = self.inner.predict_batch(&miss_feats);
            if self.cache.len() + miss_keys.len() > self.cfg.max_entries {
                self.cache.clear(); // simple wholesale eviction
            }
            for (k, p) in miss_keys.iter().zip(preds) {
                self.cache.insert(*k, p);
            }
        }
        keys.iter()
            .map(|k| {
                let p = self.cache[k];
                // a fresh miss counts once; repeats in the same batch are hits
                if miss_keys.contains(k) {
                    self.misses += 1;
                    miss_keys.retain(|m| m != k);
                } else {
                    self.hits += 1;
                }
                p
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Counts inner calls so tests can assert cache behavior.
    struct Counting {
        calls: usize,
    }

    impl PerfModel for Counting {
        fn name(&self) -> &str {
            "counting"
        }
        fn predict_batch(&mut self, feats: &[StepFeatures]) -> Vec<StepPrediction> {
            self.calls += 1;
            feats
                .iter()
                .map(|f| StepPrediction {
                    t_prefill: f.pf_new,
                    t_decode: f.dec_batch,
                    t_step: f.pf_new + f.dec_batch,
                })
                .collect()
        }
    }

    #[test]
    fn repeat_queries_hit_cache() {
        let mut m = Memoized::new(Counting { calls: 0 });
        let f = StepFeatures::decode(8, 4096.0);
        let a = m.predict(f);
        let b = m.predict(f);
        assert_eq!(a, b);
        assert_eq!(m.inner.calls, 1);
        assert_eq!(m.hits, 1);
        assert_eq!(m.misses, 1);
        assert_eq!(m.hit_rate(), 0.5);
    }

    #[test]
    fn nearby_features_share_a_cell() {
        let mut m = Memoized::new(Counting { calls: 0 });
        m.predict(StepFeatures::decode(8, 4096.0));
        m.predict(StepFeatures::decode(8, 4100.0)); // same kv cell (q=512)
        assert_eq!(m.inner.calls, 1);
    }

    #[test]
    fn different_batch_sizes_do_not_alias() {
        let mut m = Memoized::new(Counting { calls: 0 });
        let a = m.predict(StepFeatures::decode(8, 4096.0));
        let b = m.predict(StepFeatures::decode(9, 4096.0));
        assert_ne!(a.t_decode, b.t_decode);
        assert_eq!(m.inner.calls, 2);
    }

    #[test]
    fn batch_misses_priced_in_one_inner_call() {
        let mut m = Memoized::new(Counting { calls: 0 });
        let feats: Vec<StepFeatures> =
            (1..=10).map(|b| StepFeatures::decode(b, 1000.0 * b as f64)).collect();
        m.predict_batch(&feats);
        assert_eq!(m.inner.calls, 1);
        assert_eq!(m.misses, 10);
        m.predict_batch(&feats);
        assert_eq!(m.inner.calls, 1);
        assert_eq!(m.hits, 10);
    }

    #[test]
    fn duplicate_rows_in_one_batch_priced_once() {
        let mut m = Memoized::new(Counting { calls: 0 });
        let f = StepFeatures::decode(4, 2048.0);
        let out = m.predict_batch(&[f, f, f]);
        assert_eq!(out.len(), 3);
        assert_eq!(m.misses, 1);
        assert_eq!(m.hits, 2);
    }

    #[test]
    fn cached_value_is_for_quantized_features() {
        // absolute grid: pf_new=17 quantizes to 16 — prediction reflects 16
        let mut m = Memoized::with_config(
            Counting { calls: 0 },
            MemoConfig {
                quant: Quant::Absolute { tok_q: 16.0, kv_q: 512.0 },
                max_entries: 1 << 20,
            },
        );
        let p = m.predict(StepFeatures::prefill(17.0, 0.0, 1));
        assert_eq!(p.t_prefill, 16.0);
    }

    #[test]
    fn relative_quantization_bounds_error_and_boosts_hits() {
        let mut m = Memoized::new(Counting { calls: 0 }); // 1% relative
        // representative stays within 1% of the query
        let p = m.predict(StepFeatures::decode(8, 100_000.0));
        // t_step = pf + batch for Counting; check kv via quantized repr
        let key_cell = Quant::Relative { pct: 0.01 }.cell(100_000.0, true);
        let repr = Quant::Relative { pct: 0.01 }.representative(key_cell, true);
        assert!((repr - 100_000.0).abs() / 100_000.0 < 0.01, "repr={repr}");
        let _ = p;
        // growing decode KV by one batch-worth stays in the same cell
        m.predict(StepFeatures::decode(8, 100_008.0));
        assert_eq!(m.inner.calls, 1, "steady decode growth must hit");
        // a 5% jump lands in a new cell
        m.predict(StepFeatures::decode(8, 105_100.0));
        assert_eq!(m.inner.calls, 2);
    }

    #[test]
    fn relative_zero_is_exact() {
        let q = Quant::Relative { pct: 0.01 };
        assert_eq!(q.cell(0.0, true), 0);
        assert_eq!(q.representative(0, true), 0.0);
    }
}
