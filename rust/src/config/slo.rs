//! Service-level objectives (paper §V-A, Table II).
//!
//! Baselines: TTFT 250 ms (1000 ms for RAG / memory-retrieval pipelines),
//! TPOT 25 ms. Acceptable slowdowns: TTFT 2×/3×/6× and TPOT
//! 1.25×/1.5×/5× at P50/P90/P99. "All six SLOs must be satisfied."

use crate::util::stats::Summary;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloLadder {
    /// baseline TTFT, seconds
    pub ttft_base: f64,
    /// baseline TPOT, seconds
    pub tpot_base: f64,
    pub ttft_mult: [f64; 3],
    pub tpot_mult: [f64; 3],
}

impl SloLadder {
    /// Table II for regular prefill-decode pipelines.
    pub fn standard() -> SloLadder {
        SloLadder {
            ttft_base: 0.250,
            tpot_base: 0.025,
            ttft_mult: [2.0, 3.0, 6.0],
            tpot_mult: [1.25, 1.5, 5.0],
        }
    }

    /// Table II for RAG / memory-retrieval pipelines (1000 ms TTFT base).
    pub fn retrieval() -> SloLadder {
        SloLadder {
            ttft_base: 1.000,
            ..SloLadder::standard()
        }
    }

    pub fn ttft_limits(&self) -> [f64; 3] {
        [
            self.ttft_base * self.ttft_mult[0],
            self.ttft_base * self.ttft_mult[1],
            self.ttft_base * self.ttft_mult[2],
        ]
    }

    pub fn tpot_limits(&self) -> [f64; 3] {
        [
            self.tpot_base * self.tpot_mult[0],
            self.tpot_base * self.tpot_mult[1],
            self.tpot_base * self.tpot_mult[2],
        ]
    }

    /// All-six check over run distributions.
    pub fn satisfied(&self, ttft: &Summary, tpot: &Summary) -> bool {
        let tl = self.ttft_limits();
        let pl = self.tpot_limits();
        ttft.p50 <= tl[0]
            && ttft.p90 <= tl[1]
            && ttft.p99 <= tl[2]
            && tpot.p50 <= pl[0]
            && tpot.p90 <= pl[1]
            && tpot.p99 <= pl[2]
    }

    /// Which of the six constraints fail (reporting).
    pub fn violations(&self, ttft: &Summary, tpot: &Summary) -> Vec<&'static str> {
        let tl = self.ttft_limits();
        let pl = self.tpot_limits();
        let mut v = Vec::new();
        if ttft.p50 > tl[0] {
            v.push("ttft-p50");
        }
        if ttft.p90 > tl[1] {
            v.push("ttft-p90");
        }
        if ttft.p99 > tl[2] {
            v.push("ttft-p99");
        }
        if tpot.p50 > pl[0] {
            v.push("tpot-p50");
        }
        if tpot.p90 > pl[1] {
            v.push("tpot-p90");
        }
        if tpot.p99 > pl[2] {
            v.push("tpot-p99");
        }
        v
    }

    /// Per-request check (goodput counting, Figs 8 & 13). A request
    /// that decoded ≤1 token has no TPOT and cannot violate the TPOT
    /// objective, so a missing sample passes explicitly.
    pub fn request_ok(&self, ttft: f64, tpot: Option<f64>) -> bool {
        ttft <= self.ttft_limits()[0] && tpot.map_or(true, |tp| tp <= self.tpot_limits()[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum(p50: f64, p90: f64, p99: f64) -> Summary {
        Summary {
            n: 100,
            mean: p50,
            p50,
            p90,
            p99,
            min: 0.0,
            max: p99,
        }
    }

    #[test]
    fn table2_limits() {
        let s = SloLadder::standard();
        for (got, want) in s.ttft_limits().iter().zip([0.5, 0.75, 1.5]) {
            assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        }
        for (got, want) in s.tpot_limits().iter().zip([0.03125, 0.0375, 0.125]) {
            assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        }
        let r = SloLadder::retrieval();
        for (got, want) in r.ttft_limits().iter().zip([2.0, 3.0, 6.0]) {
            assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        }
    }

    #[test]
    fn all_six_must_hold() {
        let s = SloLadder::standard();
        let good_ttft = sum(0.3, 0.5, 1.0);
        let good_tpot = sum(0.02, 0.03, 0.05);
        assert!(s.satisfied(&good_ttft, &good_tpot));
        // one violation (ttft p99) is enough to fail
        let bad_ttft = sum(0.3, 0.5, 2.0);
        assert!(!s.satisfied(&bad_ttft, &good_tpot));
        assert_eq!(s.violations(&bad_ttft, &good_tpot), vec!["ttft-p99"]);
    }

    #[test]
    fn per_request_check() {
        let s = SloLadder::standard();
        assert!(s.request_ok(0.4, Some(0.03)));
        assert!(!s.request_ok(0.6, Some(0.03)));
        assert!(!s.request_ok(0.4, Some(0.04)));
        // 1-token outputs have no TPOT — they cannot violate it
        assert!(s.request_ok(0.4, None));
        assert!(!s.request_ok(0.6, None));
    }
}
