//! Configuration system (paper §III-A "declarative front-end"): JSON
//! documents → typed simulation specs.
//!
//! The parsing helpers ([`parse_pool`], [`parse_serving`],
//! [`parse_workload`], [`parse_router`], [`parse_storage`],
//! [`parse_granularity`], [`parse_migration`], [`parse_faults`],
//! [`parse_slo`]) are
//! public because the scenario
//! registry ([`crate::scenario`]) builds on the same schema: a scenario
//! file is a config document plus a batching roster, a rate sweep and
//! scale knobs (see `docs/scenarios.md`).
//!
//! Example (see `scenarios/` for full scenario files):
//! ```json
//! {
//!   "model": "llama3-70b", "npu": "h100", "tp": 2,
//!   "pool": { "batching": "disaggregated", "prefill": 20, "decode": 12 },
//!   "scheduler": { "max_batch_seqs": 256, "max_batch_tokens": 8192,
//!                  "packing": "fcfs" },
//!   "router": "load:tokens-left",
//!   "perf_model": "pjrt-memo",
//!   "network": { "per_platform": 4, "per_rack": 16 },
//!   "workload": { "trace": "azure-conv", "n": 2000, "rate": 2.0,
//!                 "arrival": "poisson", "pipeline": "regular" },
//!   "slo": "standard",
//!   "seed": 0
//! }
//! ```

pub mod slo;

use anyhow::{bail, Context, Result};

use crate::coordinator::{LoadMetric, RoutePolicy};
use crate::hardware::models::{self, ModelSpec};
use crate::memory::hierarchy::tier_by_name;
use crate::memory::storage::{KvScenario, StorageConfig};
use crate::model::ModelId;
use crate::model::policy::ModelPolicy;
use crate::network::Granularity;
use crate::scheduler::{BatchingKind, Packing, SchedConfig};
use crate::sim::builder::{
    npu_by_name, KvRetrievalSpec, MigrationSpec, NetSpec, PerfBackend, PoolSpec, PrePostSpec,
    RagSpec, ServingSpec,
};
use crate::util::json::Json;
use crate::util::rng::Arrival;
use crate::workload::request::{KvParams, RagParams};
use crate::workload::trace::{Pipeline, Reasoning, TraceKind, WorkloadSpec};
use slo::SloLadder;

/// A fully parsed simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub serving: ServingSpec,
    pub workload: WorkloadSpec,
    pub slo: SloLadder,
}

impl SimConfig {
    pub fn from_file(path: &str) -> Result<SimConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = Json::parse(&text).with_context(|| format!("parsing config {path}"))?;
        SimConfig::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<SimConfig> {
        let pool = parse_pool(doc.get("pool").context("config needs 'pool'")?)?;
        let serving = parse_serving(doc, pool)?;

        let workload = parse_workload(
            ModelId::lookup(serving.model)?,
            doc.get("workload").context("config needs 'workload'")?,
            serving.seed,
        )?;

        let slo = parse_slo(doc.str_or("slo", "auto"), &workload.pipeline)?;

        Ok(SimConfig {
            serving,
            workload,
            slo,
        })
    }
}

/// Parse everything about the serving system except the workload: model,
/// hardware, scheduler, router, perf backend, network, auxiliary
/// clients, granularity and seed. The LLM `pool` is passed in because
/// scenario files derive it from a batching roster rather than a single
/// `pool` object.
pub fn parse_serving(doc: &Json, pool: PoolSpec) -> Result<ServingSpec> {
    // register catalog models first so 'model'/'models'/'model_policy'
    // can reference them
    if let Some(cat) = doc.get("model_catalog") {
        parse_model_catalog(cat)?;
    }

    // co-resident model list: 'models' hosts every entry on every LLM
    // client; the primary is 'model' when present, else models[0]
    let mut co_models = Vec::new();
    if let Some(ms) = doc.get("models") {
        let arr = ms
            .as_arr()
            .context("'models' must be an array of model names")?;
        for (i, v) in arr.iter().enumerate() {
            let name = v
                .as_str()
                .with_context(|| format!("'models[{i}]' must be a string"))?;
            let id = ModelId::lookup(name)?;
            if !co_models.contains(&id) {
                co_models.push(id);
            }
        }
        if co_models.is_empty() {
            bail!("'models' must not be empty");
        }
    }
    let model_name = match doc.get("model").and_then(Json::as_str) {
        Some(m) => m.to_string(),
        None => match co_models.first() {
            Some(id) => id.name().to_string(),
            None => "llama3-70b".to_string(),
        },
    };
    let model_spec = models::lookup(&model_name)?;
    let model: &'static str = model_spec.name;
    let npu = npu_by_name(doc.str_or("npu", "h100"))?;
    let tp = doc.usize_or("tp", 8);

    let llm_clients = pool.n_clients();
    let mut serving = ServingSpec::new(model, npu, tp, pool);
    serving.co_models = co_models;

    if let Some(p) = doc.get("model_policy") {
        let s = p.as_str().context("'model_policy' must be a string")?;
        let policy = ModelPolicy::parse(s)?;
        // dangling reference check: every policy model must be hosted
        let primary = ModelId::lookup(serving.model)?;
        for m in policy.models() {
            if m != primary && !serving.co_models.contains(&m) {
                bail!(
                    "model_policy references '{m}' but the clients host only \
                     [{}] (add it to 'models')",
                    std::iter::once(primary)
                        .chain(serving.co_models.iter().copied())
                        .map(|m| m.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        serving.model_policy = Some(policy);
    }

    if let Some(s) = doc.get("scheduler") {
        serving.sched = SchedConfig {
            max_batch_seqs: s.usize_or("max_batch_seqs", 256),
            max_batch_tokens: s.usize_or("max_batch_tokens", 8192),
        };
        serving.packing = parse_packing(s.str_or("packing", "fcfs"))?;
    }

    serving.route = parse_router(doc.str_or("router", "load:tokens-left"))?;
    serving.perf = parse_perf_backend(doc.str_or("perf_model", "poly"))?;

    if let Some(n) = doc.get("network") {
        serving.net = NetSpec::Hierarchy {
            per_platform: n.usize_or("per_platform", 4),
            per_rack: n.usize_or("per_rack", 16),
        };
    }

    if let Some(g) = doc.get("granularity").and_then(Json::as_str) {
        serving.granularity = parse_granularity(g)?;
    }

    if let Some(r) = doc.get("rag_clients") {
        serving.rag = Some(RagSpec {
            count: aux_count(r, llm_clients),
            embed_model: models::model(r.str_or("embed_model", "e5-base"))
                .context("unknown embed model")?,
            embed_npu: npu_by_name(r.str_or("embed_npu", "grace-cpu"))?,
            retrieval_npu: npu_by_name(r.str_or("retrieval_npu", "grace-cpu"))?,
            ivf: Default::default(),
            max_batch: r.usize_or("max_batch", 0),
        });
    }

    if let Some(k) = doc.get("kv_clients") {
        serving.kv_retrieval = Some(KvRetrievalSpec {
            count: aux_count(k, llm_clients),
            storage: parse_storage(k.str_or("storage", "platform"))?,
            scenario: match k.str_or("scenario", "private") {
                "private" => KvScenario::Private,
                "shared" => KvScenario::Shared,
                other => bail!("unknown scenario '{other}'"),
            },
            max_batch: k.usize_or("max_batch", 0),
            ports: k.usize_or("ports", 1),
        });
    }

    if let Some(p) = doc.get("prepost_clients") {
        serving.prepost = Some(PrePostSpec {
            count: aux_count(p, llm_clients),
            cores: p.usize_or("cores", 16),
            guard_npu: p
                .get("guard_npu")
                .and_then(Json::as_str)
                .map(npu_by_name)
                .transpose()?,
        });
    }

    if let Some(m) = doc.get("migration") {
        serving.migration = Some(parse_migration(m)?);
    }
    if let Some(w) = doc.get("transfer_weight").and_then(Json::as_f64) {
        if !(0.0..=1.0).contains(&w) {
            bail!("'transfer_weight' must be in [0, 1], got {w}");
        }
        serving.transfer_weight = w;
    }

    serving.seed = doc.f64_or("seed", 0.0) as u64;
    if let Some(f) = doc.get("faults") {
        serving.faults = Some(parse_faults(f, serving.seed)?);
    }
    Ok(serving)
}

/// Parse a `faults` object (docs/robustness.md): a deterministic fault
/// schedule plus the retry policy applied to it.
///
/// ```json
/// "faults": {
///   "seed": 7,
///   "crashes":   [{"client": 0, "at": 30.0, "down_for": 10.0}],
///   "slowdowns": [{"client": 1, "factor": 2.0, "at": 5.0, "for": 20.0}],
///   "links":     [{"rack": 0, "at": 12.0, "for": 3.0, "degrade": 2.0}],
///   "stage_failure_prob": 0.01,
///   "retry": {"max_attempts": 3, "base": 0.05, "factor": 2.0, "jitter": 0.5},
///   "shed": false
/// }
/// ```
///
/// `seed` defaults to the serving seed. A link entry without `degrade`
/// is a hard outage. Structural problems (missing/mis-typed targets or
/// times) are parse errors here; value-range problems (probabilities
/// outside [0, 1], non-positive durations, out-of-range client/rack
/// indices) are rejected by
/// [`FaultPlan::compile`](crate::fault::FaultPlan::compile) at build
/// time — `hermes scenario check` runs both, so a bad fault spec never
/// survives to a simulation.
pub fn parse_faults(j: &Json, default_seed: u64) -> Result<crate::fault::FaultSpec> {
    use crate::fault::{CrashSpec, FaultSpec, LinkFaultSpec, SlowdownSpec};
    let mut spec = FaultSpec::new(j.f64_or("seed", default_seed as f64) as u64);
    if let Some(arr) = j.get("crashes") {
        let arr = arr.as_arr().context("'faults.crashes' must be an array")?;
        for (i, c) in arr.iter().enumerate() {
            let num = |k: &str| {
                c.get(k)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("faults.crashes[{i}] needs numeric '{k}'"))
            };
            spec.crashes.push(CrashSpec {
                client: c
                    .get("client")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("faults.crashes[{i}] needs a 'client' index"))?,
                at: num("at")?,
                down_for: num("down_for")?,
            });
        }
    }
    if let Some(arr) = j.get("slowdowns") {
        let arr = arr.as_arr().context("'faults.slowdowns' must be an array")?;
        for (i, s) in arr.iter().enumerate() {
            let num = |k: &str| {
                s.get(k)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("faults.slowdowns[{i}] needs numeric '{k}'"))
            };
            spec.slowdowns.push(SlowdownSpec {
                client: s
                    .get("client")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("faults.slowdowns[{i}] needs a 'client' index"))?,
                factor: num("factor")?,
                at: num("at")?,
                dur: num("for")?,
            });
        }
    }
    if let Some(arr) = j.get("links") {
        let arr = arr.as_arr().context("'faults.links' must be an array")?;
        for (i, l) in arr.iter().enumerate() {
            let num = |k: &str| {
                l.get(k)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("faults.links[{i}] needs numeric '{k}'"))
            };
            spec.links.push(LinkFaultSpec {
                rack: l
                    .get("rack")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("faults.links[{i}] needs a 'rack' index"))?,
                at: num("at")?,
                dur: num("for")?,
                degrade: l.get("degrade").and_then(Json::as_f64),
            });
        }
    }
    spec.stage_failure_prob = j.f64_or("stage_failure_prob", 0.0);
    if let Some(r) = j.get("retry") {
        spec.retry.max_attempts =
            r.usize_or("max_attempts", spec.retry.max_attempts as usize) as u32;
        spec.retry.base = r.f64_or("base", spec.retry.base);
        spec.retry.factor = r.f64_or("factor", spec.retry.factor);
        spec.retry.jitter = r.f64_or("jitter", spec.retry.jitter);
    }
    spec.shed = j.bool_or("shed", false);
    Ok(spec)
}

/// Parse a `migration` object: how a disaggregated pipeline prices the
/// prefill→decode KV hand-off (see `docs/disaggregation.md`).
/// `granularity` (`full` / `layerwise:<n>`) overrides the network-wide
/// hand-off granularity for migration hops only; `pool` names a tiered
/// staging hierarchy (`hbm` / `cxl` / `dram` / `nvme`, fastest first)
/// whose expected access latency is added to every migration. Unknown
/// tier names are parse errors, so dangling pool references surface in
/// `hermes scenario check` rather than at run time.
pub fn parse_migration(j: &Json) -> Result<MigrationSpec> {
    let mut spec = MigrationSpec::default();
    if let Some(g) = j.get("granularity").and_then(Json::as_str) {
        spec.granularity = Some(parse_granularity(g)?);
    }
    if let Some(pool) = j.get("pool") {
        let arr = pool
            .as_arr()
            .context("'migration.pool' must be an array of tier names")?;
        for (i, v) in arr.iter().enumerate() {
            let name = v
                .as_str()
                .with_context(|| format!("'migration.pool[{i}]' must be a string"))?;
            let tier = tier_by_name(name).with_context(|| {
                format!("unknown migration pool tier '{name}' (expected hbm/cxl/dram/nvme)")
            })?;
            spec.pool.push(tier);
        }
    }
    Ok(spec)
}

/// Auxiliary-client count: either a fixed `count` or `per_llm: N`
/// (one auxiliary client per N LLM clients, at least one) so scenario
/// files scale their RAG/KV tiers with the swept pool size.
fn aux_count(block: &Json, llm_clients: usize) -> usize {
    match block.get("per_llm").and_then(Json::as_usize) {
        Some(per) => (llm_clients / per.max(1)).max(1),
        None => block.usize_or("count", 1),
    }
}

/// Parse a `pool` object: `{"batching": "...", ...}`. Accepted forms:
/// `static` / `continuous` / `mixed` / `chunked` (+`chunk`) with `n`
/// clients, `per-client` with a `kinds` array, and
/// `disaggregated[-local|-global]` with `prefill`/`decode` counts.
pub fn parse_pool(j: &Json) -> Result<PoolSpec> {
    let batching = j.str_or("batching", "continuous");
    Ok(match batching {
        "static" => PoolSpec::Combined {
            kind: BatchingKind::Static,
            n: j.usize_or("n", 1),
        },
        "continuous" => PoolSpec::Combined {
            kind: BatchingKind::Continuous,
            n: j.usize_or("n", 1),
        },
        "chunked" => PoolSpec::Combined {
            kind: BatchingKind::Chunked {
                chunk: j.usize_or("chunk", 512),
            },
            n: j.usize_or("n", 1),
        },
        "mixed" => PoolSpec::Combined {
            kind: BatchingKind::Mixed,
            n: j.usize_or("n", 1),
        },
        "per-client" => {
            let kinds = j
                .get("kinds")
                .and_then(Json::as_arr)
                .context("per-client pool needs a 'kinds' array")?
                .iter()
                .map(|k| {
                    k.as_str()
                        .context("per-client 'kinds' entries must be strings")
                        .and_then(parse_batching_kind)
                })
                .collect::<Result<Vec<BatchingKind>>>()?;
            PoolSpec::PerClient { kinds }
        }
        "disaggregated" | "disaggregated-global" => PoolSpec::Disaggregated {
            prefill: j.usize_or("prefill", 1),
            decode: j.usize_or("decode", 1),
            local: false,
        },
        "disaggregated-local" => PoolSpec::Disaggregated {
            prefill: j.usize_or("prefill", 1),
            decode: j.usize_or("decode", 1),
            local: true,
        },
        other => bail!("unknown batching '{other}'"),
    })
}

/// Register every architecture in a `model_catalog` array with the
/// interning registry, so scenario files can serve models beyond the
/// hardcoded roster. Entries: `{"name", "params", "layers", "hidden",
/// "heads", ["kv_heads"], ["d_head"], ["bytes_per_param"], ["decoder"]}`.
/// Registration is idempotent (re-parsing a scenario is free); renaming
/// an existing model's parameters is an error.
pub fn parse_model_catalog(j: &Json) -> Result<()> {
    let arr = j.as_arr().context("'model_catalog' must be an array")?;
    for (i, e) in arr.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("model_catalog[{i}] needs a 'name'"))?;
        let req_f64 = |key: &str| -> Result<f64> {
            e.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("model_catalog[{i}] ('{name}') needs numeric '{key}'"))
        };
        let heads = req_f64("heads")? as usize;
        let hidden = req_f64("hidden")? as usize;
        if heads == 0 || hidden == 0 {
            bail!("model_catalog[{i}] ('{name}'): heads/hidden must be positive");
        }
        // leak the name only for genuinely new registrations: re-parses
        // of an already-registered model reuse its interned name (the
        // registry hands out &'static specs, so names must be 'static)
        let interned = ModelId::resolve(name).map(|id| id.spec().name);
        let spec = ModelSpec {
            name: interned.unwrap_or_else(|| Box::leak(name.to_string().into_boxed_str())),
            params: req_f64("params")?,
            layers: req_f64("layers")? as usize,
            hidden,
            heads,
            kv_heads: e.usize_or("kv_heads", heads),
            d_head: e.usize_or("d_head", hidden / heads),
            bytes_per_param: e.f64_or("bytes_per_param", 1.0),
            decoder: e.bool_or("decoder", true),
        };
        ModelId::register(spec).with_context(|| format!("model_catalog[{i}]"))?;
    }
    Ok(())
}

/// Parse a combined-client batching kind from its string form:
/// `static`, `continuous`, `mixed`, `chunked` or `chunked:<budget>`,
/// `prefill-only`, `decode-only`.
pub fn parse_batching_kind(s: &str) -> Result<BatchingKind> {
    Ok(match s {
        "static" => BatchingKind::Static,
        "continuous" => BatchingKind::Continuous,
        "mixed" => BatchingKind::Mixed,
        "chunked" => BatchingKind::Chunked { chunk: 512 },
        "prefill-only" => BatchingKind::PrefillOnly,
        "decode-only" => BatchingKind::DecodeOnly,
        s if s.starts_with("chunked:") => {
            let chunk: usize = s[8..]
                .parse()
                .with_context(|| format!("bad chunk in '{s}'"))?;
            if chunk == 0 {
                bail!("chunk budget must be positive in '{s}'");
            }
            BatchingKind::Chunked { chunk }
        }
        other => bail!("unknown batching kind '{other}'"),
    })
}

/// Parse a perf-backend name (`roofline` / `poly` / `pjrt` / `pjrt-memo`).
pub fn parse_perf_backend(s: &str) -> Result<PerfBackend> {
    Ok(match s {
        "roofline" => PerfBackend::Roofline,
        "poly" => PerfBackend::Poly,
        "pjrt" => PerfBackend::Pjrt,
        "pjrt-memo" => PerfBackend::PjrtMemo,
        other => bail!("unknown perf_model '{other}'"),
    })
}

/// Parse a packing policy name (`fcfs` / `least-work-left`).
pub fn parse_packing(s: &str) -> Result<Packing> {
    Ok(match s {
        "fcfs" => Packing::Fcfs,
        "least-work-left" | "lwl" => Packing::LeastWorkLeft,
        other => bail!("unknown packing '{other}'"),
    })
}

/// Parse a router policy string (`round-robin`, `load:<metric>`,
/// `heavy-light:<metric>`).
pub fn parse_router(s: &str) -> Result<RoutePolicy> {
    let metric = |m: &str| -> Result<LoadMetric> {
        Ok(match m {
            "input-len" => LoadMetric::InputLen,
            "output-len" => LoadMetric::OutputLen,
            "kv-size" => LoadMetric::KvSize,
            "tokens-left" => LoadMetric::TokensLeft,
            other => bail!("unknown load metric '{other}'"),
        })
    };
    Ok(match s {
        "round-robin" | "rr" => RoutePolicy::RoundRobin,
        s if s.starts_with("load:") => RoutePolicy::LoadBased(metric(&s[5..])?),
        s if s.starts_with("heavy-light:") => RoutePolicy::HeavyLight {
            metric: metric(&s[12..])?,
            threshold_tokens: 2048,
            heavy_frac: 0.5,
        },
        other => bail!("unknown router '{other}'"),
    })
}

/// Parse a KV-storage tier name (Fig 14 design points).
pub fn parse_storage(s: &str) -> Result<StorageConfig> {
    Ok(match s {
        "dedicated" | "a" => StorageConfig::DedicatedPerClient,
        "platform" | "b" => StorageConfig::PlatformShared,
        "rack" | "c" => StorageConfig::RackShared,
        "rack-dcn" | "c-dcn" => StorageConfig::RackSharedWithDcn,
        "recompute" => StorageConfig::Recompute,
        other => bail!("unknown storage '{other}'"),
    })
}

/// Parse a KV hand-off granularity: `full` or `layerwise:<layers>`.
pub fn parse_granularity(s: &str) -> Result<Granularity> {
    Ok(match s {
        "full" => Granularity::Full,
        s if s.starts_with("layerwise:") => {
            let layers: usize = s[10..]
                .parse()
                .with_context(|| format!("bad layer count in '{s}'"))?;
            if layers == 0 {
                bail!("layer count must be positive in '{s}'");
            }
            Granularity::Layerwise { layers }
        }
        other => bail!("unknown granularity '{other}'"),
    })
}

/// Resolve an SLO ladder name; `auto` picks the retrieval ladder when
/// the pipeline has RAG/KV stages (Table II).
pub fn parse_slo(name: &str, pipeline: &Pipeline) -> Result<SloLadder> {
    Ok(match name {
        "standard" => SloLadder::standard(),
        "retrieval" => SloLadder::retrieval(),
        "auto" => match pipeline {
            Pipeline::Rag(_) | Pipeline::KvRetrieval(_) => SloLadder::retrieval(),
            _ => SloLadder::standard(),
        },
        other => bail!("unknown slo '{other}'"),
    })
}

/// Parse one workload class: trace family, arrival process, pipeline
/// shape and reasoning mode.
pub fn parse_workload(model: ModelId, j: &Json, seed: u64) -> Result<WorkloadSpec> {
    let trace = match j.str_or("trace", "azure-conv") {
        "azure-conv" => TraceKind::AzureConv,
        "azure-code" => TraceKind::AzureCode,
        "synthetic" => TraceKind::Synthetic {
            in_mean: j.f64_or("in_mean", 1024.0),
            in_std: j.f64_or("in_std", 256.0),
            out_mean: j.f64_or("out_mean", 256.0),
            out_std: j.f64_or("out_std", 64.0),
        },
        other => bail!("unknown trace '{other}'"),
    };
    let n = j.usize_or("n", 500);
    let rate = j.f64_or("rate", 2.0);
    let arrival = match j.str_or("arrival", "poisson") {
        "poisson" => Arrival::Poisson { rate },
        "uniform" => Arrival::Uniform { rate },
        "normal" => Arrival::Normal {
            rate,
            cv: j.f64_or("arrival_cv", 0.3),
        },
        "bursty" => Arrival::Bursty {
            rate,
            burst_mult: j.f64_or("burst_mult", 4.0),
            calm_s: j.f64_or("calm_s", 20.0),
            burst_s: j.f64_or("burst_s", 5.0),
        },
        other => bail!("unknown arrival '{other}'"),
    };
    let pipeline = match j.str_or("pipeline", "regular") {
        "regular" => Pipeline::Regular,
        "guarded" => Pipeline::Guarded,
        "rag" => Pipeline::Rag(RagParams {
            query_tokens: j.usize_or("query_tokens", 128),
            docs: j.usize_or("docs", 20),
            doc_tokens: j.usize_or("doc_tokens", 512),
            ..Default::default()
        }),
        "kv-retrieval" => Pipeline::KvRetrieval(KvParams {
            cached_tokens: j.usize_or("cached_tokens", 3000),
        }),
        "routed" => Pipeline::Routed,
        "cascade" => Pipeline::Cascade,
        "disagg" => Pipeline::Disagg,
        other => bail!("unknown pipeline '{other}'"),
    };
    let reasoning = match j.str_or("reasoning", "none") {
        "none" => Reasoning::None,
        "single-path" => Reasoning::SinglePath {
            scale: j.f64_or("reasoning_scale", 16.0),
        },
        "multi-path" => Reasoning::MultiPath {
            scale: j.f64_or("reasoning_scale", 8.0),
            branches: j.usize_or("branches", 8),
        },
        other => bail!("unknown reasoning '{other}'"),
    };
    let deadline = match j.get("deadline").and_then(Json::as_f64) {
        Some(d) => {
            if !d.is_finite() || d <= 0.0 {
                bail!("'workload.deadline' must be finite and positive, got {d}");
            }
            Some(d)
        }
        None => None,
    };
    Ok(WorkloadSpec {
        model,
        trace,
        pipeline,
        reasoning,
        arrival,
        n_requests: n,
        seed,
        deadline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"{
        "model": "llama3-70b", "npu": "h100", "tp": 2,
        "pool": { "batching": "disaggregated", "prefill": 3, "decode": 2 },
        "scheduler": { "max_batch_seqs": 128, "max_batch_tokens": 4096,
                       "packing": "least-work-left" },
        "router": "heavy-light:input-len",
        "perf_model": "roofline",
        "network": { "per_platform": 2, "per_rack": 5 },
        "kv_clients": { "count": 1, "storage": "rack", "scenario": "shared" },
        "workload": { "trace": "azure-code", "n": 100, "rate": 1.5,
                      "arrival": "bursty", "pipeline": "kv-retrieval",
                      "cached_tokens": 4096,
                      "reasoning": "multi-path", "branches": 4 },
        "seed": 7
    }"#;

    #[test]
    fn full_config_parses() {
        let cfg = SimConfig::from_json(&Json::parse(FULL).unwrap()).unwrap();
        assert_eq!(cfg.serving.model, "llama3-70b");
        assert_eq!(cfg.serving.tp, 2);
        assert_eq!(
            cfg.serving.pool,
            PoolSpec::Disaggregated { prefill: 3, decode: 2, local: false }
        );
        assert_eq!(cfg.serving.sched.max_batch_seqs, 128);
        assert_eq!(cfg.serving.packing, Packing::LeastWorkLeft);
        assert!(matches!(cfg.serving.route, RoutePolicy::HeavyLight { .. }));
        assert!(cfg.serving.kv_retrieval.is_some());
        assert_eq!(cfg.workload.n_requests, 100);
        assert!(matches!(cfg.workload.reasoning, Reasoning::MultiPath { branches: 4, .. }));
        // auto SLO: retrieval pipeline → 1000ms TTFT base
        assert_eq!(cfg.slo.ttft_base, 1.0);
        assert_eq!(cfg.serving.seed, 7);
    }

    #[test]
    fn minimal_config_defaults() {
        let cfg = SimConfig::from_json(
            &Json::parse(r#"{"pool": {"batching": "chunked", "n": 4, "chunk": 256},
                             "workload": {"n": 10}}"#)
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            cfg.serving.pool,
            PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 256 }, n: 4 }
        );
        assert_eq!(cfg.slo.ttft_base, 0.25);
    }

    #[test]
    fn per_client_pool_parses() {
        let cfg = SimConfig::from_json(
            &Json::parse(
                r#"{"pool": {"batching": "per-client",
                             "kinds": ["continuous", "chunked:256", "static"]},
                    "workload": {"n": 10}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            cfg.serving.pool,
            PoolSpec::PerClient {
                kinds: vec![
                    BatchingKind::Continuous,
                    BatchingKind::Chunked { chunk: 256 },
                    BatchingKind::Static,
                ]
            }
        );
        assert_eq!(cfg.serving.pool.n_clients(), 3);
    }

    #[test]
    fn aux_clients_scale_per_llm() {
        let cfg = SimConfig::from_json(
            &Json::parse(
                r#"{"pool": {"batching": "continuous", "n": 16},
                    "rag_clients": {"per_llm": 8},
                    "workload": {"n": 10, "pipeline": "rag"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.serving.rag.as_ref().unwrap().count, 2);
    }

    #[test]
    fn granularity_parses() {
        assert_eq!(parse_granularity("full").unwrap(), Granularity::Full);
        assert_eq!(
            parse_granularity("layerwise:70").unwrap(),
            Granularity::Layerwise { layers: 70 }
        );
        assert!(parse_granularity("halfwise").is_err());
        assert!(parse_granularity("layerwise:0").is_err());
    }

    #[test]
    fn disagg_migration_keys_parse_and_validate() {
        let cfg = SimConfig::from_json(
            &Json::parse(
                r#"{"pool": {"batching": "disaggregated", "prefill": 2, "decode": 2},
                    "migration": {"granularity": "layerwise:40",
                                  "pool": ["hbm", "dram", "nvme"]},
                    "transfer_weight": 0.5,
                    "workload": {"n": 10, "pipeline": "disagg"}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.workload.pipeline, Pipeline::Disagg);
        let m = cfg.serving.migration.as_ref().unwrap();
        assert_eq!(m.granularity, Some(Granularity::Layerwise { layers: 40 }));
        assert_eq!(m.pool.len(), 3);
        assert_eq!(cfg.serving.transfer_weight, 0.5);

        // a dangling tier name is a parse error, not a run-time surprise
        let err = parse_migration(&Json::parse(r#"{"pool": ["hbm", "tape"]}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown migration pool tier 'tape'"), "{err}");
        assert!(parse_migration(&Json::parse(r#"{"pool": "hbm"}"#).unwrap()).is_err());

        // transfer_weight outside the blend range is rejected
        let bad = r#"{"pool": {"batching": "continuous", "n": 1},
                      "transfer_weight": 1.5, "workload": {"n": 5}}"#;
        assert!(SimConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn fault_keys_parse_and_validate() {
        let cfg = SimConfig::from_json(
            &Json::parse(
                r#"{"pool": {"batching": "continuous", "n": 2},
                    "workload": {"n": 10, "deadline": 2.5},
                    "seed": 11,
                    "faults": {"crashes": [{"client": 0, "at": 1.0, "down_for": 4.0}],
                               "slowdowns": [{"client": 1, "factor": 2.0,
                                              "at": 0.5, "for": 3.0}],
                               "links": [{"rack": 0, "at": 2.0, "for": 1.0,
                                          "degrade": 3.0}],
                               "stage_failure_prob": 0.02,
                               "retry": {"max_attempts": 5, "base": 0.1},
                               "shed": true}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.workload.deadline, Some(2.5));
        let f = cfg.serving.faults.as_ref().unwrap();
        assert_eq!(f.seed, 11, "fault seed defaults to the serving seed");
        assert_eq!(f.crashes.len(), 1);
        assert_eq!(f.slowdowns[0].factor, 2.0);
        assert_eq!(f.links[0].degrade, Some(3.0));
        assert_eq!(f.stage_failure_prob, 0.02);
        assert_eq!(f.retry.max_attempts, 5);
        assert_eq!(f.retry.base, 0.1);
        assert_eq!(f.retry.factor, 2.0, "unset retry keys keep defaults");
        assert!(f.shed);

        // a crash entry without a target is a parse error
        let err = parse_faults(&Json::parse(r#"{"crashes": [{"at": 1.0}]}"#).unwrap(), 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("faults.crashes[0]"), "{err}");

        // value-range problems surface at build time via FaultPlan::compile
        let bad = r#"{"pool": {"batching": "continuous", "n": 2},
                      "workload": {"n": 10},
                      "faults": {"stage_failure_prob": 1.5}}"#;
        let cfg = SimConfig::from_json(&Json::parse(bad).unwrap()).unwrap();
        assert!(cfg.serving.build().is_err(), "prob > 1 must not survive build");

        // a non-positive workload deadline is rejected outright
        let bad = r#"{"pool": {"batching": "continuous", "n": 1},
                      "workload": {"n": 5, "deadline": 0.0}}"#;
        assert!(SimConfig::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn batching_kind_strings() {
        assert_eq!(parse_batching_kind("continuous").unwrap(), BatchingKind::Continuous);
        assert_eq!(
            parse_batching_kind("chunked:1024").unwrap(),
            BatchingKind::Chunked { chunk: 1024 }
        );
        assert_eq!(parse_batching_kind("chunked").unwrap(), BatchingKind::Chunked { chunk: 512 });
        assert!(parse_batching_kind("quantum").is_err());
        assert!(parse_batching_kind("chunked:0").is_err(), "zero budget can never plan");
    }

    #[test]
    fn bad_values_error_clearly() {
        for (field, bad) in [
            ("batching", r#"{"pool": {"batching": "quantum"}, "workload": {}}"#),
            ("router", r#"{"pool": {"batching": "mixed"}, "router": "psychic", "workload": {}}"#),
            ("model", r#"{"model": "gpt-9", "pool": {"batching": "mixed"}, "workload": {}}"#),
        ] {
            assert!(
                SimConfig::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{field} should fail"
            );
        }
    }

    #[test]
    fn multi_model_keys_parse_and_validate() {
        let doc = Json::parse(
            r#"{"model": "llama3-70b", "models": ["llama3-70b", "llama3-8b"],
                "model_policy": "cascade:llama3-8b->llama3-70b:0.2",
                "pool": {"batching": "continuous", "n": 2},
                "workload": {"n": 10, "pipeline": "cascade"}}"#,
        )
        .unwrap();
        let cfg = SimConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.serving.model, "llama3-70b");
        assert!(cfg.serving.co_models.contains(&ModelId::named("llama3-8b")));
        assert!(matches!(
            cfg.serving.model_policy,
            Some(ModelPolicy::Cascade { .. })
        ));
        assert_eq!(
            cfg.workload.pipeline,
            crate::workload::trace::Pipeline::Cascade
        );

        // 'models' without 'model': the first entry is the primary
        let doc = Json::parse(
            r#"{"models": ["llama3-8b", "llama3-70b"],
                "pool": {"batching": "continuous", "n": 1},
                "workload": {"n": 5, "pipeline": "routed"}}"#,
        )
        .unwrap();
        let cfg = SimConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.serving.model, "llama3.1-8b", "canonical primary");

        // a policy naming an un-hosted model is a dangling reference
        let doc = Json::parse(
            r#"{"model": "llama3-70b",
                "model_policy": "cascade:llama3-8b->llama3-70b:0.2",
                "pool": {"batching": "continuous", "n": 1},
                "workload": {"n": 5}}"#,
        )
        .unwrap();
        let err = SimConfig::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("model_policy references"), "{err}");
    }

    #[test]
    fn model_catalog_registers_and_serves() {
        let doc = Json::parse(
            r#"{"model_catalog": [
                    {"name": "cfgtest-30b", "params": 30e9, "layers": 48,
                     "hidden": 6144, "heads": 48, "kv_heads": 8}
                ],
                "model": "cfgtest-30b",
                "pool": {"batching": "continuous", "n": 1},
                "perf_model": "roofline",
                "workload": {"trace": "azure-conv", "n": 6, "rate": 2.0}}"#,
        )
        .unwrap();
        let cfg = SimConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.serving.model, "cfgtest-30b");
        let spec = ModelId::named("cfgtest-30b").spec();
        assert_eq!(spec.layers, 48);
        assert_eq!(spec.kv_heads, 8);
        assert_eq!(spec.d_head, 6144 / 48, "defaulted from hidden/heads");
        // the registered model actually serves traffic
        let mut coord = cfg.serving.build().unwrap();
        coord.inject(cfg.workload.generate(0));
        coord.run();
        assert!(coord.all_serviced());
        // malformed entries fail fast
        for bad in [
            r#"[{"params": 1e9, "layers": 2, "hidden": 64, "heads": 4}]"#,
            r#"[{"name": "x-1b", "layers": 2, "hidden": 64, "heads": 4}]"#,
        ] {
            assert!(parse_model_catalog(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn built_config_runs() {
        let cfg = SimConfig::from_json(
            &Json::parse(
                r#"{"tp": 8, "pool": {"batching": "continuous", "n": 1},
                    "perf_model": "roofline",
                    "workload": {"trace": "azure-conv", "n": 8, "rate": 2.0}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let mut coord = cfg.serving.build().unwrap();
        coord.inject(cfg.workload.generate(0));
        coord.run();
        assert!(coord.all_serviced());
    }
}
