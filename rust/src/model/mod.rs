//! Model identity: an interning registry mapping model names (and their
//! aliases) to dense [`ModelId`]s, plus the dynamic model-routing
//! policies ([`policy`]).
//!
//! The serving layers thread `ModelId` — a `Copy` integer — through
//! requests, clients and the router, so the "can this client serve this
//! request's model?" check on the routing hot path is an integer
//! compare instead of a string compare, and the model catalog is
//! extensible at runtime: scenario files can register new architectures
//! through `model_catalog` (see [`crate::config`]) without touching the
//! hardcoded roster in [`crate::hardware::models`].
//!
//! The registry is process-global and append-only: built-in specs (and
//! the alias table that used to live in `hardware::model`'s match
//! statement) are seeded on first use; `register` interns additional
//! specs. Interning is thread-safe (`OnceLock` + `RwLock`, read-locked
//! on the hot `spec()` path) so parallel sweep workers
//! ([`crate::sim::parallel`]) can resolve and register models
//! concurrently — `rust/tests/registry_concurrency.rs` pins the
//! guarantees. Identity is by *canonical name* — two `ModelId`s are equal iff
//! they name the same registered model — so ids are stable within a
//! process but their numeric values are an implementation detail;
//! nothing may depend on their ordering.

pub mod policy;

use std::collections::HashMap;
use std::fmt;
use std::sync::{OnceLock, RwLock};

use anyhow::{bail, Result};

use crate::hardware::models::{BUILTIN_MODELS, ModelSpec};

/// Interned model identity: a dense index into the process-global model
/// registry. `Copy` + integer equality — the routing hot path compares
/// these, never names.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(u32);

struct Registry {
    /// leaked so [`ModelId::spec`] can hand out `&'static` references
    specs: Vec<&'static ModelSpec>,
    /// normalized name / alias → index into `specs`
    by_name: HashMap<String, u32>,
}

/// Case-insensitive, `.`/`_` → `-` (the normalization `hardware::model`
/// has always applied).
fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace(['.', '_'], "-")
}

// `RwLock`, not `Mutex`: `spec()` sits on the routing/transfer hot path
// (`Coordinator::transfer_bytes` resolves KV bytes-per-token through it)
// and parallel sweeps (`sim::parallel`) read it from every worker, while
// writes only happen when a new name is interned — read-mostly by
// construction. Interning is append-only, so a reader between two
// writes always sees a consistent prefix.
fn registry() -> &'static RwLock<Registry> {
    static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut reg = Registry {
            specs: Vec::new(),
            by_name: HashMap::new(),
        };
        for (spec, aliases) in BUILTIN_MODELS {
            let id = reg.specs.len() as u32;
            reg.specs.push(*spec);
            reg.by_name.insert(normalize(spec.name), id);
            for alias in *aliases {
                reg.by_name.insert(normalize(alias), id);
            }
        }
        RwLock::new(reg)
    })
}

impl ModelId {
    /// Look up a name or alias; `None` if unregistered.
    pub fn resolve(name: &str) -> Option<ModelId> {
        registry()
            .read()
            .unwrap()
            .by_name
            .get(&normalize(name))
            .map(|&i| ModelId(i))
    }

    /// Look up a name; the error lists every known model name so config
    /// typos are self-explanatory.
    pub fn lookup(name: &str) -> Result<ModelId> {
        match ModelId::resolve(name) {
            Some(id) => Ok(id),
            None => bail!(
                "unknown model '{name}' (known models: {})",
                known_models().join(", ")
            ),
        }
    }

    /// Infallible lookup for names that are known by construction
    /// (panics otherwise — tests and internal defaults).
    pub fn named(name: &str) -> ModelId {
        ModelId::lookup(name).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Id for a spec in hand: resolves by canonical name, registering
    /// the spec when the name is new. Name-based identity — a spec whose
    /// name is already registered resolves to the existing entry.
    pub fn of_spec(spec: &ModelSpec) -> ModelId {
        // take the write lock up front: re-checking under it makes the
        // read-then-insert race-free when threads intern the same name
        let mut reg = registry().write().unwrap();
        let key = normalize(spec.name);
        if let Some(&i) = reg.by_name.get(&key) {
            return ModelId(i);
        }
        let id = reg.specs.len() as u32;
        reg.specs.push(Box::leak(Box::new(spec.clone())));
        reg.by_name.insert(key, id);
        ModelId(id)
    }

    /// Register a new architecture (scenario `model_catalog` entries).
    /// Idempotent for an identical re-registration; redefining a known
    /// name with different parameters is an error.
    pub fn register(spec: ModelSpec) -> Result<ModelId> {
        let mut reg = registry().write().unwrap();
        let key = normalize(spec.name);
        if let Some(&i) = reg.by_name.get(&key) {
            if *reg.specs[i as usize] == spec {
                return Ok(ModelId(i));
            }
            bail!(
                "model catalog redefines '{}' with different parameters",
                spec.name
            );
        }
        let id = reg.specs.len() as u32;
        reg.specs.push(Box::leak(Box::new(spec)));
        reg.by_name.insert(key, id);
        Ok(ModelId(id))
    }

    /// The interned architecture spec. O(1) index into the registry.
    pub fn spec(self) -> &'static ModelSpec {
        registry().read().unwrap().specs[self.0 as usize]
    }

    /// Canonical model name.
    pub fn name(self) -> &'static str {
        self.spec().name
    }

    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Is this one of the models shipped in
    /// [`BUILTIN_MODELS`](crate::hardware::models::BUILTIN_MODELS)
    /// (as opposed to a runtime `model_catalog` registration)? Builtins
    /// are seeded first, so their ids occupy the low range.
    pub fn is_builtin(self) -> bool {
        (self.0 as usize) < BUILTIN_MODELS.len()
    }
}

impl From<&str> for ModelId {
    fn from(name: &str) -> ModelId {
        ModelId::named(name)
    }
}

impl fmt::Debug for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ModelId({})", self.name())
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Sorted canonical names of every registered model (error messages,
/// `hermes scenario check` reporting).
pub fn known_models() -> Vec<&'static str> {
    let reg = registry().read().unwrap();
    let mut names: Vec<&'static str> = reg.specs.iter().map(|s| s.name).collect();
    names.sort_unstable();
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::{LLAMA3_70B, LLAMA3_8B};

    #[test]
    fn interning_is_alias_stable() {
        let a = ModelId::named("llama3-70b");
        let b = ModelId::named("Llama-3.1-70B");
        assert_eq!(a, b, "aliases intern to one id");
        assert_eq!(a.name(), "llama3-70b");
        assert_eq!(a.spec(), &LLAMA3_70B);
        assert_ne!(a, ModelId::named("llama3-8b"));
        assert_eq!(ModelId::named("llama3-8b").spec(), &LLAMA3_8B);
    }

    #[test]
    fn lookup_error_lists_known_models() {
        let err = ModelId::lookup("gpt-99t").unwrap_err().to_string();
        assert!(err.contains("unknown model 'gpt-99t'"), "{err}");
        assert!(err.contains("llama3-70b"), "{err}");
        assert!(err.contains("bloom-176b"), "{err}");
    }

    #[test]
    fn register_custom_spec_is_idempotent() {
        let spec = ModelSpec {
            name: "test-custom-13b",
            params: 13e9,
            layers: 40,
            hidden: 5120,
            heads: 40,
            kv_heads: 40,
            d_head: 128,
            bytes_per_param: 1.0,
            decoder: true,
        };
        let a = ModelId::register(spec.clone()).unwrap();
        let b = ModelId::register(spec.clone()).unwrap();
        assert_eq!(a, b);
        assert_eq!(ModelId::resolve("Test_Custom.13B"), Some(a));
        assert!(known_models().contains(&"test-custom-13b"));
        // conflicting redefinition is rejected
        let conflict = ModelSpec { params: 14e9, ..spec };
        assert!(ModelId::register(conflict).is_err());
    }

    #[test]
    fn of_spec_resolves_by_name() {
        assert_eq!(ModelId::of_spec(&LLAMA3_70B), ModelId::named("llama3-70b"));
    }
}
