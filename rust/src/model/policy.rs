//! Dynamic model-selection policies behind the `Stage::ModelRoute`
//! pipeline stage.
//!
//! MIST models model routing as a first-class pipeline stage: before a
//! request reaches prefill, a policy decides *which* model serves it —
//! and a cascade may revisit that decision after the small model's
//! answer. The coordinator resolves `ModelRoute` stages inline (they
//! cost zero simulated time and never occupy a client); the policy's
//! decision is a pure, deterministic function of the request, the
//! route ordinal and the run seed, so runs stay reproducible.
//!
//! Three built-in policies:
//!
//! * [`ModelPolicy::Static`] — a fixed traffic mix: each request is
//!   assigned a model by deterministic weighted draw (per-request PCG
//!   stream keyed on the request id).
//! * [`ModelPolicy::Threshold`] — length-based: prompts at or above the
//!   threshold go to the large model, the rest to the small one (an
//!   SLO-tiering proxy: long prompts get the quality model).
//! * [`ModelPolicy::Cascade`] — small-model-first with an escalation
//!   fraction: every request runs the small model; at the second
//!   `ModelRoute` stage a fraction `escalate` re-runs prefill+decode on
//!   the large model (the "answer was not good enough" path), the rest
//!   finish with the small model's answer.

use anyhow::{bail, Context, Result};

use super::ModelId;
use crate::util::rng::Pcg;
use crate::workload::request::Request;

/// A model-selection policy, applied at every `Stage::ModelRoute` of a
/// request's pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelPolicy {
    /// fixed weighted mix across models (weights need not sum to 1)
    Static { choices: Vec<(ModelId, f64)> },
    /// prompts `>= threshold_tokens` → `large`, else `small`
    Threshold {
        threshold_tokens: usize,
        small: ModelId,
        large: ModelId,
    },
    /// small-model-first; an `escalate` fraction re-runs on `large`
    Cascade {
        small: ModelId,
        large: ModelId,
        escalate: f64,
    },
}

/// Outcome of one `ModelRoute` resolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDecision {
    /// serve the following stages with this model (at a later route
    /// ordinal, a *different* model means escalation: prefill/decode
    /// progress is reset and re-run)
    Assign(ModelId),
    /// the pipeline ends here (cascade declined to escalate)
    Finish,
}

/// Per-request decision stream: independent of event order, so routing
/// decisions are identical across load modes, pool backends and sweeps.
fn route_rng(seed: u64, req: u64) -> Pcg {
    Pcg::new(seed ^ req.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x4D52_4F55_5445)
}

impl ModelPolicy {
    /// Decide the `ordinal`-th `ModelRoute` stage (0-based) of `r`.
    pub fn decide(&self, r: &Request, ordinal: usize, seed: u64) -> RouteDecision {
        match self {
            ModelPolicy::Static { choices } => {
                if ordinal > 0 {
                    // a static mix never escalates; trailing route
                    // stages (cascade-shaped pipelines) just finish
                    return RouteDecision::Finish;
                }
                let total: f64 = choices.iter().map(|(_, w)| w).sum();
                let mut x = route_rng(seed, r.id).f64() * total;
                for (m, w) in choices {
                    x -= w;
                    if x <= 0.0 {
                        return RouteDecision::Assign(*m);
                    }
                }
                RouteDecision::Assign(choices.last().expect("static policy has choices").0)
            }
            ModelPolicy::Threshold {
                threshold_tokens,
                small,
                large,
            } => {
                if ordinal > 0 {
                    return RouteDecision::Finish;
                }
                RouteDecision::Assign(if r.prompt_tokens >= *threshold_tokens {
                    *large
                } else {
                    *small
                })
            }
            ModelPolicy::Cascade {
                small,
                large,
                escalate,
            } => match ordinal {
                0 => RouteDecision::Assign(*small),
                1 => {
                    if route_rng(seed, r.id).chance(*escalate) {
                        RouteDecision::Assign(*large)
                    } else {
                        RouteDecision::Finish
                    }
                }
                _ => RouteDecision::Finish,
            },
        }
    }

    /// Every model this policy can assign (deduped) — used to validate
    /// that the client pool actually hosts them.
    pub fn models(&self) -> Vec<ModelId> {
        let all: Vec<ModelId> = match self {
            ModelPolicy::Static { choices } => choices.iter().map(|(m, _)| *m).collect(),
            ModelPolicy::Threshold { small, large, .. }
            | ModelPolicy::Cascade { small, large, .. } => vec![*small, *large],
        };
        let mut seen = Vec::with_capacity(all.len());
        for m in all {
            if !seen.contains(&m) {
                seen.push(m);
            }
        }
        seen
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelPolicy::Static { .. } => "static",
            ModelPolicy::Threshold { .. } => "threshold",
            ModelPolicy::Cascade { .. } => "cascade",
        }
    }

    /// Parse the config-string grammar:
    ///
    /// * `static:<model>[=<weight>][,<model>[=<weight>]...]`
    /// * `threshold:<tokens>:<small-model>:<large-model>`
    /// * `cascade:<small-model>-><large-model>:<escalation-fraction>`
    pub fn parse(s: &str) -> Result<ModelPolicy> {
        if let Some(rest) = s.strip_prefix("static:") {
            let mut choices = Vec::new();
            for part in rest.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let (name, weight) = match part.split_once('=') {
                    Some((n, w)) => (
                        n.trim(),
                        w.trim()
                            .parse::<f64>()
                            .with_context(|| format!("bad model weight in '{part}'"))?,
                    ),
                    None => (part, 1.0),
                };
                if !(weight > 0.0) {
                    bail!("model weight must be positive in '{part}'");
                }
                choices.push((ModelId::lookup(name)?, weight));
            }
            if choices.is_empty() {
                bail!("static model policy needs at least one model: '{s}'");
            }
            Ok(ModelPolicy::Static { choices })
        } else if let Some(rest) = s.strip_prefix("threshold:") {
            let mut it = rest.splitn(3, ':');
            let (t, small, large) = (it.next(), it.next(), it.next());
            let (Some(t), Some(small), Some(large)) = (t, small, large) else {
                bail!("threshold policy is 'threshold:<tokens>:<small>:<large>', got '{s}'");
            };
            let threshold_tokens: usize = t
                .parse()
                .with_context(|| format!("bad token threshold in '{s}'"))?;
            Ok(ModelPolicy::Threshold {
                threshold_tokens,
                small: ModelId::lookup(small.trim())?,
                large: ModelId::lookup(large.trim())?,
            })
        } else if let Some(rest) = s.strip_prefix("cascade:") {
            let (pair, frac) = rest.rsplit_once(':').with_context(|| {
                format!("cascade policy is 'cascade:<small>-><large>:<fraction>', got '{s}'")
            })?;
            let (small, large) = pair
                .split_once("->")
                .with_context(|| format!("cascade models are '<small>-><large>' in '{s}'"))?;
            let escalate: f64 = frac
                .trim()
                .parse()
                .with_context(|| format!("bad escalation fraction in '{s}'"))?;
            if !(0.0..=1.0).contains(&escalate) {
                bail!("escalation fraction must be in [0, 1], got {escalate}");
            }
            let small = ModelId::lookup(small.trim())?;
            let large = ModelId::lookup(large.trim())?;
            if small == large {
                bail!("cascade needs two distinct models, got '{s}'");
            }
            Ok(ModelPolicy::Cascade {
                small,
                large,
                escalate,
            })
        } else {
            bail!("unknown model policy '{s}' (static:…, threshold:…, cascade:…)")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::workload::request::Stage;

    fn req(id: u64, prompt: usize) -> Request {
        Request::new(
            id,
            "llama3-70b",
            SimTime::ZERO,
            vec![Stage::ModelRoute, Stage::Prefill, Stage::Decode],
            prompt,
            10,
        )
    }

    #[test]
    fn parse_grammar_round_trips() {
        let p = ModelPolicy::parse("static:llama3-8b=0.7,llama3-70b=0.3").unwrap();
        assert_eq!(p.name(), "static");
        assert_eq!(p.models().len(), 2);
        let p = ModelPolicy::parse("threshold:2048:llama3-8b:llama3-70b").unwrap();
        assert_eq!(
            p,
            ModelPolicy::Threshold {
                threshold_tokens: 2048,
                small: ModelId::named("llama3-8b"),
                large: ModelId::named("llama3-70b"),
            }
        );
        let p = ModelPolicy::parse("cascade:llama3-8b->llama3-70b:0.25").unwrap();
        assert_eq!(p.name(), "cascade");
        for bad in [
            "psychic:foo",
            "static:",
            "static:gpt-99t",
            "threshold:abc:llama3-8b:llama3-70b",
            "threshold:100:llama3-8b",
            "cascade:llama3-8b->llama3-8b:0.2",
            "cascade:llama3-8b->llama3-70b:1.5",
        ] {
            assert!(ModelPolicy::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn static_mix_is_deterministic_and_weighted() {
        let p = ModelPolicy::parse("static:llama3-8b=0.75,llama3-70b=0.25").unwrap();
        let small = ModelId::named("llama3-8b");
        let n = 4000;
        let mut small_n = 0;
        for id in 0..n {
            let d = p.decide(&req(id, 100), 0, 7);
            assert_eq!(d, p.decide(&req(id, 100), 0, 7), "deterministic");
            if d == RouteDecision::Assign(small) {
                small_n += 1;
            }
        }
        let frac = small_n as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.05, "small fraction {frac}");
        // trailing route stages finish
        assert_eq!(p.decide(&req(1, 100), 1, 7), RouteDecision::Finish);
    }

    #[test]
    fn threshold_splits_by_prompt_length() {
        let p = ModelPolicy::parse("threshold:1000:llama3-8b:llama3-70b").unwrap();
        assert_eq!(
            p.decide(&req(1, 999), 0, 0),
            RouteDecision::Assign(ModelId::named("llama3-8b"))
        );
        assert_eq!(
            p.decide(&req(1, 1000), 0, 0),
            RouteDecision::Assign(ModelId::named("llama3-70b"))
        );
    }

    #[test]
    fn cascade_escalates_a_fraction() {
        let p = ModelPolicy::parse("cascade:llama3-8b->llama3-70b:0.3").unwrap();
        let small = ModelId::named("llama3-8b");
        let large = ModelId::named("llama3-70b");
        let n = 4000;
        let mut escalated = 0;
        for id in 0..n {
            assert_eq!(p.decide(&req(id, 100), 0, 3), RouteDecision::Assign(small));
            match p.decide(&req(id, 100), 1, 3) {
                RouteDecision::Assign(m) => {
                    assert_eq!(m, large);
                    escalated += 1;
                }
                RouteDecision::Finish => {}
            }
            assert_eq!(p.decide(&req(id, 100), 2, 3), RouteDecision::Finish);
        }
        let frac = escalated as f64 / n as f64;
        assert!((frac - 0.3).abs() < 0.05, "escalation fraction {frac}");
        // edge fractions are exact
        let never = ModelPolicy::parse("cascade:llama3-8b->llama3-70b:0").unwrap();
        let always = ModelPolicy::parse("cascade:llama3-8b->llama3-70b:1").unwrap();
        for id in 0..64 {
            assert_eq!(never.decide(&req(id, 1), 1, 3), RouteDecision::Finish);
            assert_eq!(
                always.decide(&req(id, 1), 1, 3),
                RouteDecision::Assign(large)
            );
        }
    }
}
