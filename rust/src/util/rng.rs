//! Deterministic pseudo-random numbers and the arrival/size distributions
//! HERMES needs for request modeling (paper §III-F.1).
//!
//! The offline crate cache has no `rand`; we implement PCG32 (O'Neill 2014,
//! `PCG-XSH-RR 64/32`) seeded through SplitMix64. Every simulator component
//! draws from an explicitly-seeded `Pcg` so runs are exactly reproducible.

/// PCG32 generator (64-bit state, 32-bit output).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// cached second normal deviate (Box–Muller produces pairs)
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97f4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg {
    pub fn new(seed: u64) -> Pcg {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg {
            state: 0,
            inc: init_inc,
            spare_normal: None,
        };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-client / per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Pcg {
        Pcg::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97f4A7C15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_mu_sigma(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal parameterized by the mean/σ of the *underlying* normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_mu_sigma(mu, sigma).exp()
    }

    /// Exponential with rate λ (mean 1/λ).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson-distributed count. Knuth for small λ, normal approx above 64.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = self.normal_mu_sigma(lambda, lambda.sqrt()).round();
            return v.max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Request inter-arrival processes (paper: "uniform, normal, poisson, and
/// bursty distributions").
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Fixed spacing: one request every 1/rate seconds.
    Uniform { rate: f64 },
    /// Gaps ~ Normal(1/rate, cv/rate), truncated at 0.
    Normal { rate: f64, cv: f64 },
    /// Poisson process: exponential gaps with rate λ.
    Poisson { rate: f64 },
    /// Markov-modulated: alternates calm (rate) and burst (rate*burst_mult)
    /// phases with mean phase lengths `calm_s`/`burst_s` seconds.
    Bursty {
        rate: f64,
        burst_mult: f64,
        calm_s: f64,
        burst_s: f64,
    },
}

impl Arrival {
    /// Generate `n` arrival timestamps (seconds, ascending, starting near 0).
    pub fn timestamps(&self, n: usize, rng: &mut Pcg) -> Vec<f64> {
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        match *self {
            Arrival::Uniform { rate } => {
                let gap = 1.0 / rate;
                for _ in 0..n {
                    t += gap;
                    out.push(t);
                }
            }
            Arrival::Normal { rate, cv } => {
                let mean = 1.0 / rate;
                for _ in 0..n {
                    t += rng.normal_mu_sigma(mean, cv * mean).max(0.0);
                    out.push(t);
                }
            }
            Arrival::Poisson { rate } => {
                for _ in 0..n {
                    t += rng.exp(rate);
                    out.push(t);
                }
            }
            Arrival::Bursty {
                rate,
                burst_mult,
                calm_s,
                burst_s,
            } => {
                let mut in_burst = false;
                let mut phase_end = rng.exp(1.0 / calm_s);
                for _ in 0..n {
                    let r = if in_burst { rate * burst_mult } else { rate };
                    t += rng.exp(r);
                    while t > phase_end {
                        in_burst = !in_burst;
                        phase_end += rng.exp(1.0 / if in_burst { burst_s } else { calm_s });
                    }
                    out.push(t);
                }
            }
        }
        out
    }

    pub fn rate(&self) -> f64 {
        match *self {
            Arrival::Uniform { rate }
            | Arrival::Normal { rate, .. }
            | Arrival::Poisson { rate }
            | Arrival::Bursty { rate, .. } => rate,
        }
    }

    /// The same process shape at a different base rate (rate sweeps over
    /// non-Poisson arrivals keep their cv / burst structure).
    pub fn scaled_to(&self, rate: f64) -> Arrival {
        match *self {
            Arrival::Uniform { .. } => Arrival::Uniform { rate },
            Arrival::Normal { cv, .. } => Arrival::Normal { rate, cv },
            Arrival::Poisson { .. } => Arrival::Poisson { rate },
            Arrival::Bursty {
                burst_mult,
                calm_s,
                burst_s,
                ..
            } => Arrival::Bursty {
                rate,
                burst_mult,
                calm_s,
                burst_s,
            },
        }
    }
}

/// Incremental arrival-timestamp generator: yields exactly the sequence
/// [`Arrival::timestamps`] would produce, one timestamp at a time,
/// consuming the rng draw-for-draw in the same order. The streaming
/// workload source (`workload::stream`) relies on this equivalence to
/// make lazy generation bit-identical to upfront materialization while
/// holding O(1) state instead of the whole timestamp vector.
#[derive(Debug, Clone)]
pub struct ArrivalTimes {
    arrival: Arrival,
    rng: Pcg,
    t: f64,
    /// Bursty phase state (unused by the memoryless processes)
    in_burst: bool,
    phase_end: f64,
}

impl ArrivalTimes {
    pub fn new(arrival: Arrival, mut rng: Pcg) -> ArrivalTimes {
        // Bursty draws its first phase boundary before any arrival —
        // mirror `timestamps`, which draws it ahead of the loop
        let (in_burst, phase_end) = match arrival {
            Arrival::Bursty { calm_s, .. } => (false, rng.exp(1.0 / calm_s)),
            _ => (false, 0.0),
        };
        ArrivalTimes {
            arrival,
            rng,
            t: 0.0,
            in_burst,
            phase_end,
        }
    }

    /// Next arrival timestamp (seconds; non-decreasing).
    pub fn next_time(&mut self) -> f64 {
        match self.arrival.clone() {
            Arrival::Uniform { rate } => self.t += 1.0 / rate,
            Arrival::Normal { rate, cv } => {
                let mean = 1.0 / rate;
                self.t += self.rng.normal_mu_sigma(mean, cv * mean).max(0.0);
            }
            Arrival::Poisson { rate } => self.t += self.rng.exp(rate),
            Arrival::Bursty {
                rate,
                burst_mult,
                calm_s,
                burst_s,
            } => {
                let r = if self.in_burst { rate * burst_mult } else { rate };
                self.t += self.rng.exp(r);
                while self.t > self.phase_end {
                    self.in_burst = !self.in_burst;
                    self.phase_end +=
                        self.rng.exp(1.0 / if self.in_burst { burst_s } else { calm_s });
                }
            }
        }
        self.t
    }

    /// Give back the rng after the draws made so far — how the
    /// streaming generator positions its token-sampling stream exactly
    /// where [`Arrival::timestamps`] would have left it (including the
    /// Box–Muller spare).
    pub fn into_rng(self) -> Pcg {
        self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg::new(7);
        let mut b = Pcg::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::new(8);
        assert_ne!(Pcg::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count={c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut rng = Pcg::new(4);
        for lambda in [0.5, 4.0, 100.0] {
            let n = 20_000;
            let mean =
                (0..n).map(|_| rng.poisson(lambda)).sum::<u64>() as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda.max(1.0) + 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn arrivals_ascending_and_rate_respected() {
        let mut rng = Pcg::new(5);
        for arr in [
            Arrival::Uniform { rate: 10.0 },
            Arrival::Normal { rate: 10.0, cv: 0.3 },
            Arrival::Poisson { rate: 10.0 },
            Arrival::Bursty {
                rate: 10.0,
                burst_mult: 4.0,
                calm_s: 5.0,
                burst_s: 1.0,
            },
        ] {
            let ts = arr.timestamps(5_000, &mut rng);
            assert!(ts.windows(2).all(|w| w[1] >= w[0]));
            let measured = ts.len() as f64 / ts.last().unwrap();
            // bursty raises the effective rate; just check the right decade
            assert!(
                measured > 5.0 && measured < 45.0,
                "arr={arr:?} measured={measured}"
            );
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn arrival_times_match_upfront_timestamps_draw_for_draw() {
        for arr in [
            Arrival::Uniform { rate: 7.0 },
            Arrival::Normal { rate: 7.0, cv: 0.4 },
            Arrival::Poisson { rate: 7.0 },
            Arrival::Bursty {
                rate: 7.0,
                burst_mult: 5.0,
                calm_s: 3.0,
                burst_s: 0.5,
            },
        ] {
            let mut eager_rng = Pcg::new(77);
            let eager = arr.timestamps(2_000, &mut eager_rng);
            let mut lazy = ArrivalTimes::new(arr.clone(), Pcg::new(77));
            for (i, t) in eager.iter().enumerate() {
                assert_eq!(*t, lazy.next_time(), "{arr:?} diverged at {i}");
            }
            // the rngs must end in the same state (spare included), so a
            // downstream sampling stream continues identically
            let mut lazy_rng = lazy.into_rng();
            for _ in 0..16 {
                assert_eq!(eager_rng.normal(), lazy_rng.normal(), "{arr:?}");
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
