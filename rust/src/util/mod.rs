//! Support substrates built in-repo because the build environment is
//! offline (no serde / clap / criterion / proptest / rand in the crate
//! cache): JSON, RNG + distributions, statistics, CLI parsing, a
//! micro-bench harness and a property-test runner.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
