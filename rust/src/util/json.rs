//! Minimal JSON value, parser and writer.
//!
//! The build environment is offline and `serde`/`serde_json` are not in the
//! local crate cache, so HERMES carries its own JSON substrate. It supports
//! everything the simulator needs: config files, `coefficients.json`
//! artifacts, metrics dumps and Chrome-trace export.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept in a `BTreeMap` so that serialized
/// output is deterministic (stable across runs — useful for golden tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Remove a key from an object; no-op on non-objects. Returns the
    /// removed value, if any.
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(m) => m.remove(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["cluster", "clients"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Typed getters with defaults — the config-system workhorses.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty_into(&mut out, 0);
        out
    }

    /// Append the compact serialization to an existing buffer — no
    /// intermediate `String` per value, so callers assembling large
    /// documents reuse one allocation.
    pub fn write_into(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    /// Append the 2-space-indented serialization to `out` as if this
    /// value sat at nesting level `depth` of an enclosing document —
    /// the building block for streamed emission ([`JsonRowWriter`]).
    pub fn write_pretty_into(&self, out: &mut String, depth: usize) {
        self.write(out, Some(2), depth);
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Shallow-merge `patch` over `self` (objects only): keys in `patch`
    /// replace keys in `self`, other keys are kept. Non-object inputs
    /// return `patch` unchanged. The scenario registry uses this to apply
    /// panel overrides onto a base workload description.
    pub fn merged(&self, patch: &Json) -> Json {
        match (self, patch) {
            (Json::Obj(base), Json::Obj(over)) => {
                let mut m = base.clone();
                for (k, v) in over {
                    m.insert(k.clone(), v.clone());
                }
                Json::Obj(m)
            }
            _ => patch.clone(),
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    // write! into the existing buffer — the format!() this replaced
    // allocated a throwaway String per number, the dominant cost of
    // emitting big numeric documents (BENCH rows, metric dumps)
    if !n.is_finite() {
        // JSON has no Inf/NaN; clamp to null (only ever hit by broken metrics).
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Streams a pretty-printed JSON array of rows to an `io::Write`
/// without ever materializing the whole document: each [`push`]
/// serializes one row into a reused buffer and writes it through
/// immediately. The emitted bytes are identical to
/// `Json::Arr(rows).to_pretty()` — golden files and parsers can't tell
/// the difference. `bench::run_and_report` streams `BENCH_*.json`
/// through this so output cost at the 100M tier stays O(one row), and
/// any similarly shaped row-per-record dump can do the same.
///
/// [`push`]: JsonRowWriter::push
pub struct JsonRowWriter<W: std::io::Write> {
    out: W,
    n: usize,
    buf: String,
}

impl<W: std::io::Write> JsonRowWriter<W> {
    pub fn new(out: W) -> Self {
        JsonRowWriter { out, n: 0, buf: String::new() }
    }

    /// Serialize `row` at array depth and write it through.
    pub fn push(&mut self, row: &Json) -> std::io::Result<()> {
        self.buf.clear();
        self.buf.push_str(if self.n == 0 { "[\n  " } else { ",\n  " });
        row.write_pretty_into(&mut self.buf, 1);
        self.n += 1;
        self.out.write_all(self.buf.as_bytes())
    }

    /// Close the array and flush; returns the inner writer.
    pub fn finish(mut self) -> std::io::Result<W> {
        self.out
            .write_all(if self.n == 0 { b"[]".as_slice() } else { b"\n]".as_slice() })?;
        self.out.flush()?;
        Ok(self.out)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => {
                    self.pos += 1;
                }
                // Tolerate // line comments in config files.
                b'/' if self.bytes.get(self.pos + 1) == Some(&b'/') => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.pos += 1;
                    }
                }
                _ => break,
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", kw)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode a UTF-8 multi-byte sequence.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\ é é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ é é");
    }

    #[test]
    fn parse_comments() {
        let j = Json::parse("{\n // a comment\n \"x\": 1\n}").unwrap();
        assert_eq!(j.f64_or("x", 0.0), 1.0);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.to_pretty()).unwrap(), j);
    }

    #[test]
    fn builder_api() {
        let mut j = Json::obj();
        j.set("name", "hermes").set("n", 3u64).set("ok", true);
        assert_eq!(j.str_or("name", ""), "hermes");
        assert_eq!(j.usize_or("n", 0), 3);
        assert!(j.bool_or("ok", false));
        assert_eq!(j.usize_or("missing", 9), 9);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn merged_overrides_shallowly() {
        let base = Json::parse(r#"{"trace": "azure-conv", "n": 100, "rate": 2.0}"#).unwrap();
        let patch = Json::parse(r#"{"trace": "azure-code", "branches": 4}"#).unwrap();
        let m = base.merged(&patch);
        assert_eq!(m.str_or("trace", ""), "azure-code");
        assert_eq!(m.usize_or("n", 0), 100);
        assert_eq!(m.usize_or("branches", 0), 4);
        // non-object patch replaces wholesale
        assert_eq!(base.merged(&Json::Num(1.0)), Json::Num(1.0));
    }

    #[test]
    fn deterministic_key_order() {
        let j = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(j.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn non_finite_nums_become_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn write_into_appends_in_place() {
        let j = Json::parse(r#"{"a":[1,2],"b":"x"}"#).unwrap();
        let mut buf = String::from("prefix ");
        j.write_into(&mut buf);
        assert_eq!(buf, format!("prefix {}", j.to_string()));
        // pretty at depth 1 indents continuation lines as if nested
        let mut buf = String::new();
        j.write_pretty_into(&mut buf, 1);
        assert!(buf.ends_with("\n  }"), "depth-1 closer indents two spaces: {buf:?}");
    }

    #[test]
    fn row_writer_matches_to_pretty() {
        // rows with every value shape the bench document uses
        let rows: Vec<Json> = vec![
            Json::parse(r#"{"name":"a","n":1,"nested":{"x":[1,2.5,true]}}"#).unwrap(),
            Json::parse(r#"{"name":"b","s":"q\"uote","v":null}"#).unwrap(),
            Json::parse(r#"{"aggregate":{"events":12,"wall_s":0.25}}"#).unwrap(),
        ];
        let mut w = JsonRowWriter::new(Vec::new());
        for r in &rows {
            w.push(r).unwrap();
        }
        let bytes = w.finish().unwrap();
        let streamed = String::from_utf8(bytes).unwrap();
        assert_eq!(streamed, Json::Arr(rows.clone()).to_pretty());
        // and the result still parses back to the same document
        assert_eq!(Json::parse(&streamed).unwrap(), Json::Arr(rows));
        // empty document
        let w = JsonRowWriter::new(Vec::new());
        let bytes = w.finish().unwrap();
        assert_eq!(bytes, b"[]");
        assert_eq!(Json::Arr(Vec::new()).to_pretty(), "[]");
    }
}
