//! Minimal property-based testing runner (proptest is unavailable offline).
//!
//! `check(seed, cases, |rng| { ... })` runs a closure against `cases`
//! independently-seeded PCG streams; on failure it reports the case seed so
//! the exact failing input can be replayed with `replay(seed, ...)`.

use super::rng::Pcg;

/// Run `prop` for `cases` random cases. `prop` returns `Err(msg)` to fail.
/// Panics with the failing case seed embedded in the message.
pub fn check<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    let mut meta = Pcg::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Pcg::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    let mut rng = Pcg::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed property failed (seed {case_seed:#x}): {msg}");
    }
}

/// Assertion helpers that produce `Result` instead of panicking, so the
/// runner can attach the replay seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(1, 50, |rng| {
            n += 1;
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check(2, 100, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 9, "hit {x}");
            Ok(())
        });
    }

    #[test]
    fn prop_assert_eq_formats() {
        let r: Result<(), String> = (|| {
            prop_assert_eq!(1 + 1, 3);
            Ok(())
        })();
        assert!(r.unwrap_err().contains("1 + 1"));
    }
}
