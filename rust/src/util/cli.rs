//! Tiny command-line parser (clap is unavailable offline).
//!
//! Grammar: `hermes <subcommand> [--key value]... [--flag]... [positional]...`
//! Values are looked up typed with defaults; unknown flags are an error so
//! typos fail fast.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// flags that were consumed by a typed getter — used by `finish()`.
    /// `RefCell` makes `Args` `!Sync`, which is fine: arguments are
    /// fully parsed and consumed on the main thread before any sweep
    /// fan-out (`sim::parallel`) starts; nothing here reaches a worker
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments *excluding* argv[0].
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless next token is another flag → boolean
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Strict positive-integer flag: `Ok(None)` when absent, `Err` on a
    /// malformed or zero value. The parallelism knobs (`--jobs`,
    /// `--shards`) sit on this — a typo must fail loudly, not silently
    /// fall back to the serial path and report serial numbers.
    pub fn positive_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(Some(n)),
                _ => Err(format!("--{key} needs a positive integer, got '{v}'")),
            },
        }
    }

    /// Strict enumerated flag: the value (or `default` when the flag is
    /// absent) must be one of `allowed`. The mode knobs (`--metrics`)
    /// sit on this — a typo must fail loudly, not silently run a whole
    /// benchmark under the wrong metrics contract.
    pub fn one_of(&self, key: &str, default: &str, allowed: &[&str]) -> Result<String, String> {
        let v = self.str_or(key, default);
        if allowed.contains(&v.as_str()) {
            Ok(v)
        } else {
            Err(format!("--{key} must be one of {}, got '{v}'", allowed.join("|")))
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v == "true" || v == "1" || v == "yes")
            .unwrap_or(default)
    }

    /// Error if any provided flag was never consumed by a getter.
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !seen.iter().any(|s| s == *k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown flag(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        // NOTE: a bool flag followed by a positional is ambiguous; use
        // `--verbose=true` or put positionals first.
        let a = parse("simulate pos1 --config c.json --rate 2.5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.str_or("config", ""), "c.json");
        assert_eq!(a.f64_or("rate", 0.0), 2.5);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --n=12 --mode=chunked");
        assert_eq!(a.usize_or("n", 0), 12);
        assert_eq!(a.str_or("mode", ""), "chunked");
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("x --dry-run --out f.json");
        assert!(a.bool_or("dry-run", false));
        assert_eq!(a.str_or("out", ""), "f.json");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("mode", "static"), "static");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("x --typo 1");
        let _ = a.usize_or("n", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn one_of_is_strict() {
        let a = parse("x --metrics sketch");
        assert_eq!(a.one_of("metrics", "exact", &["exact", "sketch"]), Ok("sketch".into()));
        assert!(a.finish().is_ok());
        // absent flag falls back to the default
        assert_eq!(parse("x").one_of("metrics", "exact", &["exact", "sketch"]), Ok("exact".into()));
        // a typo is a hard error, not a silent mode change
        assert!(parse("x --metrics sketchy")
            .one_of("metrics", "exact", &["exact", "sketch"])
            .is_err());
    }

    #[test]
    fn positive_usize_is_strict() {
        assert_eq!(parse("x --shards 4").positive_usize("shards"), Ok(Some(4)));
        assert_eq!(parse("x").positive_usize("shards"), Ok(None));
        // zero and garbage are hard errors, not a silent serial default
        assert!(parse("x --shards 0").positive_usize("shards").is_err());
        assert!(parse("x --shards four").positive_usize("shards").is_err());
        // a consumed-but-invalid flag still counts as seen for finish()
        let a = parse("x --jobs 2");
        let _ = a.positive_usize("jobs");
        assert!(a.finish().is_ok());
    }
}
