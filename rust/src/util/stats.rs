//! Summary statistics used across metrics collection and fidelity checks:
//! percentiles (T50/T90/T99 as the paper reports), CDFs (Fig 15), and a
//! small least-squares helper used by tests that sanity-check the
//! Python-fit polynomial coefficients.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation (q in [0,100]); 0.0 for empty
/// input. NaN-safe: samples are ordered with `f64::total_cmp`, which
/// sorts NaNs to the ends instead of panicking mid-sort — metric
/// streams can legitimately carry NaN (e.g. 0/0 rates) and a summary
/// must never take the whole run down.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = (q / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Common latency summary: mean, T50, T90, T99 (paper §III-F.2).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        Summary {
            n: v.len(),
            mean: mean(&v),
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            min: v[0],
            max: *v.last().unwrap(),
        }
    }
}

/// Empirical CDF sampled at `points` evenly-spaced quantiles — the Fig 15
/// plotting format (x = latency, y = fraction ≤ x).
pub fn cdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return Vec::new();
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    (0..points)
        .map(|i| {
            let q = (i + 1) as f64 / points as f64;
            (percentile_sorted(&v, q * 100.0), q)
        })
        .collect()
}

/// Default relative-error bound for [`QuantileSketch`]: quantile
/// estimates are within ±1% of the exact sample value. This is the
/// documented error contract of `--metrics sketch` runs (see
/// docs/performance.md, "Memory model").
pub const SKETCH_ALPHA: f64 = 0.01;

/// Mergeable streaming quantile sketch (DDSketch-style, Masson et al.):
/// logarithmic bins with relative width α, so any quantile estimate is
/// within relative error α of the exact sample at that rank — in O(log
/// range) memory regardless of how many samples stream through.
///
/// Determinism is part of the contract, mirroring the repo's
/// bit-exactness discipline:
///
/// * bins hold **integer** counts in a `BTreeMap`, so insertion order
///   never matters and `merge` is exactly associative and commutative
///   for every count and quantile — a sharded run's per-domain sketches
///   merge to bit-identical percentiles at any shard count;
/// * only the `sum` accumulator (used for the mean) is an f64 whose
///   value depends on fold order, which is why sharded-vs-serial tests
///   pin quantiles exactly and means approximately;
/// * the NaN/∞ policy matches [`percentile`]'s `total_cmp` order:
///   non-positive values rank first (estimated 0.0 — latencies are
///   non-negative), then finite bins ascending, then +∞, then NaN.
///   An empty sketch reports 0.0, like `percentile` on empty input.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    alpha: f64,
    /// ln γ where γ = (1+α)/(1−α); bin k covers (γ^(k−1), γ^k]
    gamma_ln: f64,
    /// finite positive samples: bin key → count, ordered ascending
    bins: std::collections::BTreeMap<i32, u64>,
    /// samples ≤ 0.0 (incl. −∞), all estimated as 0.0
    n_low: u64,
    n_inf: u64,
    n_nan: u64,
    n: u64,
    /// running sum for the mean — the one order-sensitive accumulator
    sum: f64,
    /// min/max under `total_cmp` (NaN largest), clamping bin estimates
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new(SKETCH_ALPHA)
    }
}

impl QuantileSketch {
    pub fn new(alpha: f64) -> QuantileSketch {
        assert!(alpha > 0.0 && alpha < 1.0, "sketch alpha must be in (0,1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        QuantileSketch {
            alpha,
            gamma_ln: gamma.ln(),
            bins: std::collections::BTreeMap::new(),
            n_low: 0,
            n_inf: 0,
            n_nan: 0,
            n: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
        }
    }

    /// The configured relative-error bound α.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Mean of everything inserted (0.0 when empty). NaN/∞ samples
    /// poison the mean exactly as they would a retained-sample mean.
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Estimated resident bytes: bin storage dominates; counters and
    /// BTreeMap node overhead are folded into the per-bin constant.
    pub fn bytes_est(&self) -> usize {
        96 + self.bins.len() * 48
    }

    pub fn insert(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            if x.total_cmp(&self.min).is_lt() {
                self.min = x;
            }
            if x.total_cmp(&self.max).is_gt() {
                self.max = x;
            }
        }
        self.n += 1;
        self.sum += x;
        if x.is_nan() {
            self.n_nan += 1;
        } else if x == f64::INFINITY {
            self.n_inf += 1;
        } else if x <= 0.0 {
            self.n_low += 1;
        } else {
            let k = (x.ln() / self.gamma_ln).ceil() as i32;
            *self.bins.entry(k).or_insert(0) += 1;
        }
    }

    /// Fold `other` into `self`. Bin counts add exactly, so merging is
    /// associative and order-independent for every quantile; only the
    /// f64 `sum` (mean) depends on merge order. Callers that need a
    /// deterministic mean merge in a fixed order (the sharded outcome
    /// merge walks domains ascending).
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            (self.alpha - other.alpha).abs() < 1e-12,
            "cannot merge sketches with different alpha"
        );
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            if other.min.total_cmp(&self.min).is_lt() {
                self.min = other.min;
            }
            if other.max.total_cmp(&self.max).is_gt() {
                self.max = other.max;
            }
        }
        self.n += other.n;
        self.sum += other.sum;
        self.n_low += other.n_low;
        self.n_inf += other.n_inf;
        self.n_nan += other.n_nan;
        for (&k, &c) in &other.bins {
            *self.bins.entry(k).or_insert(0) += c;
        }
    }

    /// Midpoint estimate for bin k, within relative error α of every
    /// sample in the bin; clamped to the observed [min, max] so edge
    /// bins never overshoot the actual extremes.
    fn bin_estimate(&self, k: i32) -> f64 {
        let gamma = self.gamma_ln.exp();
        let est = 2.0 * (k as f64 * self.gamma_ln).exp() / (gamma + 1.0);
        let lo = if self.min.is_finite() { self.min.max(0.0) } else { 0.0 };
        let hi = if self.max.is_finite() { self.max } else { f64::MAX };
        est.clamp(lo, hi)
    }

    /// Value estimate at rank r (0-based) in `total_cmp` order:
    /// lows → finite bins ascending → +∞ → NaN.
    fn value_at_rank(&self, mut r: u64) -> f64 {
        if r < self.n_low {
            return 0.0;
        }
        r -= self.n_low;
        for (&k, &c) in &self.bins {
            if r < c {
                return self.bin_estimate(k);
            }
            r -= c;
        }
        if r < self.n_inf {
            return f64::INFINITY;
        }
        f64::NAN
    }

    /// Quantile estimate (q in [0,100]) with the same rank convention
    /// as [`percentile`]: linear interpolation between the estimates at
    /// the two bracketing ranks. For positive finite data the result is
    /// within relative error α of the exact interpolated percentile.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n == 1 {
            return self.value_at_rank(0);
        }
        let pos = (q / 100.0).clamp(0.0, 1.0) * (self.n - 1) as f64;
        let lo = pos.floor() as u64;
        let hi = pos.ceil() as u64;
        let frac = pos - lo as f64;
        let a = self.value_at_rank(lo);
        if hi == lo {
            return a;
        }
        let b = self.value_at_rank(hi);
        a * (1.0 - frac) + b * frac
    }

    /// The same latency [`Summary`] shape the exact path produces, with
    /// quantiles from the sketch. `min`/`max` are exact (tracked per
    /// sample); `mean` is exact up to f64 fold order.
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return Summary::default();
        }
        Summary {
            n: self.n as usize,
            mean: self.mean(),
            p50: self.quantile(50.0),
            p90: self.quantile(90.0),
            p99: self.quantile(99.0),
            min: self.min,
            max: self.max,
        }
    }
}

/// Ordinary least squares fit: returns coefficients w minimizing
/// ||X w − y||², via normal equations + Gaussian elimination with partial
/// pivoting. Feature counts here are tiny (≤8), so this is plenty.
pub fn lstsq(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty());
    let f = x[0].len();
    // A = XᵀX (f×f), b = Xᵀy
    let mut a = vec![vec![0.0f64; f]; f];
    let mut b = vec![0.0f64; f];
    for (row, &yi) in x.iter().zip(y.iter()) {
        for i in 0..f {
            b[i] += row[i] * yi;
            for j in 0..f {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    // Ridge epsilon for numeric safety on collinear features.
    for i in 0..f {
        a[i][i] += 1e-12;
    }
    solve(a, b)
}

/// Solve a dense linear system via Gaussian elimination w/ partial pivoting.
pub fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-300 {
            continue;
        }
        for row in col + 1..n {
            let factor = a[row][col] / d;
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= factor * a[col][k];
            }
            b[row] -= factor * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = if a[row][row].abs() < 1e-300 {
            0.0
        } else {
            acc / a[row][row]
        };
    }
    x
}

/// Mean squared error between predictions and targets.
pub fn mse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .collect::<Vec<_>>())
}

/// Mean absolute percentage error (used for Fig 6 fidelity reporting).
pub fn mape(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| ((p - t) / t.max(1e-300)).abs())
        .collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!(s.p90 > 4.0 && s.p90 <= 5.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 20.0);
        assert_eq!(percentile(&v, 50.0), 15.0);
    }

    #[test]
    fn cdf_monotone_and_covers() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let c = cdf(&xs, 10);
        assert_eq!(c.len(), 10);
        assert!(c.windows(2).all(|w| w[1].0 >= w[0].0 && w[1].1 > w[0].1));
        assert_eq!(c.last().unwrap().1, 1.0);
    }

    #[test]
    fn lstsq_recovers_exact_linear() {
        // y = 3 + 2a - 0.5b
        let x: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![1.0, i as f64, (i * i % 17) as f64])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 + 2.0 * r[1] - 0.5 * r[2]).collect();
        let w = lstsq(&x, &y);
        assert!((w[0] - 3.0).abs() < 1e-6);
        assert!((w[1] - 2.0).abs() < 1e-6);
        assert!((w[2] + 0.5).abs() < 1e-6);
        let pred: Vec<f64> = x
            .iter()
            .map(|r| r.iter().zip(&w).map(|(a, b)| a * b).sum())
            .collect();
        assert!(mse(&pred, &y) < 1e-12);
    }

    #[test]
    fn solve_pivots() {
        // needs a row swap to avoid zero pivot
        let a = vec![vec![0.0, 1.0], vec![2.0, 0.0]];
        let b = vec![3.0, 4.0];
        let x = solve(a, b);
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mape_simple() {
        assert!((mape(&[110.0], &[100.0]) - 0.1).abs() < 1e-12);
    }

    /// |sketch − exact| ≤ α·exact at p50/p90/p99 for a given sample set.
    fn assert_sketch_within_alpha(xs: &[f64], label: &str) {
        let mut sk = QuantileSketch::default();
        for &x in xs {
            sk.insert(x);
        }
        for q in [50.0, 90.0, 99.0] {
            let exact = percentile(xs, q);
            let approx = sk.quantile(q);
            let tol = sk.alpha() * exact.abs() + 1e-12;
            assert!(
                (approx - exact).abs() <= tol,
                "{label} p{q}: sketch={approx} exact={exact} tol={tol}"
            );
        }
        assert_eq!(sk.count(), xs.len() as u64);
    }

    #[test]
    fn sketch_error_bound_uniform() {
        let xs: Vec<f64> = (0..10_000).map(|i| (i as f64 + 0.5) / 100.0).collect();
        assert_sketch_within_alpha(&xs, "uniform");
    }

    #[test]
    fn sketch_error_bound_lognormal() {
        // heavy-tailed: exp of a uniform grid spans ~5 decades, the
        // regime logarithmic bins exist for
        let xs: Vec<f64> = (0..10_000)
            .map(|i| (12.0 * (i as f64 + 0.5) / 10_000.0 - 6.0).exp())
            .collect();
        assert_sketch_within_alpha(&xs, "lognormal");
    }

    #[test]
    fn sketch_error_bound_adversarial_spike() {
        // 999 identical fast requests and one 10⁶× outlier: the spike
        // must not drag p50/p90, and p99 must interpolate exactly as the
        // sorted-sample path does
        let mut xs = vec![1.0; 999];
        xs.push(1.0e6);
        assert_sketch_within_alpha(&xs, "spike");
        // repeated extreme bimodal values
        let xs: Vec<f64> = (0..1000)
            .map(|i| if i % 10 == 0 { 3600.0 } else { 0.001 })
            .collect();
        assert_sketch_within_alpha(&xs, "bimodal");
    }

    #[test]
    fn sketch_merge_is_order_stable_and_associative() {
        let xs: Vec<f64> = (0..3_000)
            .map(|i| ((i * 2654435761u64 % 97) as f64 + 1.0) * 0.01)
            .collect();
        let mut parts: Vec<QuantileSketch> = Vec::new();
        for chunk in xs.chunks(500) {
            let mut sk = QuantileSketch::default();
            for &x in chunk {
                sk.insert(x);
            }
            parts.push(sk);
        }
        // merge(a,b) vs merge(b,a): every quantile and count bit-identical
        let mut ab = parts[0].clone();
        ab.merge(&parts[1]);
        let mut ba = parts[1].clone();
        ba.merge(&parts[0]);
        assert_eq!(ab.count(), ba.count());
        for q in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(ab.quantile(q).to_bits(), ba.quantile(q).to_bits());
        }
        // associativity: fold left-to-right vs pairwise tree
        let mut left = parts[0].clone();
        for p in &parts[1..] {
            left.merge(p);
        }
        let mut pair01 = parts[0].clone();
        pair01.merge(&parts[1]);
        let mut pair23 = parts[2].clone();
        pair23.merge(&parts[3]);
        let mut pair45 = parts[4].clone();
        pair45.merge(&parts[5]);
        let mut tree = pair01;
        tree.merge(&pair23);
        tree.merge(&pair45);
        assert_eq!(left.count(), tree.count());
        for q in [50.0, 90.0, 99.0] {
            assert_eq!(left.quantile(q).to_bits(), tree.quantile(q).to_bits());
        }
        // merged == single sketch over the whole stream, bit for bit
        let mut whole = QuantileSketch::default();
        for &x in &xs {
            whole.insert(x);
        }
        for q in [50.0, 90.0, 99.0] {
            assert_eq!(whole.quantile(q).to_bits(), left.quantile(q).to_bits());
        }
        // the f64 mean is order-sensitive but must agree closely
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
    }

    #[test]
    fn sketch_edge_cases() {
        // empty: mirrors percentile()'s 0.0-on-empty convention
        let sk = QuantileSketch::default();
        assert_eq!(sk.quantile(50.0), 0.0);
        assert_eq!(sk.summary(), Summary::default());
        assert_eq!(sk.bytes_est(), 96);
        // single sample: every quantile is (an α-accurate estimate of) it
        let mut sk = QuantileSketch::default();
        sk.insert(42.0);
        for q in [0.0, 50.0, 100.0] {
            assert!((sk.quantile(q) - 42.0).abs() <= SKETCH_ALPHA * 42.0);
        }
        let s = sk.summary();
        assert_eq!(s.n, 1);
        assert_eq!(s.min, 42.0);
        assert_eq!(s.max, 42.0);
        // all-NaN: NaN ranks last (total_cmp), so high quantiles are NaN
        let mut sk = QuantileSketch::default();
        sk.insert(f64::NAN);
        sk.insert(f64::NAN);
        assert_eq!(sk.count(), 2);
        assert!(sk.quantile(90.0).is_nan());
        assert!(sk.summary().max.is_nan());
        // zeros and +inf order around finite bins like total_cmp sorts
        let mut sk = QuantileSketch::default();
        sk.insert(0.0);
        sk.insert(1.0);
        sk.insert(f64::INFINITY);
        assert_eq!(sk.quantile(0.0), 0.0);
        assert!((sk.quantile(50.0) - 1.0).abs() <= SKETCH_ALPHA);
        assert_eq!(sk.quantile(100.0), f64::INFINITY);
        // memory stays O(bins), not O(samples)
        let mut sk = QuantileSketch::default();
        for i in 0..100_000 {
            sk.insert(1.0 + (i % 1000) as f64 * 0.01);
        }
        assert!(sk.bytes_est() < 32 * 1024, "bytes={}", sk.bytes_est());
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // regression: the percentile sorts used partial_cmp().unwrap(),
        // which panics on NaN; total_cmp orders NaN after +inf instead
        let xs = [1.0, f64::NAN, 3.0, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 2.5).abs() < 1e-12, "NaN sorts last: p50={p50}");
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN is the largest under the total order");
        assert!(s.p50.is_finite());
        let c = cdf(&xs, 4);
        assert_eq!(c.len(), 4);
        // all-NaN input must also survive
        let all_nan = [f64::NAN, f64::NAN];
        let s = Summary::of(&all_nan);
        assert_eq!(s.n, 2);
        assert!(s.p90.is_nan());
    }
}
