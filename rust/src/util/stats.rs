//! Summary statistics used across metrics collection and fidelity checks:
//! percentiles (T50/T90/T99 as the paper reports), CDFs (Fig 15), and a
//! small least-squares helper used by tests that sanity-check the
//! Python-fit polynomial coefficients.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation (q in [0,100]); 0.0 for empty
/// input. NaN-safe: samples are ordered with `f64::total_cmp`, which
/// sorts NaNs to the ends instead of panicking mid-sort — metric
/// streams can legitimately carry NaN (e.g. 0/0 rates) and a summary
/// must never take the whole run down.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = (q / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Common latency summary: mean, T50, T90, T99 (paper §III-F.2).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        Summary {
            n: v.len(),
            mean: mean(&v),
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            min: v[0],
            max: *v.last().unwrap(),
        }
    }
}

/// Empirical CDF sampled at `points` evenly-spaced quantiles — the Fig 15
/// plotting format (x = latency, y = fraction ≤ x).
pub fn cdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return Vec::new();
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    (0..points)
        .map(|i| {
            let q = (i + 1) as f64 / points as f64;
            (percentile_sorted(&v, q * 100.0), q)
        })
        .collect()
}

/// Ordinary least squares fit: returns coefficients w minimizing
/// ||X w − y||², via normal equations + Gaussian elimination with partial
/// pivoting. Feature counts here are tiny (≤8), so this is plenty.
pub fn lstsq(x: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty());
    let f = x[0].len();
    // A = XᵀX (f×f), b = Xᵀy
    let mut a = vec![vec![0.0f64; f]; f];
    let mut b = vec![0.0f64; f];
    for (row, &yi) in x.iter().zip(y.iter()) {
        for i in 0..f {
            b[i] += row[i] * yi;
            for j in 0..f {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    // Ridge epsilon for numeric safety on collinear features.
    for i in 0..f {
        a[i][i] += 1e-12;
    }
    solve(a, b)
}

/// Solve a dense linear system via Gaussian elimination w/ partial pivoting.
pub fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-300 {
            continue;
        }
        for row in col + 1..n {
            let factor = a[row][col] / d;
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= factor * a[col][k];
            }
            b[row] -= factor * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = if a[row][row].abs() < 1e-300 {
            0.0
        } else {
            acc / a[row][row]
        };
    }
    x
}

/// Mean squared error between predictions and targets.
pub fn mse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .collect::<Vec<_>>())
}

/// Mean absolute percentage error (used for Fig 6 fidelity reporting).
pub fn mape(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    mean(&pred
        .iter()
        .zip(target)
        .map(|(p, t)| ((p - t) / t.max(1e-300)).abs())
        .collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!(s.p90 > 4.0 && s.p90 <= 5.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]), Summary::default());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 20.0);
        assert_eq!(percentile(&v, 50.0), 15.0);
    }

    #[test]
    fn cdf_monotone_and_covers() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let c = cdf(&xs, 10);
        assert_eq!(c.len(), 10);
        assert!(c.windows(2).all(|w| w[1].0 >= w[0].0 && w[1].1 > w[0].1));
        assert_eq!(c.last().unwrap().1, 1.0);
    }

    #[test]
    fn lstsq_recovers_exact_linear() {
        // y = 3 + 2a - 0.5b
        let x: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![1.0, i as f64, (i * i % 17) as f64])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 + 2.0 * r[1] - 0.5 * r[2]).collect();
        let w = lstsq(&x, &y);
        assert!((w[0] - 3.0).abs() < 1e-6);
        assert!((w[1] - 2.0).abs() < 1e-6);
        assert!((w[2] + 0.5).abs() < 1e-6);
        let pred: Vec<f64> = x
            .iter()
            .map(|r| r.iter().zip(&w).map(|(a, b)| a * b).sum())
            .collect();
        assert!(mse(&pred, &y) < 1e-12);
    }

    #[test]
    fn solve_pivots() {
        // needs a row swap to avoid zero pivot
        let a = vec![vec![0.0, 1.0], vec![2.0, 0.0]];
        let b = vec![3.0, 4.0];
        let x = solve(a, b);
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mape_simple() {
        assert!((mape(&[110.0], &[100.0]) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // regression: the percentile sorts used partial_cmp().unwrap(),
        // which panics on NaN; total_cmp orders NaN after +inf instead
        let xs = [1.0, f64::NAN, 3.0, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 2.5).abs() < 1e-12, "NaN sorts last: p50={p50}");
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN is the largest under the total order");
        assert!(s.p50.is_finite());
        let c = cdf(&xs, 4);
        assert_eq!(c.len(), 4);
        // all-NaN input must also survive
        let all_nan = [f64::NAN, f64::NAN];
        let s = Summary::of(&all_nan);
        assert_eq!(s.n, 2);
        assert!(s.p90.is_nan());
    }
}
