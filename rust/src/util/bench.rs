//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` is a `harness = false` binary that uses this module
//! to time closures (warmup + sampling, mean/p50/p99 reporting) and to
//! print paper-style tables. Keep it dependency-free and deterministic.

use super::stats::Summary;
use std::time::Instant;

/// Time `f` for `samples` iterations after `warmup` iterations.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        xs.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary::of(&xs);
    println!(
        "{name:<44} mean={:>10} p50={:>10} p99={:>10} (n={})",
        fmt_secs(s.mean),
        fmt_secs(s.p50),
        fmt_secs(s.p99),
        s.n
    );
    s
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3}s", s)
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Fixed-width table printer for the figure/table regeneration benches.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let body = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}", w = w))
                .collect::<Vec<_>>()
                .join(" | ");
            println!("| {body} |");
        };
        line(&self.headers, &self.widths);
        let sep = self
            .widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>()
            .join("-|-");
        println!("|-{sep}-|");
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Section banner so bench output reads like the paper's figure captions.
pub fn banner(title: &str) {
    println!();
    println!("{}", "=".repeat(title.len().min(100)));
    println!("{title}");
    println!("{}", "=".repeat(title.len().min(100)));
}

/// Prevent the optimizer from discarding a value (black_box substitute).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_returns_positive() {
        let s = time_fn("noop-loop", 2, 5, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(s.n, 5);
        assert!(s.mean > 0.0);
        assert!(s.p99 >= s.p50);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_secs(2.0), "2.000s");
        assert_eq!(fmt_secs(2e-3), "2.000ms");
        assert_eq!(fmt_secs(2e-6), "2.000us");
        assert_eq!(fmt_secs(2e-9), "2.0ns");
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 2);
    }
}
