//! A contended point-to-point / shared link with α+β cost and busy-until
//! serialization: transfers queue FIFO behind whatever the link is
//! already carrying.

use crate::sim::SimTime;

/// Static link parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// bandwidth, bytes/s
    pub bw: f64,
    /// one-way latency, s
    pub lat: f64,
}

impl LinkSpec {
    /// Uncontended transfer duration for `bytes`.
    pub fn duration(&self, bytes: f64) -> f64 {
        self.lat + bytes / self.bw
    }
}

/// A stateful link instance accumulating contention.
#[derive(Debug, Clone)]
pub struct Link {
    pub spec: LinkSpec,
    busy_until: SimTime,
    pub bytes_total: f64,
    pub transfers: u64,
}

impl Link {
    pub fn new(spec: LinkSpec) -> Link {
        Link {
            spec,
            busy_until: SimTime::ZERO,
            bytes_total: 0.0,
            transfers: 0,
        }
    }

    /// Enqueue a transfer of `bytes` at `now`; returns its finish time.
    /// The latency α is pipelined (does not occupy the link); the
    /// serialization term β·bytes does.
    pub fn transfer(&mut self, now: SimTime, bytes: f64) -> SimTime {
        let start = now.max(self.busy_until);
        let occupy = SimTime::from_secs(bytes / self.spec.bw);
        self.busy_until = start + occupy;
        self.bytes_total += bytes;
        self.transfers += 1;
        self.busy_until + SimTime::from_secs(self.spec.lat)
    }

    /// When the link would next be free (metrics / backpressure).
    pub fn busy_until(&self) -> SimTime {
        self.busy_until
    }

    /// Utilization over a window [0, horizon].
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        (self.bytes_total / self.spec.bw / horizon.as_secs()).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gbps(n: f64) -> LinkSpec {
        LinkSpec { bw: n * 1e9, lat: 1e-5 }
    }

    #[test]
    fn uncontended_transfer_is_alpha_beta() {
        let mut l = Link::new(gbps(10.0));
        let fin = l.transfer(SimTime::from_secs(1.0), 10e9);
        // 1s serialization + 10us latency
        assert!((fin.as_secs() - 2.00001).abs() < 1e-9, "{fin}");
    }

    #[test]
    fn back_to_back_transfers_queue() {
        let mut l = Link::new(gbps(1.0));
        let a = l.transfer(SimTime::ZERO, 1e9); // occupies [0,1]
        let b = l.transfer(SimTime::ZERO, 1e9); // queues: occupies [1,2]
        assert!((a.as_secs() - 1.00001).abs() < 1e-9);
        assert!((b.as_secs() - 2.00001).abs() < 1e-9);
        assert_eq!(l.transfers, 2);
    }

    #[test]
    fn idle_gap_not_charged() {
        let mut l = Link::new(gbps(1.0));
        l.transfer(SimTime::ZERO, 1e9);
        // link free again at t=1; a transfer at t=5 starts immediately
        let fin = l.transfer(SimTime::from_secs(5.0), 1e9);
        assert!((fin.as_secs() - 6.00001).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounded() {
        let mut l = Link::new(gbps(1.0));
        l.transfer(SimTime::ZERO, 5e8);
        assert!((l.utilization(SimTime::from_secs(1.0)) - 0.5).abs() < 1e-9);
        assert_eq!(l.utilization(SimTime::ZERO), 0.0);
    }

    #[test]
    fn utilization_window_accumulates_and_saturates() {
        let mut l = Link::new(gbps(2.0));
        // three transfers totalling 3 GB on a 2 GB/s link
        l.transfer(SimTime::ZERO, 1e9);
        l.transfer(SimTime::ZERO, 1e9);
        l.transfer(SimTime::from_secs(5.0), 1e9);
        assert_eq!(l.bytes_total, 3e9);
        assert_eq!(l.transfers, 3);
        // 1.5 s of serialization over a 2 s window
        assert!((l.utilization(SimTime::from_secs(2.0)) - 0.75).abs() < 1e-9);
        // a 6 s window dilutes it to 0.25
        assert!((l.utilization(SimTime::from_secs(6.0)) - 0.25).abs() < 1e-9);
        // a window shorter than the carried volume clamps at 1.0 (the
        // link cannot be more than fully busy)
        assert_eq!(l.utilization(SimTime::from_secs(1.0)), 1.0);
    }

    #[test]
    fn busy_until_tracks_queue_tail_not_latency() {
        let mut l = Link::new(gbps(1.0));
        let fin = l.transfer(SimTime::ZERO, 2e9);
        // the α latency is pipelined: finish = busy_until + lat
        assert_eq!(l.busy_until(), SimTime::from_secs(2.0));
        assert!((fin.as_secs() - 2.00001).abs() < 1e-9);
        // an idle link's busy_until does not advance on its own
        assert_eq!(l.busy_until(), SimTime::from_secs(2.0));
    }
}
