//! Hierarchical cluster topology and inter-client transfer estimation.
//!
//! Clients live at (rack, platform) coordinates. A transfer's path picks
//! the tightest shared level: same platform → NVLink fabric; same rack →
//! rack switch (shared, contended per rack); cross-rack → DCN spine
//! (shared, contended globally). `NetworkKind::DummyLink` reproduces
//! splitwise-sim's single lower-bound-bandwidth link for the Fig 5
//! comparison.

use std::collections::HashMap;

use super::link::{Link, LinkSpec};
use crate::sim::SimTime;

/// Physical placement of a client.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Location {
    pub rack: usize,
    pub platform: usize,
}

/// KV transfer granularity (paper §III-B.2 / Splitwise §4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Granularity {
    /// whole KV cache moves after the stage completes
    Full,
    /// per-layer streaming overlapped with compute: only the final
    /// layer's chunk is exposed on the critical path
    Layerwise { layers: usize },
}

/// Which communication model to use.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NetworkKind {
    /// hierarchical NVLink / rack / DCN model (astra-sim substitute)
    Hierarchical,
    /// splitwise-sim-style single link with one bandwidth number
    DummyLink(LinkSpec),
}

/// Default level specs (HGX-class numbers; Calculon-derived as in §V-A).
pub const NVLINK: LinkSpec = LinkSpec { bw: 450e9, lat: 2e-6 };
pub const RACK_SWITCH: LinkSpec = LinkSpec { bw: 50e9, lat: 10e-6 };
/// paper §V-B: "inter-rack connectivity 128 GB/s Ethernet links" with
/// ~20 ms link latency for DCN fallback paths
pub const DCN: LinkSpec = LinkSpec { bw: 128e9, lat: 20e-3 };

pub struct Network {
    pub kind: NetworkKind,
    pub locations: Vec<Location>,
    pub nvlink: LinkSpec,
    rack_links: HashMap<usize, Link>,
    dcn_link: Link,
    dummy_link: Link,
    /// bytes moved per level (metrics)
    pub bytes_intra_platform: f64,
}

impl Network {
    pub fn new(kind: NetworkKind, locations: Vec<Location>) -> Network {
        let racks: Vec<usize> = {
            let mut r: Vec<usize> = locations.iter().map(|l| l.rack).collect();
            r.sort();
            r.dedup();
            r
        };
        Network {
            kind,
            locations,
            nvlink: NVLINK,
            rack_links: racks
                .into_iter()
                .map(|r| (r, Link::new(RACK_SWITCH)))
                .collect(),
            dcn_link: Link::new(DCN),
            dummy_link: Link::new(match kind {
                NetworkKind::DummyLink(spec) => spec,
                _ => LinkSpec { bw: 50e9, lat: 1e-5 },
            }),
            bytes_intra_platform: 0.0,
        }
    }

    /// All clients in one rack/platform — convenience constructor.
    pub fn single_platform(n_clients: usize) -> Network {
        Network::new(
            NetworkKind::Hierarchical,
            (0..n_clients)
                .map(|_| Location { rack: 0, platform: 0 })
                .collect(),
            )
    }

    /// Spread `n_clients` over racks of `per_rack`, platforms of
    /// `per_platform` clients.
    pub fn hierarchy(n_clients: usize, per_platform: usize, per_rack: usize) -> Network {
        let locs = (0..n_clients)
            .map(|i| Location {
                rack: i / per_rack,
                platform: i / per_platform,
            })
            .collect();
        Network::new(NetworkKind::Hierarchical, locs)
    }

    fn effective_bytes(bytes: f64, gran: Granularity) -> f64 {
        match gran {
            Granularity::Full => bytes,
            // layerwise streaming exposes only the last layer's chunk
            Granularity::Layerwise { layers } => bytes / layers.max(1) as f64,
        }
    }

    /// Simulate a transfer; returns the time the data is available at
    /// the destination.
    pub fn transfer(
        &mut self,
        now: SimTime,
        src: usize,
        dst: usize,
        bytes: f64,
        gran: Granularity,
    ) -> SimTime {
        if src == dst || bytes <= 0.0 {
            return now;
        }
        let eff = Self::effective_bytes(bytes, gran);
        if let NetworkKind::DummyLink(_) = self.kind {
            return self.dummy_link.transfer(now, eff);
        }
        let (a, b) = (self.locations[src], self.locations[dst]);
        if a.platform == b.platform && a.rack == b.rack {
            // NVLink fabric is point-to-point per platform — modeled
            // uncontended (full bisection within the box).
            self.bytes_intra_platform += eff;
            now + SimTime::from_secs(self.nvlink.duration(eff))
        } else if a.rack == b.rack {
            self.rack_links
                .get_mut(&a.rack)
                .expect("rack link")
                .transfer(now, eff)
        } else {
            // cross-rack: source rack uplink -> DCN spine; model the
            // spine as the bottleneck (racks' uplinks folded into it)
            self.dcn_link.transfer(now, eff)
        }
    }

    /// Pure estimate without mutating contention state (router lookahead).
    pub fn estimate(&self, src: usize, dst: usize, bytes: f64, gran: Granularity) -> f64 {
        if src == dst || bytes <= 0.0 {
            return 0.0;
        }
        let eff = Self::effective_bytes(bytes, gran);
        if let NetworkKind::DummyLink(spec) = self.kind {
            return spec.duration(eff);
        }
        let (a, b) = (self.locations[src], self.locations[dst]);
        if a.platform == b.platform && a.rack == b.rack {
            self.nvlink.duration(eff)
        } else if a.rack == b.rack {
            RACK_SWITCH.duration(eff)
        } else {
            DCN.duration(eff)
        }
    }

    pub fn bytes_on_dcn(&self) -> f64 {
        self.dcn_link.bytes_total
    }

    /// Rack coordinate of a client — the sharded coordinator's domain
    /// partition key ([`crate::coordinator::shard`]).
    pub fn rack_of(&self, client: usize) -> usize {
        self.locations[client].rack
    }

    /// Conservative-window lookahead for sharded execution: the minimum
    /// latency any cross-domain interaction pays. Domains are unions of
    /// whole racks, so every cross-domain hop crosses racks and rides
    /// the DCN spine — its one-way link latency lower-bounds the time
    /// between a hand-off leaving one domain and arriving in another,
    /// and is therefore a safe synchronization window width.
    pub fn lookahead(&self) -> SimTime {
        SimTime::from_secs(self.dcn_link.spec.lat)
    }

    /// Price a cross-rack transfer on the shared DCN spine without
    /// naming endpoints — the sharded orchestrator's window-barrier
    /// replay path, which re-prices deferred cross-domain hops in
    /// global `(time, domain, seq)` order so the spine's FIFO
    /// contention state mutates exactly as the serial run's would.
    /// Callers guarantee the hop is genuinely cross-rack and non-empty.
    pub fn dcn_transfer(&mut self, now: SimTime, bytes: f64, gran: Granularity) -> SimTime {
        debug_assert!(bytes > 0.0, "cross-rack hop with no payload");
        let eff = Self::effective_bytes(bytes, gran);
        self.dcn_link.transfer(now, eff)
    }

    /// Bytes carried by one rack's switch (0 for unknown racks).
    pub fn bytes_on_rack(&self, rack: usize) -> f64 {
        self.rack_links
            .get(&rack)
            .map(|l| l.bytes_total)
            .unwrap_or(0.0)
    }

    /// Utilization of one rack's switch over `[0, horizon]`.
    pub fn rack_utilization(&self, rack: usize, horizon: SimTime) -> f64 {
        self.rack_links
            .get(&rack)
            .map(|l| l.utilization(horizon))
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_rack_net() -> Network {
        // 8 clients: platforms of 2, racks of 4
        Network::hierarchy(8, 2, 4)
    }

    #[test]
    fn level_selection() {
        let mut n = two_rack_net();
        let gb = 1e9;
        let t_plat = n.transfer(SimTime::ZERO, 0, 1, gb, Granularity::Full);
        let t_rack = n.transfer(SimTime::ZERO, 0, 2, gb, Granularity::Full);
        let t_dcn = n.transfer(SimTime::ZERO, 0, 7, gb, Granularity::Full);
        assert!(t_plat < t_rack, "nvlink {t_plat} < rack {t_rack}");
        assert!(t_rack < t_dcn, "rack {t_rack} < dcn {t_dcn}");
        // DCN latency (~20ms) dominates its alpha term
        assert!(t_dcn.as_secs() > 0.02);
    }

    #[test]
    fn layerwise_hides_most_of_the_transfer() {
        let n = two_rack_net();
        let full = n.estimate(0, 2, 80e9, Granularity::Full);
        let lw = n.estimate(0, 2, 80e9, Granularity::Layerwise { layers: 80 });
        assert!(lw < full / 20.0, "full={full} layerwise={lw}");
    }

    #[test]
    fn rack_links_contend_independently() {
        let mut n = two_rack_net();
        let gb = 10e9;
        // two transfers on rack 0's switch queue up...
        let a = n.transfer(SimTime::ZERO, 0, 2, gb, Granularity::Full);
        let b = n.transfer(SimTime::ZERO, 1, 3, gb, Granularity::Full);
        assert!(b > a);
        // ...but rack 1's switch is idle
        let c = n.transfer(SimTime::ZERO, 4, 6, gb, Granularity::Full);
        assert_eq!(c, a);
    }

    #[test]
    fn dummy_link_serializes_everything() {
        let spec = LinkSpec { bw: 1e9, lat: 0.0 };
        let mut n = Network::new(
            NetworkKind::DummyLink(spec),
            (0..4).map(|i| Location { rack: i, platform: i }).collect(),
        );
        let a = n.transfer(SimTime::ZERO, 0, 1, 1e9, Granularity::Full);
        let b = n.transfer(SimTime::ZERO, 2, 3, 1e9, Granularity::Full);
        assert!((a.as_secs() - 1.0).abs() < 1e-9);
        assert!((b.as_secs() - 2.0).abs() < 1e-9, "dummy link must serialize");
    }

    #[test]
    fn self_transfer_free() {
        let mut n = two_rack_net();
        assert_eq!(
            n.transfer(SimTime::from_secs(3.0), 2, 2, 1e12, Granularity::Full),
            SimTime::from_secs(3.0)
        );
    }

    #[test]
    fn path_selection_routes_bytes_to_exactly_one_level() {
        // 8 clients, platforms of 2, racks of 4:
        //   0↔1 same platform (NVLink), 0↔2 same rack (switch),
        //   0↔7 cross-rack (DCN spine)
        let gb = 1e9;
        // same platform: only the intra-platform counter moves
        let mut n = two_rack_net();
        n.transfer(SimTime::ZERO, 0, 1, gb, Granularity::Full);
        assert_eq!(n.bytes_intra_platform, gb);
        assert_eq!(n.bytes_on_rack(0), 0.0);
        assert_eq!(n.bytes_on_dcn(), 0.0);
        // same rack, different platform: only rack 0's switch moves
        let mut n = two_rack_net();
        n.transfer(SimTime::ZERO, 0, 2, gb, Granularity::Full);
        assert_eq!(n.bytes_intra_platform, 0.0);
        assert_eq!(n.bytes_on_rack(0), gb);
        assert_eq!(n.bytes_on_rack(1), 0.0, "rack 1 uninvolved");
        assert_eq!(n.bytes_on_dcn(), 0.0);
        // cross-rack: only the DCN spine moves
        let mut n = two_rack_net();
        n.transfer(SimTime::ZERO, 0, 7, gb, Granularity::Full);
        assert_eq!(n.bytes_intra_platform, 0.0);
        assert_eq!(n.bytes_on_rack(0), 0.0);
        assert_eq!(n.bytes_on_rack(1), 0.0);
        assert_eq!(n.bytes_on_dcn(), gb);
        // unknown rack reads as idle instead of panicking
        assert_eq!(n.bytes_on_rack(99), 0.0);
    }

    #[test]
    fn estimate_is_side_effect_free_at_every_level() {
        let n = two_rack_net();
        for (src, dst, level_spec) in
            [(0usize, 1usize, NVLINK), (0, 2, RACK_SWITCH), (0, 7, DCN)]
        {
            let est = n.estimate(src, dst, 1e9, Granularity::Full);
            assert!(
                (est - level_spec.duration(1e9)).abs() < 1e-12,
                "{src}->{dst}: {est}"
            );
        }
        // no contention state was mutated by estimates
        assert_eq!(n.bytes_intra_platform, 0.0);
        assert_eq!(n.bytes_on_rack(0), 0.0);
        assert_eq!(n.bytes_on_dcn(), 0.0);
    }

    #[test]
    fn rack_utilization_windows_account_carried_bytes() {
        let mut n = two_rack_net();
        // 50 GB/s rack switch: 25 GB occupies it for 0.5 s
        n.transfer(SimTime::ZERO, 0, 2, 25e9, Granularity::Full);
        let u1 = n.rack_utilization(0, SimTime::from_secs(1.0));
        assert!((u1 - 0.5).abs() < 1e-9, "u1={u1}");
        // a second transfer doubles the carried bytes in the window
        n.transfer(SimTime::ZERO, 1, 3, 25e9, Granularity::Full);
        let u2 = n.rack_utilization(0, SimTime::from_secs(1.0));
        assert!((u2 - 1.0).abs() < 1e-9, "u2={u2}");
        // a wider window dilutes utilization proportionally
        let u4 = n.rack_utilization(0, SimTime::from_secs(4.0));
        assert!((u4 - 0.25).abs() < 1e-9, "u4={u4}");
        // idle racks and unknown racks read zero
        assert_eq!(n.rack_utilization(1, SimTime::from_secs(1.0)), 0.0);
        assert_eq!(n.rack_utilization(9, SimTime::from_secs(1.0)), 0.0);
    }

    #[test]
    fn concurrent_kv_migrations_serialize_on_shared_links() {
        // two prefill→decode KV hand-offs of 10 GB each on rack 0's
        // 50 GB/s switch, issued at the same instant: FIFO serialization
        // means the second starts at the first's busy-until instead of
        // overlapping
        let mut n = two_rack_net();
        let kv = 10e9;
        let a = n.transfer(SimTime::ZERO, 0, 2, kv, Granularity::Full);
        let b = n.transfer(SimTime::ZERO, 1, 3, kv, Granularity::Full);
        let serialize = kv / RACK_SWITCH.bw;
        assert!((a.as_secs() - (serialize + RACK_SWITCH.lat)).abs() < 1e-9, "{a}");
        assert!((b.as_secs() - (2.0 * serialize + RACK_SWITCH.lat)).abs() < 1e-9, "{b}");
        assert_eq!(n.bytes_on_rack(0), 2.0 * kv);
        // the contended window is fully busy carrying both migrations
        let horizon = SimTime::from_secs(2.0 * serialize);
        assert!((n.rack_utilization(0, horizon) - 1.0).abs() < 1e-9);
        // a cross-rack migration rides the DCN spine, not the rack
        // switch, so it does not extend rack 0's queue
        let c = n.transfer(SimTime::ZERO, 0, 7, kv, Granularity::Full);
        assert!((c.as_secs() - (kv / DCN.bw + DCN.lat)).abs() < 1e-9, "{c}");
        assert_eq!(n.bytes_on_rack(0), 2.0 * kv, "unchanged by the DCN hop");
        assert_eq!(n.bytes_on_dcn(), kv);
        // zero-byte hand-off (nothing prefilled): free and uncounted
        let now = SimTime::from_secs(9.0);
        assert_eq!(n.transfer(now, 0, 2, 0.0, Granularity::Full), now);
        assert_eq!(n.bytes_on_rack(0), 2.0 * kv);
    }

    #[test]
    fn layerwise_migration_charges_only_exposed_chunk() {
        // layerwise-overlapped migration: compute hides all but the
        // final layer's chunk, so the link carries bytes/layers
        let mut n = two_rack_net();
        n.transfer(SimTime::ZERO, 0, 2, 80e9, Granularity::Layerwise { layers: 80 });
        assert_eq!(n.bytes_on_rack(0), 1e9);
    }

    #[test]
    fn estimate_matches_uncontended_transfer() {
        let mut n = two_rack_net();
        let est = n.estimate(0, 2, 5e9, Granularity::Full);
        let fin = n.transfer(SimTime::ZERO, 0, 2, 5e9, Granularity::Full);
        assert!((est - fin.as_secs()).abs() < 1e-9);
    }
}
