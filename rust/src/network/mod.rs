//! Global communication modeling (paper §III-B.2).
//!
//! The paper delegates multi-level interconnect simulation to astra-sim;
//! this module is the in-repo substitute: a hierarchical α+β model
//! (NVLink intra-platform, InfiniBand/PCIe intra-rack, Ethernet DCN
//! inter-rack) with per-link busy-until contention, plus the "dummy
//! single link" model splitwise-sim uses — both are needed to reproduce
//! the Fig 5 validation gap.

pub mod link;
pub mod topology;

pub use link::{Link, LinkSpec};
pub use topology::{Granularity, Location, Network, NetworkKind};
