//! NPU / CPU / memory-node hardware specifications.
//!
//! "We use the term NPU to refer to these hardware components" (§III).
//! Numbers for the CPUs come straight from the paper's Fig 9 setup
//! (Grace-inspired / Sapphire-Rapids-inspired); GPU numbers are the public
//! spec sheets. Memory-node tiers (Fig 14 configs A/B/C) live in
//! `memory::storage`.

/// One hardware device (GPU, CPU socket, or accelerator).
#[derive(Debug, Clone, PartialEq)]
pub struct NpuSpec {
    pub name: &'static str,
    /// peak dense matmul throughput, FLOP/s (bf16 for GPUs, fp32 for CPUs)
    pub peak_flops: f64,
    /// main-memory bandwidth, B/s (HBM / LPDDR / DDR)
    pub mem_bw: f64,
    /// device memory capacity, bytes
    pub mem_capacity: f64,
    /// board power at full load, W
    pub tdp_w: f64,
    /// idle power, W
    pub idle_w: f64,
    /// scale-up link bandwidth per device (NVLink etc.), B/s
    pub link_bw: f64,
    /// scale-up link latency, s
    pub link_lat: f64,
    /// host/PCIe bandwidth, B/s
    pub pcie_bw: f64,
}

impl NpuSpec {
    /// Memory left for KV cache after a TP-sharded copy of `weight_bytes`.
    pub fn kv_budget(&self, weight_bytes: f64, tp: usize) -> f64 {
        // ~10% reserved for activations/fragmentation (vLLM-like).
        (self.mem_capacity * 0.9 - weight_bytes / tp as f64).max(0.0)
    }
}

/// Nvidia H100 SXM5: 989 TF bf16 dense, 80 GB HBM3 @ 3.35 TB/s, NVLink4
/// 900 GB/s, 700 W.
pub const H100: NpuSpec = NpuSpec {
    name: "h100",
    peak_flops: 989e12,
    mem_bw: 3.35e12,
    mem_capacity: 80e9,
    tdp_w: 700.0,
    idle_w: 90.0,
    link_bw: 900e9,
    link_lat: 2.0e-6,
    pcie_bw: 64e9,
};

/// Nvidia A100 SXM4: 312 TF bf16, 80 GB HBM2e @ 2.04 TB/s, 400 W.
pub const A100: NpuSpec = NpuSpec {
    name: "a100",
    peak_flops: 312e12,
    mem_bw: 2.04e12,
    mem_capacity: 80e9,
    tdp_w: 400.0,
    idle_w: 60.0,
    link_bw: 600e9,
    link_lat: 2.5e-6,
    pcie_bw: 32e9,
};

/// "Large CPU (Grace-inspired): 14.2 TFLOPs single-precision, LPDDR5X,
/// 1 TB @ 768 GB/s" (paper Fig 9 setup).
pub const GRACE_CPU: NpuSpec = NpuSpec {
    name: "grace-cpu",
    peak_flops: 14.2e12,
    mem_bw: 768e9,
    mem_capacity: 1e12,
    tdp_w: 500.0,
    idle_w: 150.0,
    link_bw: 450e9, // NVLink-C2C
    link_lat: 3.0e-6,
    pcie_bw: 64e9,
};

/// "Small CPU (Sapphire-Rapids-inspired): 6.27 TFLOPs, DDR5 8-channel,
/// 4 TB @ 307.2 GB/s" (paper Fig 9 setup).
pub const SPR_CPU: NpuSpec = NpuSpec {
    name: "spr-cpu",
    peak_flops: 6.27e12,
    mem_bw: 307.2e9,
    mem_capacity: 4e12,
    tdp_w: 350.0,
    idle_w: 100.0,
    link_bw: 0.0,
    link_lat: 0.0,
    pcie_bw: 32e9,
};

/// Registry lookup by name.
pub fn npu(name: &str) -> Option<NpuSpec> {
    let key = name.to_ascii_lowercase();
    Some(match key.as_str() {
        "h100" => H100,
        "a100" => A100,
        "grace-cpu" | "grace" | "large-cpu" => GRACE_CPU,
        "spr-cpu" | "spr" | "small-cpu" | "sapphire-rapids" => SPR_CPU,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::LLAMA3_70B;

    #[test]
    fn kv_budget_accounts_for_tp_sharding() {
        // 70B fp8 = 70.6 GB weights; TP2 → 35.3 GB/GPU → ~36.7 GB KV left
        let b2 = H100.kv_budget(LLAMA3_70B.weight_bytes(), 2);
        assert!(b2 > 30e9 && b2 < 40e9, "b2={b2}");
        // TP8 → 8.8 GB/GPU → ~63 GB KV budget
        let b8 = H100.kv_budget(LLAMA3_70B.weight_bytes(), 8);
        assert!(b8 > 55e9 && b8 < 70e9, "b8={b8}");
        // TP1: 70.6 GB weights on one 80 GB H100 → ~1.4 GB KV, very tight
        let b1 = H100.kv_budget(LLAMA3_70B.weight_bytes(), 1);
        assert!(b1 > 0.0 && b1 < 3e9, "b1={b1}");
    }

    #[test]
    fn registry_lookup() {
        assert_eq!(npu("H100").unwrap().name, "h100");
        assert_eq!(npu("large-cpu").unwrap().name, "grace-cpu");
        assert_eq!(npu("small-cpu").unwrap().name, "spr-cpu");
        assert!(npu("tpu-v9").is_none());
    }

    #[test]
    fn paper_cpu_numbers() {
        assert_eq!(GRACE_CPU.peak_flops, 14.2e12);
        assert_eq!(GRACE_CPU.mem_bw, 768e9);
        assert_eq!(GRACE_CPU.mem_capacity, 1e12);
        assert_eq!(SPR_CPU.peak_flops, 6.27e12);
        assert_eq!(SPR_CPU.mem_bw, 307.2e9);
    }
}
