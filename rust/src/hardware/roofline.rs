//! GenZ-like analytical roofline model for transformer inference steps.
//!
//! This is the simulator's ground-truth hardware model, in the same role
//! the paper gives LLMCompass/GenZ: it (a) generates the synthetic
//! "58K-datapoint hardware trace" that `python/compile/fit.py` fits the
//! polynomial predictor on, (b) backs `RooflinePerfModel` for
//! configurations with no fitted artifact, and (c) serves as the
//! fine-grained "measured" oracle in the Fig 6 fidelity study.
//!
//! Step latency = max(compute time, memory time) + TP collective time +
//! fixed framework overhead. All quantities are per *engine step*
//! (one forward pass over the scheduled batch, vLLM-style).

use super::models::ModelSpec;
use super::npu::NpuSpec;

/// Achieved fraction of peak FLOPs for big GEMM-heavy (prefill) work.
pub const EFF_COMPUTE: f64 = 0.55;
/// Achieved fraction of peak memory bandwidth for streaming (decode) work.
pub const EFF_MEM: f64 = 0.75;
/// Fixed per-step framework overhead (scheduling, kernel launch), seconds.
pub const STEP_OVERHEAD: f64 = 350e-6;

/// A prefill work item in a step: `past` tokens already cached (their KV
/// is read), `new` tokens processed this step (chunked batching sends
/// partial prompts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillItem {
    pub past: f64,
    pub new: f64,
}

/// An LLM engine's hardware shard: model × NPU × tensor-parallel degree.
#[derive(Debug, Clone)]
pub struct LlmCluster {
    pub model: ModelSpec,
    pub npu: NpuSpec,
    pub tp: usize,
}

/// FLOPs / bytes / comm tally for one step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepWork {
    pub flops: f64,
    pub bytes: f64,
    /// tokens whose activations cross the TP allreduce each layer
    pub comm_tokens: f64,
    /// weights are read once per step regardless of batch composition
    pub reads_weights: bool,
}

impl StepWork {
    pub fn add_prefill(&mut self, m: &ModelSpec, it: PrefillItem) {
        // GEMM flops: 2 · params · new_tokens
        self.flops += m.flops_per_token() * it.new;
        // attention: each new token attends over (past + avg preceding new)
        self.flops += it.new * m.attn_flops(it.past + it.new / 2.0);
        // KV: read cached past once, write new
        let kvb = m.kv_bytes_per_token();
        self.bytes += kvb * (it.past + it.new);
        self.comm_tokens += it.new;
        self.reads_weights = true;
    }

    pub fn add_decode(&mut self, m: &ModelSpec, batch: usize, kv_total: f64) {
        let b = batch as f64;
        self.flops += m.flops_per_token() * b;
        self.flops += b * m.attn_flops(kv_total / b.max(1.0));
        // read every cached KV token + write one per sequence
        self.bytes += m.kv_bytes_per_token() * (kv_total + b);
        self.comm_tokens += b;
        self.reads_weights = true;
    }
}

impl LlmCluster {
    pub fn new(model: ModelSpec, npu: NpuSpec, tp: usize) -> LlmCluster {
        assert!(tp >= 1);
        LlmCluster { model, npu, tp }
    }

    /// KV-cache capacity of the shard, in tokens.
    pub fn kv_capacity_tokens(&self) -> f64 {
        self.tp as f64 * self.npu.kv_budget(self.model.weight_bytes(), self.tp)
            / self.model.kv_bytes_per_token()
    }

    /// Ring-allreduce time for the activations of `tokens` tokens,
    /// twice per layer (attention out + MLP out).
    fn tp_comm_time(&self, tokens: f64) -> f64 {
        if self.tp <= 1 || tokens <= 0.0 {
            return 0.0;
        }
        let msg = tokens * self.model.hidden as f64 * 2.0; // bf16 activations
        let n = self.tp as f64;
        let per_ar = 2.0 * (n - 1.0) / n * msg / self.npu.link_bw
            + 2.0 * (n - 1.0) * self.npu.link_lat;
        2.0 * self.model.layers as f64 * per_ar
    }

    /// Latency of one engine step doing `work`.
    pub fn step_time(&self, mut work: StepWork) -> f64 {
        if work.reads_weights {
            work.bytes += self.model.weight_bytes();
        }
        let tp = self.tp as f64;
        let t_compute = work.flops / (EFF_COMPUTE * self.npu.peak_flops * tp);
        let t_memory = work.bytes / (EFF_MEM * self.npu.mem_bw * tp);
        t_compute.max(t_memory) + self.tp_comm_time(work.comm_tokens) + STEP_OVERHEAD
    }

    /// Pure-prefill step (continuous batching prefill phase).
    pub fn prefill_time(&self, items: &[PrefillItem]) -> f64 {
        let mut w = StepWork::default();
        for it in items {
            w.add_prefill(&self.model, *it);
        }
        self.step_time(w)
    }

    /// Pure-decode step for `batch` sequences with `kv_total` cached tokens.
    pub fn decode_time(&self, batch: usize, kv_total: f64) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let mut w = StepWork::default();
        w.add_decode(&self.model, batch, kv_total);
        self.step_time(w)
    }

    /// Mixed step (chunked batching / Splitwise mixed pool): prefill chunks
    /// and decode tokens share one forward pass.
    pub fn mixed_time(
        &self,
        prefill: &[PrefillItem],
        decode_batch: usize,
        decode_kv: f64,
    ) -> f64 {
        let mut w = StepWork::default();
        for it in prefill {
            w.add_prefill(&self.model, *it);
        }
        if decode_batch > 0 {
            w.add_decode(&self.model, decode_batch, decode_kv);
        }
        if !w.reads_weights {
            return 0.0;
        }
        self.step_time(w)
    }

    /// Encoder embedding pass over `tokens` query tokens (RAG clients).
    pub fn embed_time(&self, tokens: f64) -> f64 {
        self.prefill_time(&[PrefillItem {
            past: 0.0,
            new: tokens,
        }])
    }

    /// Achieved compute utilization of a step — drives the power model.
    pub fn step_utilization(&self, work: &StepWork, step_time: f64) -> f64 {
        if step_time <= 0.0 {
            return 0.0;
        }
        (work.flops / (self.npu.peak_flops * self.tp as f64 * step_time)).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::models::{LLAMA3_70B, LLAMA3_8B, MISTRAL_7B};
    use crate::hardware::npu::{A100, GRACE_CPU, H100, SPR_CPU};

    fn l70_tp8() -> LlmCluster {
        LlmCluster::new(LLAMA3_70B, H100, 8)
    }

    #[test]
    fn decode_is_memory_bound_and_sane() {
        let c = l70_tp8();
        // single-sequence decode step on TP8 H100 ≈ 6–12 ms (weights read)
        let t = c.decode_time(1, 1000.0);
        assert!(t > 4e-3 && t < 15e-3, "t={t}");
        // batching 64 sequences barely increases time (memory-bound win)
        let t64 = c.decode_time(64, 64.0 * 1000.0);
        assert!(t64 < 2.5 * t, "t={t} t64={t64}");
    }

    #[test]
    fn prefill_scales_with_tokens_and_is_compute_bound() {
        let c = l70_tp8();
        let t2k = c.prefill_time(&[PrefillItem { past: 0.0, new: 2048.0 }]);
        // 2k-token prefill of a 70B on 8×H100 ≈ 40–120 ms
        assert!(t2k > 30e-3 && t2k < 150e-3, "t2k={t2k}");
        let t4k = c.prefill_time(&[PrefillItem { past: 0.0, new: 4096.0 }]);
        assert!(t4k > 1.7 * t2k && t4k < 2.6 * t2k);
    }

    #[test]
    fn chunked_prefill_total_close_to_monolithic() {
        let c = l70_tp8();
        let mono = c.prefill_time(&[PrefillItem { past: 0.0, new: 4096.0 }]);
        let chunks: f64 = (0..8)
            .map(|i| {
                c.prefill_time(&[PrefillItem {
                    past: (i * 512) as f64,
                    new: 512.0,
                }])
            })
            .sum();
        // chunking pays extra KV re-reads + per-step overhead but stays
        // within ~2× of monolithic prefill
        assert!(chunks > mono && chunks < 2.0 * mono, "mono={mono} chunks={chunks}");
    }

    #[test]
    fn tp_speeds_up_prefill() {
        let tp2 = LlmCluster::new(LLAMA3_70B, H100, 2);
        let tp8 = l70_tp8();
        let it = [PrefillItem { past: 0.0, new: 2048.0 }];
        let (a, b) = (tp2.prefill_time(&it), tp8.prefill_time(&it));
        assert!(a / b > 2.5 && a / b < 4.5, "tp2={a} tp8={b}");
    }

    #[test]
    fn mixed_step_cheaper_than_separate_steps() {
        let c = l70_tp8();
        let pf = [PrefillItem { past: 0.0, new: 512.0 }];
        let sep = c.prefill_time(&pf) + c.decode_time(16, 16_000.0);
        let mixed = c.mixed_time(&pf, 16, 16_000.0);
        assert!(mixed < sep, "mixed={mixed} sep={sep}");
        assert!(mixed > c.prefill_time(&pf));
    }

    #[test]
    fn fig9_embedding_bottleneck_ordering() {
        // Mistral-7B embedding: small CPU ≫ large CPU > A100 (paper Fig 9)
        let spr = LlmCluster::new(MISTRAL_7B, SPR_CPU, 1).embed_time(128.0);
        let grace = LlmCluster::new(MISTRAL_7B, GRACE_CPU, 1).embed_time(128.0);
        let a100 = LlmCluster::new(MISTRAL_7B, A100, 1).embed_time(128.0);
        assert!(spr > grace && grace > a100, "spr={spr} grace={grace} a100={a100}");
        assert!(spr / a100 > 10.0, "offload win should be dramatic");
    }

    #[test]
    fn kv_capacity_tokens_tp8_70b() {
        let c = l70_tp8();
        // ~8 GPUs*72GB-ish usable minus 141 GB weights → ≈1.3M tokens @320KB
        let cap = c.kv_capacity_tokens();
        assert!(cap > 0.8e6 && cap < 2.0e6, "cap={cap}");
    }

    #[test]
    fn empty_steps_cost_nothing() {
        let c = l70_tp8();
        assert_eq!(c.decode_time(0, 0.0), 0.0);
        assert_eq!(c.mixed_time(&[], 0, 0.0), 0.0);
    }

    #[test]
    fn small_model_faster_than_large() {
        let c8 = LlmCluster::new(LLAMA3_8B, H100, 1);
        let c70 = LlmCluster::new(LLAMA3_70B, H100, 8);
        let it = [PrefillItem { past: 0.0, new: 1024.0 }];
        assert!(c8.prefill_time(&it) < c70.prefill_time(&it) * 8.0);
    }
}
