//! Power and energy accounting (paper: "Power consumption is estimated
//! using power numbers generated from GenZ").
//!
//! Linear utilization model: P(util) = P_idle + util · (P_tdp − P_idle)
//! per NPU. Decode-only clients are memory-bound (low compute util), so
//! they burn markedly less power than prefill clients — exactly the
//! mechanism behind the paper's "disaggregated wins throughput/energy"
//! observation (Fig 10).

use super::npu::NpuSpec;

/// Instantaneous power (W) of one NPU at a given compute utilization.
pub fn npu_power(npu: &NpuSpec, util: f64) -> f64 {
    npu.idle_w + util.clamp(0.0, 1.0) * (npu.tdp_w - npu.idle_w)
}

/// Energy (J) for a step of `duration` seconds on `n_npus` devices at
/// compute utilization `util`.
pub fn step_energy(npu: &NpuSpec, n_npus: usize, util: f64, duration: f64) -> f64 {
    npu_power(npu, util) * n_npus as f64 * duration
}

/// Accumulates energy over a simulation run for one client.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    pub busy_joules: f64,
    pub busy_seconds: f64,
    /// wall-clock span covered (for idle accounting)
    pub span_seconds: f64,
}

impl EnergyMeter {
    pub fn record_step(&mut self, npu: &NpuSpec, n_npus: usize, util: f64, duration: f64) {
        self.busy_joules += step_energy(npu, n_npus, util, duration);
        self.busy_seconds += duration;
    }

    /// Total energy including idle draw for the uncovered span.
    pub fn total_joules(&self, npu: &NpuSpec, n_npus: usize) -> f64 {
        let idle = (self.span_seconds - self.busy_seconds).max(0.0);
        self.busy_joules + npu.idle_w * n_npus as f64 * idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::npu::H100;

    #[test]
    fn power_interpolates_idle_to_tdp() {
        assert_eq!(npu_power(&H100, 0.0), H100.idle_w);
        assert_eq!(npu_power(&H100, 1.0), H100.tdp_w);
        let half = npu_power(&H100, 0.5);
        assert!(half > H100.idle_w && half < H100.tdp_w);
        // clamped
        assert_eq!(npu_power(&H100, 7.0), H100.tdp_w);
    }

    #[test]
    fn decode_client_cheaper_than_prefill_client() {
        // memory-bound decode util ~0.05 vs prefill util ~0.55
        let e_dec = step_energy(&H100, 2, 0.05, 1.0);
        let e_pre = step_energy(&H100, 2, 0.55, 1.0);
        assert!(e_dec < 0.5 * e_pre, "dec={e_dec} pre={e_pre}");
    }

    #[test]
    fn meter_adds_idle_energy() {
        let mut m = EnergyMeter::default();
        m.record_step(&H100, 1, 1.0, 1.0);
        m.span_seconds = 3.0;
        let total = m.total_joules(&H100, 1);
        assert!((total - (H100.tdp_w + 2.0 * H100.idle_w)).abs() < 1e-9);
    }
}
