//! Hardware layer: model architectures, NPU spec sheets, the GenZ-like
//! analytical roofline, and the power/energy model (paper §III-E).

pub mod models;
pub mod npu;
pub mod power;
pub mod roofline;

pub use models::{lookup as model_lookup, model, ModelSpec};
pub use npu::{npu, NpuSpec};
pub use roofline::{LlmCluster, PrefillItem, StepWork};
