//! Model architecture registry.
//!
//! Every model the paper's experiments mention: the LLMs served
//! (Llama-2-70B, Llama-3/3.1-70B, Llama-3.1-8B, Bloom-176B), the RAG
//! embedding models (E5-Base, Mistral-7B) and the ~2B guard model used by
//! post-processing clients (toxicity / bias filtering, §III-E.4).

/// Transformer architecture parameters sufficient for roofline math and
/// KV-cache accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// total parameter count
    pub params: f64,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// KV heads (GQA); == heads for MHA models like Bloom.
    pub kv_heads: usize,
    pub d_head: usize,
    /// bytes per parameter. Served decoder LLMs use 1.0 (fp8 weights —
    /// the standard H100 serving configuration, and the only one under
    /// which the paper's H100-TP2 / 70B setup meets a 25 ms TPOT with
    /// room for KV cache; see DESIGN.md §3). KV cache stays fp16.
    pub bytes_per_param: f64,
    /// decoder (true) vs encoder-only embedding model (false)
    pub decoder: bool,
}

impl ModelSpec {
    /// KV-cache bytes for ONE token: K and V, per layer, per KV head,
    /// fp16. E.g. Llama-70B (GQA-8): 2·80·8·128·2 = 320 KiB/token.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.layers as f64 * self.kv_heads as f64 * self.d_head as f64 * 2.0
    }

    /// Weight bytes (per full model; divide by TP degree for a shard).
    pub fn weight_bytes(&self) -> f64 {
        self.params * self.bytes_per_param
    }

    /// Matmul FLOPs to process one token through the whole stack
    /// (≈ 2 · params; attention score/context FLOPs are separate because
    /// they scale with context length).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.params
    }

    /// Attention score+context FLOPs for one new token attending over a
    /// context of `ctx` tokens: QKᵀ and PV each cost
    /// 2 · layers · hidden · ctx.
    pub fn attn_flops(&self, ctx: f64) -> f64 {
        4.0 * self.layers as f64 * (self.heads * self.d_head) as f64 * ctx
    }
}

/// The shipped model roster with its alias table, seeded into the
/// interning registry ([`crate::model`]) on first use. Names and aliases
/// are matched after normalization (case-insensitive, `.`/`_` → `-`), so
/// an alias need only be listed in one spelling. This table is the
/// single source of aliases — `model()` below and every `ModelId` lookup
/// resolve through the registry, and scenario `model_catalog` entries
/// extend the same namespace at runtime.
pub const BUILTIN_MODELS: &[(&ModelSpec, &[&str])] = &[
    (&LLAMA2_70B, &["llama-2-70b"]),
    (&LLAMA3_70B, &["llama-3-70b", "llama3.1-70b", "llama-3.1-70b"]),
    (&LLAMA3_8B, &["llama-3.1-8b", "llama3-8b"]),
    (&BLOOM_176B, &[]),
    (&MISTRAL_7B, &[]),
    (&E5_BASE, &[]),
    (&GUARD_2B, &[]),
];

/// Registry lookup by name (case-insensitive, dashes/dots normalized).
/// Delegates to the interning registry, so runtime-registered catalog
/// models resolve here too.
pub fn model(name: &str) -> Option<ModelSpec> {
    crate::model::ModelId::resolve(name).map(|id| id.spec().clone())
}

/// Like [`model`], but an unknown name is an error that lists every
/// known model name — config/scenario typos are self-explanatory.
pub fn lookup(name: &str) -> anyhow::Result<ModelSpec> {
    crate::model::ModelId::lookup(name).map(|id| id.spec().clone())
}

pub const LLAMA2_70B: ModelSpec = ModelSpec {
    name: "llama2-70b",
    params: 70e9,
    layers: 80,
    hidden: 8192,
    heads: 64,
    kv_heads: 8,
    d_head: 128,
    bytes_per_param: 1.0,
    decoder: true,
};

/// Llama-3-70B and Llama-3.1-70B share the 70B GQA-8 architecture.
pub const LLAMA3_70B: ModelSpec = ModelSpec {
    name: "llama3-70b",
    params: 70.6e9,
    layers: 80,
    hidden: 8192,
    heads: 64,
    kv_heads: 8,
    d_head: 128,
    bytes_per_param: 1.0,
    decoder: true,
};

pub const LLAMA3_8B: ModelSpec = ModelSpec {
    name: "llama3.1-8b",
    params: 8.03e9,
    layers: 32,
    hidden: 4096,
    heads: 32,
    kv_heads: 8,
    d_head: 128,
    bytes_per_param: 1.0,
    decoder: true,
};

/// Bloom uses MHA (112 KV heads) → enormous per-token KV (~3.8 MiB).
pub const BLOOM_176B: ModelSpec = ModelSpec {
    name: "bloom-176b",
    params: 176e9,
    layers: 70,
    hidden: 14336,
    heads: 112,
    kv_heads: 112,
    d_head: 128,
    bytes_per_param: 1.0,
    decoder: true,
};

pub const MISTRAL_7B: ModelSpec = ModelSpec {
    name: "mistral-7b",
    params: 7.24e9,
    layers: 32,
    hidden: 4096,
    heads: 32,
    kv_heads: 8,
    d_head: 128,
    bytes_per_param: 1.0,
    decoder: true,
};

/// E5-Base embedding encoder (~110M, BERT-base shape).
pub const E5_BASE: ModelSpec = ModelSpec {
    name: "e5-base",
    params: 0.11e9,
    layers: 12,
    hidden: 768,
    heads: 12,
    kv_heads: 12,
    d_head: 64,
    bytes_per_param: 2.0,
    decoder: false,
};

/// Small (~2B) LLM used to model toxicity/bias filters in post-processing
/// clients (§III-E.4: "a forward pass on small LLM model (~2B)").
pub const GUARD_2B: ModelSpec = ModelSpec {
    name: "guard-2b",
    params: 2e9,
    layers: 24,
    hidden: 2048,
    heads: 16,
    kv_heads: 16,
    d_head: 128,
    bytes_per_param: 2.0,
    decoder: true,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_match_hand_calcs() {
        // 70B GQA-8: 2 * 80 * 8 * 128 * 2B = 320 KiB per token
        assert_eq!(LLAMA2_70B.kv_bytes_per_token(), 327_680.0);
        // 8B GQA-8: 2 * 32 * 8 * 128 * 2B = 128 KiB
        assert_eq!(LLAMA3_8B.kv_bytes_per_token(), 131_072.0);
        // Bloom MHA: ~3.8 MiB per token — the Fig 5 memory-pressure model
        assert_eq!(BLOOM_176B.kv_bytes_per_token(), 4_014_080.0);
    }

    #[test]
    fn weight_bytes_fp8_serving() {
        assert_eq!(LLAMA2_70B.weight_bytes(), 70e9);
        // encoder/guard models keep fp16
        assert_eq!(E5_BASE.bytes_per_param, 2.0);
    }

    #[test]
    fn lookup_normalizes_names() {
        assert_eq!(model("Llama3.1-70B").unwrap().name, "llama3-70b");
        assert_eq!(model("llama_2_70b").unwrap().name, "llama2-70b");
        assert_eq!(model("E5-Base").unwrap().name, "e5-base");
        assert!(model("gpt-99t").is_none());
    }

    #[test]
    fn unknown_model_error_names_the_roster() {
        let err = lookup("gpt-99t").unwrap_err().to_string();
        assert!(err.contains("unknown model 'gpt-99t'"), "{err}");
        for known in ["llama2-70b", "llama3-70b", "mistral-7b", "guard-2b"] {
            assert!(err.contains(known), "error must list {known}: {err}");
        }
    }

    #[test]
    fn attn_flops_scale_with_ctx() {
        let m = &LLAMA3_8B;
        assert_eq!(m.attn_flops(2000.0), 2.0 * m.attn_flops(1000.0));
    }

    #[test]
    fn encoder_flag() {
        assert!(!E5_BASE.decoder);
        assert!(MISTRAL_7B.decoder);
    }
}
