//! Core-simulator speed benchmarks (`hermes bench`, `cargo bench
//! --bench core_speed`).
//!
//! The ROADMAP's north star is a simulator that handles production-scale
//! traffic "as fast as the hardware allows"; peers treat simulation
//! speed as a first-class deliverable (LLMServingSim, Frontier). This
//! harness runs the `scenarios/bench_*.json` scenarios — parameterized
//! large-scale single runs of 50k–1M requests across LLM / RAG /
//! KV-retrieval pools — and reports wall-clock, events/second, peak
//! pool sizes, request-pool operation counters and the O(in-flight)
//! memory columns (`peak_resident_slots` / `resident_bytes_est` /
//! `retired`), writing `BENCH_core.json` so every subsequent PR has a
//! perf trajectory to defend.
//!
//! Every scenario runs in the shipping configuration first: the dense
//! arena-backed [`RequestPool`] with incremental O(1) load accounting
//! ([`LoadMode::Incremental`]), in the scenario's [`ExecMode`]
//! (`extras.stream` / `extras.retire`). Three baselines quantify the
//! hot-path refactors:
//!
//! * **hashmap pool** ([`PoolBackend::Map`], incremental routing) — the
//!   pre-arena pool; runs whenever the baseline setting is not `off`
//!   and the scenario doesn't set `extras.map_pool: false` (it costs
//!   about as much as the main run). Reported as
//!   `speedup_vs_hashmap_pool`.
//! * **full scan** ([`LoadMode::FullScan`], hashmap pool) — the
//!   pre-incremental-routing path, O(pool × clients) per routing
//!   decision; opt-in via `extras.baseline` or `--baseline on` (hours
//!   at 100k+ scale). Reported as `speedup_vs_full_scan`.
//! * **retirement off** (eager injection, nothing retired) — the
//!   pre-streaming memory behavior, run only for scenarios whose
//!   shipping mode streams or retires; its `peak_resident_slots` is
//!   the whole trace. Reported as `resident_slots_reduction`.
//!
//! One forward-looking configuration rides along: **sharded**
//! (`--shards K`, or the scenario's own `extras.shards`) re-runs the
//! shipping config on K conservative time-window domains
//! ([`crate::coordinator::shard`]). The simulation is bit-identical to
//! the serial shipping run, so the row's `sharded` block and
//! `speedup_vs_serial_sharded` isolate the wall-clock effect of the
//! parallel event loop.
//!
//! See `docs/performance.md`.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::slo::SloLadder;
use crate::coordinator::shard::{run_sharded, Arrivals};
use crate::coordinator::LoadMode;
use crate::metrics::{MetricsSink, RunMetrics};
use crate::scenario::Scenario;
use crate::scheduler::{PoolBackend, RequestPool};
use crate::sim::parallel;
use crate::util::json::{Json, JsonRowWriter};
use crate::workload::request::{CompletionRecord, ReqId};

/// How the run feeds and drains its requests: eager/retained (the
/// pre-streaming default) vs streaming arrivals and/or request
/// retirement. Scenario files opt in via `extras.stream` /
/// `extras.retire` (see `scenarios/bench_llm_1m.json`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecMode {
    /// lazy arrival source (`Coordinator::stream`) instead of upfront
    /// injection — the queue and pool never hold the whole trace
    pub stream: bool,
    /// retire finished requests (`Coordinator::retire`) — pool slots
    /// recycle, resident memory tracks peak in-flight
    pub retire: bool,
    /// streaming metrics: fold each completion into a [`MetricsSink`]
    /// (mergeable quantile sketches + running sums) at retirement time
    /// instead of retaining `CompletionRecord`s — metrics memory stays
    /// O(1) in request count, percentiles carry the sketch's relative
    /// error bound (docs/performance.md "Streaming metrics")
    pub sketch: bool,
}

/// `--metrics` on the bench harness: force a metrics mode across every
/// scenario, or defer to each scenario's `extras.metrics`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsOverride {
    /// the scenario's `extras.metrics` decides (`"exact"` when unset)
    #[default]
    Auto,
    /// exact retained-records metrics everywhere (the oracle)
    Exact,
    /// streaming sketch metrics everywhere
    Sketch,
}

/// Timing and scale counters from one benchmark run.
#[derive(Debug, Clone)]
pub struct BenchRun {
    /// wall-clock seconds spent draining the event queue (streamed
    /// runs: request generation happens inside the loop and is included)
    pub wall_s: f64,
    pub events: u64,
    pub events_per_s: f64,
    /// event-queue high-water mark
    pub peak_queue: usize,
    /// arrived-but-unfinished request high-water mark
    pub peak_inflight: usize,
    pub n_requests: usize,
    pub n_serviced: usize,
    pub n_clients: usize,
    /// simulated seconds covered by the run
    pub makespan_s: f64,
    /// simulated seconds per wall second
    pub sim_rate: f64,
    pub throughput_tok_s: f64,
    /// request-pool reads during the event loop (injection excluded)
    pub pool_reads: u64,
    /// request-pool writes during the event loop (injection excluded)
    pub pool_writes: u64,
    /// allocated arena slots (map backend: live entries)
    pub pool_slots: usize,
    /// high-water mark of client-resident requests (arena occupancy)
    pub pool_peak_resident: usize,
    /// high-water mark of simultaneously stored requests — the
    /// O(in-flight) memory claim as a number (`peak_resident_slots`)
    pub peak_resident_slots: usize,
    /// peak estimated bytes of stored requests (struct + pipeline array)
    pub resident_bytes_est: usize,
    /// requests whose pool slot was freed for reuse during the run
    pub retired: u64,
    /// estimated bytes of resident metrics state at run end: the
    /// streaming sink's sketches (sketch mode, O(1) in request count)
    /// or the retained records + ID vecs + raw sample vecs the exact
    /// collector materializes (O(n))
    pub metrics_bytes_est: usize,
    /// whether this run streamed its metrics through the sketch sink
    pub metrics_sketch: bool,
    /// fraction of injected requests that completed successfully — the
    /// failure-aware companion to throughput (1.0 on fault-free tiers,
    /// below it when crashes/timeouts/shedding eat requests)
    pub goodput: f64,
    /// fault-policy retry re-queues during the run (0 without a fault
    /// plan — see [`crate::fault`])
    pub retries: u64,
    /// requests failed by their deadline expiring
    pub timeouts: u64,
    /// priced network hops (stage hand-offs / KV migrations) — one per
    /// request on disaggregated pipelines
    pub transfers: u64,
    /// bytes carried by those hops (the migration volume on
    /// `bench_disagg_100k`)
    pub transfer_bytes: f64,
    /// effective conservative-window domains the run executed on
    /// (1 = the serial single-queue event loop; >1 only for the
    /// sharded run, see [`crate::coordinator::shard`])
    pub domains: usize,
}

/// One scenario's outcome: the shipping run plus the enabled baselines.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub title: String,
    /// the scenario's execution mode (applied to the shipping run and
    /// the pool/routing baselines alike, so their ratios compare pools,
    /// not modes)
    pub exec: ExecMode,
    /// arena pool + incremental load accounting (the shipping config)
    pub incremental: BenchRun,
    /// `LoadMode::FullScan` + hashmap pool (pre-incremental routing)
    pub baseline: Option<BenchRun>,
    /// hashmap pool + incremental routing (pre-arena pool)
    pub map_pool: Option<BenchRun>,
    /// eager injection + no retirement (the pre-streaming memory
    /// behavior) — only run for scenarios whose shipping mode streams
    /// or retires, so the O(in-flight) claim has an O(total) reference
    pub retained: Option<BenchRun>,
    /// shard count the sharded run was requested with (1 = no sharded
    /// run planned): `--shards K`, else the scenario's `extras.shards`
    pub shards: usize,
    /// the shipping configuration re-run under `--shards K`
    /// (conservative time-window domains, docs/performance.md "Sharded
    /// execution") — bit-identical events/serviced/makespan to
    /// `incremental`, with its own wall clock
    pub sharded: Option<BenchRun>,
}

impl BenchResult {
    /// Full-scan wall-clock / incremental wall-clock (>1 = faster now).
    pub fn speedup(&self) -> Option<f64> {
        self.baseline
            .as_ref()
            .map(|b| b.wall_s / self.incremental.wall_s.max(1e-12))
    }

    /// Hashmap-pool wall-clock / arena wall-clock (>1 = arena faster).
    pub fn pool_speedup(&self) -> Option<f64> {
        self.map_pool
            .as_ref()
            .map(|b| b.wall_s / self.incremental.wall_s.max(1e-12))
    }

    /// Retained-baseline peak slots / shipping-run peak slots
    /// (>1 = streaming+retirement holds fewer requests resident).
    pub fn residency_reduction(&self) -> Option<f64> {
        self.retained.as_ref().map(|b| {
            b.peak_resident_slots as f64 / self.incremental.peak_resident_slots.max(1) as f64
        })
    }

    /// Serial wall-clock / sharded wall-clock (>1 = sharding pays off).
    pub fn shard_speedup(&self) -> Option<f64> {
        self.sharded
            .as_ref()
            .map(|b| self.incremental.wall_s / b.wall_s.max(1e-12))
    }
}

/// Whether to run the baselines alongside each scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// hashmap-pool baseline always; full-scan only where the scenario's
    /// `extras.baseline` (or fast scale) permits it — the full-scan pass
    /// on 100k+ requests takes hours
    Auto,
    On,
    Off,
}

/// Registry names of the shipped benchmark scenarios (`bench_*`).
pub fn bench_scenarios() -> Vec<String> {
    Scenario::list()
        .into_iter()
        .filter(|n| n.starts_with("bench_"))
        .collect()
}

/// Estimated bytes of resident metrics state: the streaming sink's
/// sketches, or — exact mode — the retained completion records, the
/// serviced/failed ID vecs and the raw per-request sample vecs the
/// exact collector materializes. The bench column that proves the
/// sketch path's O(1)-in-request-count claim.
fn metrics_footprint(
    sink: Option<&MetricsSink>,
    n_records: usize,
    n_ids: usize,
    m: &RunMetrics,
) -> usize {
    match sink {
        Some(s) => s.bytes_est(),
        None => {
            n_records * std::mem::size_of::<CompletionRecord>()
                + n_ids * std::mem::size_of::<ReqId>()
                + (m.ttft_samples.len() + m.tpot_samples.len() + m.e2e_samples.len()) * 8
        }
    }
}

/// Run `sc` once under `mode`/`backend`/`exec` and time the event
/// loop. Pool construction happens outside the timed section and the
/// pool counters are reset after injection. Eager runs generate the
/// whole workload outside the clock; streamed runs sample each request
/// lazily *inside* the event loop (that cost is included in the wall
/// clock), while the source's one-time O(n) timestamp pre-advance —
/// replaying the arrival draws to position each class's token rng —
/// happens in `Coordinator::stream`, outside the timed section like
/// eager generation.
pub fn run_once(
    sc: &Scenario,
    fast: bool,
    mode: LoadMode,
    backend: PoolBackend,
    exec: ExecMode,
) -> Result<BenchRun> {
    let scale = sc.scale(fast);
    let entry = sc
        .roster
        .first()
        .context("bench scenario needs a roster entry")?;
    let spec = sc.serving(entry, scale.clients)?;
    let rate = *scale
        .rates
        .first()
        .context("bench scenario needs a rate")?;
    let n_requests = scale.clients * scale.requests_per_client;
    let mix = sc
        .workload(None, n_requests)?
        .scaled(n_requests, rate * spec.pool.n_clients() as f64);
    let n_requests = mix.n_total();

    let mut coord = spec.build()?;
    coord.load_mode = mode;
    coord.pool = RequestPool::with_backend(backend);
    coord.retire = exec.retire;
    if exec.sketch {
        coord.sink = Some(MetricsSink::new(SloLadder::standard()));
    }
    if exec.stream {
        coord.stream(&mix);
    } else {
        coord.inject(mix.generate());
    }
    coord.pool.reset_ops();
    let t0 = Instant::now();
    coord.run();
    let wall = t0.elapsed().as_secs_f64();
    let ops = coord.pool.ops();

    let m = RunMetrics::collect(&coord, &SloLadder::standard());
    let metrics_bytes_est = metrics_footprint(
        coord.sink.as_ref(),
        coord.records.len(),
        coord.serviced.len() + coord.failed.len(),
        &m,
    );
    Ok(BenchRun {
        wall_s: wall,
        events: coord.stats.events,
        events_per_s: coord.stats.events as f64 / wall.max(1e-9),
        peak_queue: coord.stats.peak_queue,
        peak_inflight: coord.stats.peak_inflight,
        n_requests,
        n_serviced: m.n_serviced,
        n_clients: coord.clients.len(),
        makespan_s: m.makespan,
        sim_rate: m.makespan / wall.max(1e-9),
        throughput_tok_s: m.throughput_tok_s,
        pool_reads: ops.reads,
        pool_writes: ops.writes,
        pool_slots: ops.slots,
        pool_peak_resident: ops.peak_resident,
        peak_resident_slots: ops.peak_live,
        resident_bytes_est: ops.peak_bytes_est,
        retired: ops.retired,
        metrics_bytes_est,
        metrics_sketch: exec.sketch,
        goodput: m.n_serviced as f64 / n_requests.max(1) as f64,
        retries: m.retries,
        timeouts: m.timeouts,
        transfers: coord.stats.transfers,
        transfer_bytes: coord.stats.transfer_bytes,
        domains: 1,
    })
}

/// Run the shipping configuration under `--shards K`: the single run is
/// partitioned into conservative time-window domains
/// ([`run_sharded`], docs/performance.md "Sharded execution") and the
/// merged outcome is reported as a [`BenchRun`]. The simulation fields
/// (events, serviced, makespan, transfers) are bit-identical to the
/// serial shipping run; the wall clock is the sharded harness's own.
/// Two measurement caveats vs [`run_once`]: domain coordinators are
/// built inside the timed section (the serial path builds outside it),
/// and the pool counters include injection (there is no post-injection
/// reset hook inside the domain workers) — so pool reads/writes are
/// comparable between sharded rows, not against serial rows.
pub fn run_once_sharded(
    sc: &Scenario,
    fast: bool,
    exec: ExecMode,
    shards: usize,
) -> Result<BenchRun> {
    let scale = sc.scale(fast);
    let entry = sc
        .roster
        .first()
        .context("bench scenario needs a roster entry")?;
    let spec = sc.serving(entry, scale.clients)?;
    let rate = *scale
        .rates
        .first()
        .context("bench scenario needs a rate")?;
    let n_requests = scale.clients * scale.requests_per_client;
    let mix = sc
        .workload(None, n_requests)?
        .scaled(n_requests, rate * spec.pool.n_clients() as f64);
    let n_requests = mix.n_total();

    // the shipping configuration, exactly as run_once sets it up
    let build = || -> Result<_> {
        let mut c = spec.build()?;
        c.load_mode = LoadMode::Incremental;
        c.pool = RequestPool::with_backend(PoolBackend::Arena);
        c.retire = exec.retire;
        if exec.sketch {
            // per-domain sinks; shard::merge folds them back together
            // in ascending domain order
            c.sink = Some(MetricsSink::new(SloLadder::standard()));
        }
        Ok(c)
    };
    // eager generation stays outside the clock, like run_once; streamed
    // runs sample lazily inside their domain workers
    let arrivals = if exec.stream {
        Arrivals::Stream(&mix)
    } else {
        Arrivals::Inject(mix.generate())
    };
    // auxiliary RAG/KV/pre-post tiers count toward n_clients exactly as
    // in the serial row (which reads coord.clients.len())
    let n_clients = build()?.clients.len();
    let t0 = Instant::now();
    let out = run_sharded(build, arrivals, shards)?;
    let wall = t0.elapsed().as_secs_f64();

    let m = RunMetrics::collect_outcome(&out, &SloLadder::standard());
    let metrics_bytes_est = metrics_footprint(
        out.sink.as_ref(),
        out.records.len(),
        out.serviced.len() + out.failed.len(),
        &m,
    );
    let ops = out.pool_ops;
    Ok(BenchRun {
        wall_s: wall,
        events: out.stats.events,
        events_per_s: out.stats.events as f64 / wall.max(1e-9),
        peak_queue: out.stats.peak_queue,
        peak_inflight: out.stats.peak_inflight,
        n_requests,
        n_serviced: m.n_serviced,
        n_clients,
        makespan_s: m.makespan,
        sim_rate: m.makespan / wall.max(1e-9),
        throughput_tok_s: m.throughput_tok_s,
        pool_reads: ops.reads,
        pool_writes: ops.writes,
        pool_slots: ops.slots,
        pool_peak_resident: ops.peak_resident,
        peak_resident_slots: ops.peak_live,
        resident_bytes_est: ops.peak_bytes_est,
        retired: ops.retired,
        metrics_bytes_est,
        metrics_sketch: exec.sketch,
        goodput: m.n_serviced as f64 / n_requests.max(1) as f64,
        retries: m.retries,
        timeouts: m.timeouts,
        transfers: out.stats.transfers,
        transfer_bytes: out.stats.transfer_bytes,
        domains: out.domains,
    })
}

/// One independent benchmark run of a planned scenario: the shipping
/// configuration or one of its baselines. The unit of work the `--jobs`
/// pool dispatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum UnitKind {
    /// arena pool + incremental routing in the scenario's exec mode
    Incremental,
    /// hashmap pool + incremental routing (pre-arena baseline)
    MapPool,
    /// hashmap pool + full-scan routing (pre-incremental baseline)
    FullScan,
    /// eager injection, nothing retired (pre-streaming memory baseline)
    Retained,
    /// the shipping config under `--shards K` (conservative time-window
    /// domains) — bit-identical simulation, its own wall clock
    Sharded,
}

/// A loaded scenario plus the configurations it will run — the
/// what-to-run decisions (`extras`, `--baseline`, scale) made up front
/// so execution is a pure fan-out of independent units.
struct ScenarioPlan {
    sc: Scenario,
    fast: bool,
    exec: ExecMode,
    /// shard count for the sharded unit (1 = none planned)
    shards: usize,
    /// submission order; `Incremental` always first
    units: Vec<UnitKind>,
}

fn plan_scenario(
    name: &str,
    fast: bool,
    baseline: Baseline,
    shards: usize,
    metrics: MetricsOverride,
) -> Result<ScenarioPlan> {
    let sc = Scenario::load(name)?;
    let extras = sc.extras();
    // `--metrics sketch|exact` overrides; otherwise the scenario's
    // `extras.metrics` decides (the 100M tier ships "sketch" — exact
    // metrics would retain 100M CompletionRecords). A typo in the
    // scenario file must not silently change the metrics contract.
    let sketch = match metrics {
        MetricsOverride::Exact => false,
        MetricsOverride::Sketch => true,
        MetricsOverride::Auto => match extras.str_or("metrics", "exact") {
            "exact" => false,
            "sketch" => true,
            other => bail!("scenario '{name}': extras.metrics must be \"sketch\" or \"exact\", got '{other}'"),
        },
    };
    let exec = ExecMode {
        stream: extras.bool_or("stream", false),
        retire: extras.bool_or("retire", false),
        sketch,
    };
    // `--shards K` (K > 1) shards every scenario; otherwise a scenario
    // can opt its own showcase in via `extras.shards` (bench_llm_1m
    // ships with 4, so the default harness records the sharded speedup
    // in BENCH_core.json alongside the serial trajectory)
    let shards = if shards > 1 { shards } else { extras.usize_or("shards", 1) };
    let mut units = vec![UnitKind::Incremental];
    // pre-arena pool: same asymptotics as the shipping run, so it runs
    // by default. Scenarios whose full-scale run is long enough that a
    // doubled wall clock hurts (the 1M tier) opt out via
    // `extras.map_pool: false` — but only at full scale (the stated
    // cost does not exist at fast scale), and never over an explicit
    // `--baseline on`
    let skip_map = !extras.bool_or("map_pool", true)
        && baseline != Baseline::On
        && !sc.use_fast(fast);
    if baseline != Baseline::Off && !skip_map {
        units.push(UnitKind::MapPool);
    }
    let want_full_scan = match baseline {
        Baseline::On => true,
        Baseline::Off => false,
        Baseline::Auto => extras.bool_or("baseline", false) || sc.use_fast(fast),
    };
    if want_full_scan {
        units.push(UnitKind::FullScan);
    }
    // the O(in-flight) reference: eager injection, nothing retired —
    // its peak_resident_slots is the whole trace. Scenarios for which
    // materializing the trace is itself infeasible (the 100M tier: 100M
    // pool slots + 100M retained records) opt out via
    // `extras.retained: false` — but, like map_pool, only at full scale
    // and never over an explicit `--baseline on`
    let skip_retained = !extras.bool_or("retained", true)
        && baseline != Baseline::On
        && !sc.use_fast(fast);
    if (exec.stream || exec.retire) && baseline != Baseline::Off && !skip_retained {
        units.push(UnitKind::Retained);
    }
    if shards > 1 {
        units.push(UnitKind::Sharded);
    }
    Ok(ScenarioPlan { sc, fast, exec, shards, units })
}

fn run_unit(plan: &ScenarioPlan, kind: UnitKind) -> Result<BenchRun> {
    let (mode, backend, exec) = match kind {
        UnitKind::Incremental => (LoadMode::Incremental, PoolBackend::Arena, plan.exec),
        UnitKind::MapPool => (LoadMode::Incremental, PoolBackend::Map, plan.exec),
        UnitKind::FullScan => (LoadMode::FullScan, PoolBackend::Map, plan.exec),
        // the full pre-streaming behavior: eager, nothing retired, exact
        // retained-records metrics — the O(total) reference on both
        // memory axes (pool slots and metrics state)
        UnitKind::Retained => (LoadMode::Incremental, PoolBackend::Arena, ExecMode::default()),
        UnitKind::Sharded => {
            return run_once_sharded(&plan.sc, plan.fast, plan.exec, plan.shards)
        }
    };
    run_once(&plan.sc, plan.fast, mode, backend, exec)
}

/// Benchmark one scenario by registry name or path, serially (the
/// `--jobs 1` oracle path of [`run_scenarios`]). A scenario with
/// `extras.shards` still runs its sharded showcase unit.
pub fn run_scenario(name: &str, fast: bool, baseline: Baseline) -> Result<BenchResult> {
    let mut results =
        run_scenarios(&[name.to_string()], fast, baseline, 1, 1, MetricsOverride::Auto)?;
    Ok(results.pop().expect("one scenario in, one result out"))
}

/// Benchmark every scenario in `names`: plan each scenario's runs
/// (shipping config + enabled baselines), flatten them into one unit
/// list, dispatch on a `jobs`-wide worker pool
/// ([`parallel::run`] — `jobs <= 1` executes inline, serially, in
/// submission order), and reassemble per-scenario results in input
/// order. Every unit is an independent simulation, so the assembled
/// results are bit-identical across job counts (wall-clock timing
/// fields aside) — `rust/tests/parallel_equivalence.rs` pins this.
pub fn run_scenarios(
    names: &[String],
    fast: bool,
    baseline: Baseline,
    jobs: usize,
    shards: usize,
    metrics: MetricsOverride,
) -> Result<Vec<BenchResult>> {
    let plans = names
        .iter()
        .map(|name| plan_scenario(name, fast, baseline, shards, metrics))
        .collect::<Result<Vec<_>>>()?;
    let units: Vec<(usize, UnitKind)> = plans
        .iter()
        .enumerate()
        .flat_map(|(i, p)| p.units.iter().map(move |&k| (i, k)))
        .collect();
    let runs = parallel::run(jobs, units.len(), |u| {
        let (i, kind) = units[u];
        run_unit(&plans[i], kind)
    });

    let mut per_plan: Vec<Vec<(UnitKind, BenchRun)>> = plans.iter().map(|_| Vec::new()).collect();
    for (&(i, kind), run) in units.iter().zip(runs) {
        per_plan[i].push((kind, run?));
    }
    let mut out = Vec::with_capacity(plans.len());
    for (plan, runs) in plans.into_iter().zip(per_plan) {
        let mut incremental = None;
        let mut map_pool = None;
        let mut full_scan = None;
        let mut retained = None;
        let mut sharded = None;
        for (kind, run) in runs {
            match kind {
                UnitKind::Incremental => incremental = Some(run),
                UnitKind::MapPool => map_pool = Some(run),
                UnitKind::FullScan => full_scan = Some(run),
                UnitKind::Retained => retained = Some(run),
                UnitKind::Sharded => sharded = Some(run),
            }
        }
        out.push(BenchResult {
            name: plan.sc.name.clone(),
            title: plan.sc.title.clone(),
            exec: plan.exec,
            incremental: incremental.expect("every plan runs the shipping config"),
            baseline: full_scan,
            map_pool,
            retained,
            shards: plan.shards,
            sharded,
        });
    }
    Ok(out)
}

fn run_to_json(b: &BenchRun) -> Json {
    let mut j = Json::obj();
    j.set("wall_s", b.wall_s)
        .set("events", b.events)
        .set("events_per_s", b.events_per_s)
        .set("peak_event_queue", b.peak_queue)
        .set("peak_inflight_requests", b.peak_inflight)
        .set("n_requests", b.n_requests)
        .set("n_serviced", b.n_serviced)
        .set("n_clients", b.n_clients)
        .set("makespan_s", b.makespan_s)
        .set("sim_seconds_per_wall_second", b.sim_rate)
        .set("throughput_tok_s", b.throughput_tok_s)
        .set("pool_reads", b.pool_reads)
        .set("pool_writes", b.pool_writes)
        .set("pool_slots", b.pool_slots)
        .set("pool_peak_resident", b.pool_peak_resident)
        .set("peak_resident_slots", b.peak_resident_slots)
        .set("resident_bytes_est", b.resident_bytes_est)
        .set("retired", b.retired)
        .set("metrics", if b.metrics_sketch { "sketch" } else { "exact" })
        .set("metrics_bytes_est", b.metrics_bytes_est)
        .set("goodput", b.goodput)
        .set("retries", b.retries)
        .set("timeouts", b.timeouts)
        .set("transfers", b.transfers)
        .set("transfer_gb", b.transfer_bytes / 1e9)
        .set("domains", b.domains);
    j
}

/// Total simulated events across every run in `results` (the shipping
/// configuration and all baselines) — the numerator of the harness's
/// aggregate events/s.
pub fn total_events(results: &[BenchResult]) -> u64 {
    results
        .iter()
        .map(|r| {
            r.incremental.events
                + r.baseline.as_ref().map_or(0, |b| b.events)
                + r.map_pool.as_ref().map_or(0, |b| b.events)
                + r.retained.as_ref().map_or(0, |b| b.events)
                + r.sharded.as_ref().map_or(0, |b| b.events)
        })
        .sum()
}

fn n_runs(results: &[BenchResult]) -> usize {
    results
        .iter()
        .map(|r| {
            1 + r.baseline.is_some() as usize
                + r.map_pool.is_some() as usize
                + r.retained.is_some() as usize
                + r.sharded.is_some() as usize
        })
        .sum()
}

/// One scenario's `BENCH_core.json` row.
fn result_to_json(r: &BenchResult, jobs: usize) -> Json {
    let mut j = Json::obj();
    j.set("name", r.name.clone())
        .set("title", r.title.clone())
        .set("stream", r.exec.stream)
        .set("retire", r.exec.retire)
        // the metrics contract this row ran under: "exact" (retained
        // records, the oracle) or "sketch" (streaming sink, percentiles
        // within the sketch's relative-error bound)
        .set("metrics", if r.exec.sketch { "sketch" } else { "exact" })
        .set("jobs", jobs)
        // requested shard count for the row's sharded run (1 =
        // none ran). scripts/check_bench_regression.py matches
        // rows by name only and deliberately ignores this column
        .set("shards", r.shards)
        .set("incremental", run_to_json(&r.incremental));
    if let Some(b) = &r.sharded {
        j.set("sharded", run_to_json(b));
    }
    if let Some(s) = r.shard_speedup() {
        j.set("speedup_vs_serial_sharded", s);
    }
    if let Some(b) = &r.baseline {
        j.set("full_scan_baseline", run_to_json(b));
    }
    if let Some(s) = r.speedup() {
        j.set("speedup_vs_full_scan", s);
    }
    if let Some(b) = &r.map_pool {
        j.set("hashmap_pool_baseline", run_to_json(b));
    }
    if let Some(s) = r.pool_speedup() {
        j.set("speedup_vs_hashmap_pool", s);
    }
    if let Some(b) = &r.retained {
        j.set("retirement_off_baseline", run_to_json(b));
    }
    if let Some(x) = r.residency_reduction() {
        j.set("resident_slots_reduction", x);
    }
    j
}

/// The trailing nameless `aggregate` entry — total events across every
/// run divided by the harness's elapsed wall clock.
fn aggregate_to_json(results: &[BenchResult], jobs: usize, wall_s: f64) -> Json {
    let events = total_events(results);
    let mut agg = Json::obj();
    agg.set("jobs", jobs)
        .set("runs", n_runs(results))
        .set("events", events)
        .set("wall_s", wall_s)
        .set("aggregate_events_per_s", events as f64 / wall_s.max(1e-9));
    let mut summary = Json::obj();
    summary.set("aggregate", agg);
    summary
}

/// The `BENCH_core.json` document: one row per scenario (each carrying
/// the `jobs` the harness ran with and the per-run wall clocks), plus a
/// trailing `aggregate` entry — total events across every run divided
/// by the harness's elapsed wall clock (`wall_s`). Per-run events/s is
/// flat in job count (each simulation is single-threaded); the
/// aggregate column is where the multicore win shows.
/// `scripts/check_bench_regression.py` keys rows by `name`, so the
/// nameless aggregate entry is invisible to the regression tripwire.
/// `run_and_report` emits the same rows through a [`JsonRowWriter`]
/// instead of materializing this document.
pub fn to_json(results: &[BenchResult], jobs: usize, wall_s: f64) -> Json {
    let mut rows: Vec<Json> = results.iter().map(|r| result_to_json(r, jobs)).collect();
    rows.push(aggregate_to_json(results, jobs, wall_s));
    Json::Arr(rows)
}

/// Run every scenario in `names` on a `jobs`-wide worker pool, print
/// the per-scenario detail, the summary table and the aggregate
/// events/s line, and write the JSON document to `out_path`. Shared by
/// `hermes bench` and `cargo bench --bench core_speed` so the two faces
/// of the harness cannot drift apart.
pub fn run_and_report(
    names: &[String],
    fast: bool,
    baseline: Baseline,
    jobs: usize,
    shards: usize,
    metrics: MetricsOverride,
    out_path: &str,
) -> Result<Vec<BenchResult>> {
    for name in names {
        println!(
            "benchmarking '{name}'{}{}{}{} ...",
            if fast { " (fast scale)" } else { "" },
            if jobs > 1 { format!(" [jobs={jobs}]") } else { String::new() },
            if shards > 1 { format!(" [shards={shards}]") } else { String::new() },
            match metrics {
                MetricsOverride::Auto => "",
                MetricsOverride::Exact => " [metrics=exact]",
                MetricsOverride::Sketch => " [metrics=sketch]",
            }
        );
    }
    let t0 = Instant::now();
    let results = run_scenarios(names, fast, baseline, jobs, shards, metrics)?;
    let batch_wall = t0.elapsed().as_secs_f64();
    for r in &results {
        let inc = &r.incremental;
        println!("'{}' — {}:", r.name, r.title);
        println!(
            "  {} requests on {} clients: {:.3}s wall, {} events ({:.0} events/s, {:.1} sim-s/wall-s)",
            inc.n_requests, inc.n_clients, inc.wall_s, inc.events, inc.events_per_s, inc.sim_rate
        );
        println!(
            "  peak event queue {}  peak in-flight {}  serviced {}/{}",
            inc.peak_queue, inc.peak_inflight, inc.n_serviced, inc.n_requests
        );
        if inc.retries + inc.timeouts > 0 || inc.goodput < 1.0 {
            println!(
                "  faults: goodput {:.1}%  {} retries  {} timeouts",
                inc.goodput * 100.0,
                inc.retries,
                inc.timeouts
            );
        }
        println!(
            "  pool: {} reads  {} writes  {} slots  peak resident {}",
            inc.pool_reads, inc.pool_writes, inc.pool_slots, inc.pool_peak_resident
        );
        println!(
            "  memory: peak {} resident slots (~{:.1} MiB est){}{}",
            inc.peak_resident_slots,
            inc.resident_bytes_est as f64 / (1024.0 * 1024.0),
            if r.exec.stream { "  [streamed]" } else { "" },
            if r.exec.retire {
                format!("  [{} retired]", inc.retired)
            } else {
                String::new()
            }
        );
        println!(
            "  metrics: {} (~{:.1} KiB resident state)",
            if inc.metrics_sketch { "sketch" } else { "exact" },
            inc.metrics_bytes_est as f64 / 1024.0
        );
        if let Some(b) = &r.retained {
            println!(
                "  retirement-off baseline: peak {} resident slots (~{:.1} MiB est) -> {:.0}x residency reduction",
                b.peak_resident_slots,
                b.resident_bytes_est as f64 / (1024.0 * 1024.0),
                r.residency_reduction().unwrap_or(0.0)
            );
        }
        if let Some(b) = &r.map_pool {
            println!(
                "  hashmap-pool baseline: {:.3}s wall ({:.0} events/s) -> {:.2}x arena speedup",
                b.wall_s,
                b.events_per_s,
                r.pool_speedup().unwrap_or(0.0)
            );
        }
        if let Some(b) = &r.baseline {
            println!(
                "  full-scan baseline: {:.3}s wall ({:.0} events/s) -> {:.1}x speedup",
                b.wall_s,
                b.events_per_s,
                r.speedup().unwrap_or(0.0)
            );
        }
        if let Some(b) = &r.sharded {
            println!(
                "  sharded ({} of {} requested domains): {:.3}s wall ({:.0} events/s) -> {:.2}x vs serial, peak {} resident slots",
                b.domains,
                r.shards,
                b.wall_s,
                b.events_per_s,
                r.shard_speedup().unwrap_or(0.0),
                b.peak_resident_slots
            );
        }
    }

    let mut table = crate::util::bench::Table::new(&[
        "scenario", "requests", "clients", "wall(s)", "events/s", "sim-s/wall-s", "peak queue",
        "peak slots", "retired", "goodput", "shards", "vs hashmap", "vs full-scan",
    ]);
    for r in &results {
        table.row(&[
            r.name.clone(),
            r.incremental.n_requests.to_string(),
            r.incremental.n_clients.to_string(),
            format!("{:.3}", r.incremental.wall_s),
            format!("{:.0}", r.incremental.events_per_s),
            format!("{:.1}", r.incremental.sim_rate),
            r.incremental.peak_queue.to_string(),
            r.incremental.peak_resident_slots.to_string(),
            r.incremental.retired.to_string(),
            format!("{:.3}", r.incremental.goodput),
            // the sharded run's effective domains and wall-clock ratio
            // (the serial shipping row is always the columns to the left)
            r.sharded
                .as_ref()
                .map(|b| format!("{} ({:.2}x)", b.domains, r.shard_speedup().unwrap_or(0.0)))
                .unwrap_or_else(|| "-".to_string()),
            r.pool_speedup()
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".to_string()),
            r.speedup().map(|s| format!("{s:.1}x")).unwrap_or_else(|| "-".to_string()),
        ]);
    }
    table.print();

    let events = total_events(&results);
    println!(
        "aggregate: {} runs, {} events in {:.3}s wall ({:.0} events/s, jobs={})",
        n_runs(&results),
        events,
        batch_wall,
        events as f64 / batch_wall.max(1e-9),
        jobs
    );

    // stream rows to the file one at a time instead of materializing
    // the whole document (`to_json(..).to_pretty()` holds every row
    // twice — as Json values and as the rendered string); byte-identical
    // output, see `JsonRowWriter`
    let file =
        std::fs::File::create(out_path).with_context(|| format!("creating {out_path}"))?;
    let mut w = JsonRowWriter::new(std::io::BufWriter::new(file));
    for r in &results {
        w.push(&result_to_json(r, jobs))
            .with_context(|| format!("writing {out_path}"))?;
    }
    w.push(&aggregate_to_json(&results, jobs, batch_wall))
        .with_context(|| format!("writing {out_path}"))?;
    w.finish().with_context(|| format!("writing {out_path}"))?;
    println!("bench results -> {out_path}");
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_registry_has_scenarios() {
        let names = bench_scenarios();
        assert!(
            names.iter().any(|n| n == "bench_llm_50k"),
            "missing bench_llm_50k in {names:?}"
        );
        assert!(names.iter().any(|n| n == "bench_mixed_100k"));
        assert!(names.iter().any(|n| n == "bench_kv_200k"));
        assert!(names.iter().any(|n| n == "bench_llm_1m"));
        assert!(names.iter().any(|n| n == "bench_llm_100m"));
        assert!(names.iter().any(|n| n == "bench_disagg_100k"));
        assert!(names.iter().any(|n| n == "bench_faults_100k"));
    }

    #[test]
    fn fault_bench_reports_goodput_and_shards_identically() {
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        // fast scale of the robustness tier: a 1P/1D pool whose decode
        // client crashes for a third of the run, so the fault plan must
        // visibly eat requests. Baseline::Off keeps this a two-run smoke
        // (shipping + the scenario's own sharded unit).
        let r = run_scenarios(
            &["bench_faults_100k".to_string()],
            true,
            Baseline::Off,
            1,
            1,
            MetricsOverride::Auto,
        )
        .unwrap()
        .pop()
        .unwrap();
        let inc = &r.incremental;
        assert!(inc.n_serviced < inc.n_requests, "the crash window must eat requests");
        assert!(inc.goodput < 1.0, "goodput must reflect the losses");
        assert!(inc.goodput > 0.3, "most requests still complete");
        assert!(inc.retries > 0, "transient failures must be retried");
        // the sharded run replays the same fault schedule bit-identically
        // (the full differential lives in rust/tests/fault_equivalence.rs)
        let sh = r.sharded.as_ref().expect("fault tier ships a sharded run");
        assert_eq!(sh.events, inc.events);
        assert_eq!(sh.n_serviced, inc.n_serviced);
        assert_eq!(sh.makespan_s, inc.makespan_s);
        assert_eq!(sh.goodput, inc.goodput);
        assert_eq!(sh.retries, inc.retries);
        assert_eq!(sh.timeouts, inc.timeouts);
        // the failure-aware columns land in the BENCH_core.json row
        let j = to_json(&[r], 1, 0.5);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        let col = |k: &str| row.at(&["incremental", k]).and_then(|x| x.as_f64());
        assert!(col("goodput").unwrap() < 1.0);
        assert!(col("retries").unwrap() > 0.0);
        assert!(col("timeouts").is_some());
    }

    #[test]
    fn hundred_million_tier_plan_drops_o_total_units() {
        // full scale: no retained baseline (100M materialized requests),
        // no map-pool baseline, sketch metrics from extras.metrics
        let plan = plan_scenario("bench_llm_100m", false, Baseline::Auto, 1, MetricsOverride::Auto)
            .unwrap();
        assert!(plan.exec.sketch, "100m tier ships sketch metrics");
        assert!(plan.exec.stream && plan.exec.retire);
        assert_eq!(plan.shards, 4);
        assert!(!plan.units.contains(&UnitKind::Retained), "retained baseline must be skipped");
        assert!(!plan.units.contains(&UnitKind::MapPool));
        assert!(!plan.units.contains(&UnitKind::FullScan));
        assert!(plan.units.contains(&UnitKind::Sharded));
        // fast scale keeps every baseline so CI still exercises them
        let fast = plan_scenario("bench_llm_100m", true, Baseline::Auto, 1, MetricsOverride::Auto)
            .unwrap();
        assert!(fast.units.contains(&UnitKind::Retained));
        assert!(fast.units.contains(&UnitKind::MapPool));
        // and --metrics exact overrides the scenario's sketch default
        let exact =
            plan_scenario("bench_llm_100m", false, Baseline::Auto, 1, MetricsOverride::Exact)
                .unwrap();
        assert!(!exact.exec.sketch);
    }

    #[test]
    fn disagg_bench_counts_migration_bytes() {
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        // fast scale of the disaggregation tier: 1 prefill + 1 decode
        // client, every request crossing the network exactly once
        let r = run_scenario("bench_disagg_100k", true, Baseline::Auto).unwrap();
        let inc = r.incremental.clone();
        assert_eq!(inc.n_serviced, inc.n_requests);
        assert_eq!(inc.transfers as usize, inc.n_requests, "one migration per request");
        assert!(inc.transfer_bytes > 0.0, "migrations carry the prefilled KV");
        // routing modes and pool backends must not change the migration
        // accounting
        for b in [r.baseline.as_ref(), r.map_pool.as_ref()].into_iter().flatten() {
            assert_eq!(b.transfers, inc.transfers);
            assert_eq!(b.transfer_bytes, inc.transfer_bytes);
        }
        // ... and neither may domain sharding: the scenario ships
        // extras.shards=2, splitting the prefill and decode racks into
        // two conservative-window domains whose cross-domain KV
        // migrations are priced at the window barrier — bit-identically
        assert_eq!(r.shards, 2);
        let sh = r.sharded.as_ref().expect("disagg tier ships a sharded run");
        assert_eq!(sh.domains, 2, "prefill/decode racks must split into two domains");
        assert_eq!(sh.events, inc.events);
        assert_eq!(sh.n_serviced, inc.n_serviced);
        assert_eq!(sh.makespan_s, inc.makespan_s);
        assert_eq!(sh.transfers, inc.transfers);
        assert_eq!(sh.transfer_bytes, inc.transfer_bytes);
        // the migration-byte columns land in the BENCH_core.json row
        let j = to_json(&[r], 1, 0.5);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(
            row.at(&["incremental", "transfers"]).and_then(|j| j.as_f64()),
            Some(inc.transfers as f64)
        );
        assert!(
            row.at(&["incremental", "transfer_gb"])
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0)
                > 0.0
        );
    }

    #[test]
    fn million_request_tier_stays_o_inflight_at_fast_scale() {
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        // fast scale of the 1M tier: same shape, 10k requests. The
        // acceptance bound — peak resident slots ≤ 5% of the trace —
        // must hold here; the full-scale number lands in BENCH_core.json
        let r = run_scenario("bench_llm_1m", true, Baseline::Auto).unwrap();
        assert!(r.exec.stream && r.exec.retire, "1m tier ships streamed+retired");
        let inc = &r.incremental;
        assert_eq!(inc.n_serviced, inc.n_requests);
        assert_eq!(inc.retired as usize, inc.n_requests, "every request retired");
        assert!(
            inc.peak_resident_slots * 20 <= inc.n_requests,
            "peak resident slots {} exceeds 5% of {} requests",
            inc.peak_resident_slots,
            inc.n_requests
        );
        // the event queue never held the trace either
        assert!(inc.peak_queue < inc.n_requests / 2, "queue held the trace");
        // the retained baseline materializes everything — the contrast
        // the O(in-flight) claim is measured against
        let retained = r.retained.as_ref().expect("retirement-off baseline runs");
        assert_eq!(retained.peak_resident_slots, retained.n_requests);
        assert_eq!(retained.retired, 0);
        assert!(r.residency_reduction().unwrap() >= 20.0);
        // and the simulation itself is identical in both modes
        assert_eq!(retained.events, inc.events);
        assert_eq!(retained.n_serviced, inc.n_serviced);
        assert_eq!(retained.makespan_s, inc.makespan_s);
        // the sharded showcase (extras.shards=4): the multi-stage mix
        // splits prefill / decode / KV-retrieval / pre-post clients into
        // four conservative-window domains, bit-identical to serial,
        // and the merged per-domain peaks keep the O(in-flight) claim
        assert_eq!(r.shards, 4);
        let sh = r.sharded.as_ref().expect("1m tier ships a sharded showcase");
        assert_eq!(sh.domains, 4, "stage tiers must split into four domains");
        assert_eq!(sh.events, inc.events);
        assert_eq!(sh.n_serviced, inc.n_serviced);
        assert_eq!(sh.makespan_s, inc.makespan_s);
        assert_eq!(sh.retired as usize, inc.n_requests);
        assert!(
            sh.peak_resident_slots * 10 <= sh.n_requests,
            "sharded peak resident slots {} exceeds 10% of {} requests",
            sh.peak_resident_slots,
            sh.n_requests
        );
    }

    #[test]
    fn fast_bench_runs_and_baselines_agree() {
        // HERMES_FULL=1 would override the fast flag and turn this into
        // a 50k-request run plus an hours-long full-scan baseline —
        // this is a smoke test, so skip rather than inherit paper scale
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        // fast scale keeps this a smoke test; Auto enables both
        // baselines at fast scale, so every configuration executes
        let r = run_scenario("bench_llm_50k", true, Baseline::Auto).unwrap();
        assert!(r.incremental.n_serviced > 0);
        assert_eq!(r.incremental.n_serviced, r.incremental.n_requests);
        assert!(r.incremental.pool_reads > 0, "pool reads must be counted");
        assert!(r.incremental.pool_writes > 0, "pool writes must be counted");
        assert!(r.incremental.pool_peak_resident > 0);
        let b = r.baseline.as_ref().expect("fast scale runs the baseline");
        // routing from cached vs recomputed loads must not change the
        // simulation itself
        assert_eq!(b.events, r.incremental.events);
        assert_eq!(b.n_serviced, r.incremental.n_serviced);
        assert_eq!(b.makespan_s, r.incremental.makespan_s);
        // ... and neither may the pool backend
        let m = r.map_pool.as_ref().expect("hashmap baseline runs on Auto");
        assert_eq!(m.events, r.incremental.events);
        assert_eq!(m.n_serviced, r.incremental.n_serviced);
        assert_eq!(m.makespan_s, r.incremental.makespan_s);
        let j = to_json(&[r], 2, 0.5);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        let rows = parsed.as_arr().unwrap();
        let row = &rows[0];
        assert!(row.get("incremental").is_some());
        assert!(row.get("hashmap_pool_baseline").is_some());
        assert!(row.get("speedup_vs_hashmap_pool").is_some());
        assert_eq!(row.at(&["jobs"]).and_then(|j| j.as_f64()), Some(2.0));
        // every row carries the shards column (1 = no sharded run); the
        // regression script matches rows by name and ignores it
        assert_eq!(row.at(&["shards"]).and_then(|j| j.as_f64()), Some(1.0));
        assert!(row.get("sharded").is_none());
        assert!(
            row.at(&["incremental", "pool_reads"])
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0)
                > 0.0
        );
        // the trailing aggregate entry: nameless (so the regression
        // script skips it), carrying the jobs + aggregate events/s
        // columns the parallel harness commits to
        let agg = rows.last().unwrap();
        assert!(agg.get("name").is_none());
        assert_eq!(agg.at(&["aggregate", "jobs"]).and_then(|j| j.as_f64()), Some(2.0));
        assert!(
            agg.at(&["aggregate", "aggregate_events_per_s"])
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0)
                > 0.0
        );
        // 50k tier: incremental + hashmap + full-scan (no retained —
        // the scenario neither streams nor retires)
        assert_eq!(agg.at(&["aggregate", "runs"]).and_then(|j| j.as_f64()), Some(3.0));
    }

    #[test]
    fn sketch_metrics_mode_bounds_metrics_memory() {
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        // the 1M tier at fast scale, once per metrics mode; Baseline::Off
        // keeps this a two-configuration smoke (plus the scenario's own
        // sharded showcase, which must stay bounded too)
        let names = vec!["bench_llm_1m".to_string()];
        let exact = run_scenarios(&names, true, Baseline::Off, 1, 1, MetricsOverride::Exact)
            .unwrap()
            .pop()
            .unwrap();
        let sk = run_scenarios(&names, true, Baseline::Off, 1, 1, MetricsOverride::Sketch)
            .unwrap()
            .pop()
            .unwrap();
        assert!(!exact.incremental.metrics_sketch);
        assert!(sk.incremental.metrics_sketch);
        // the sink only changes how completions are folded — the
        // simulation itself is bit-identical
        assert_eq!(sk.incremental.events, exact.incremental.events);
        assert_eq!(sk.incremental.n_serviced, exact.incremental.n_serviced);
        assert_eq!(sk.incremental.makespan_s, exact.incremental.makespan_s);
        assert_eq!(sk.incremental.throughput_tok_s, exact.incremental.throughput_tok_s);
        // O(1) sketch state vs O(n) retained records + sample vecs
        assert!(
            sk.incremental.metrics_bytes_est * 4 < exact.incremental.metrics_bytes_est,
            "sketch metrics state {} not clearly below exact {}",
            sk.incremental.metrics_bytes_est,
            exact.incremental.metrics_bytes_est
        );
        assert!(
            sk.incremental.metrics_bytes_est < 256 * 1024,
            "sketch metrics state {} exceeds the O(1) budget",
            sk.incremental.metrics_bytes_est
        );
        // the sharded run merges per-domain sinks and stays bounded
        let sh = sk.sharded.as_ref().expect("1m tier ships a sharded showcase");
        assert!(sh.metrics_sketch);
        assert!(sh.metrics_bytes_est < 256 * 1024);
        // the columns land in the BENCH row for the regression script
        let j = to_json(&[sk], 1, 0.5);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.at(&["metrics"]).and_then(|x| x.as_str()), Some("sketch"));
        assert_eq!(
            row.at(&["incremental", "metrics"]).and_then(|x| x.as_str()),
            Some("sketch")
        );
        assert!(
            row.at(&["incremental", "metrics_bytes_est"])
                .and_then(|x| x.as_f64())
                .unwrap_or(0.0)
                > 0.0
        );
    }
}
