//! Core-simulator speed benchmarks (`hermes bench`, `cargo bench
//! --bench core_speed`).
//!
//! The ROADMAP's north star is a simulator that handles production-scale
//! traffic "as fast as the hardware allows"; peers treat simulation
//! speed as a first-class deliverable (LLMServingSim, Frontier). This
//! harness runs the `scenarios/bench_*.json` scenarios — parameterized
//! large-scale single runs of 50k–200k requests across LLM / RAG /
//! KV-retrieval pools — and reports wall-clock, events/second and peak
//! pool sizes, writing `BENCH_core.json` so every subsequent PR has a
//! perf trajectory to defend.
//!
//! Each scenario is always run with the incremental O(1) load
//! accounting ([`LoadMode::Incremental`]); scenarios that opt in via
//! `extras.baseline` (or a `--baseline on` override) are additionally
//! run under [`LoadMode::FullScan`] — the pre-refactor
//! O(total-requests × clients) routing path — to measure the speedup
//! the incremental accounting buys. See `docs/performance.md`.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::slo::SloLadder;
use crate::coordinator::LoadMode;
use crate::metrics::RunMetrics;
use crate::scenario::Scenario;
use crate::util::json::Json;

/// Timing and scale counters from one benchmark run.
#[derive(Debug, Clone)]
pub struct BenchRun {
    /// wall-clock seconds spent draining the event queue
    pub wall_s: f64,
    pub events: u64,
    pub events_per_s: f64,
    /// event-queue high-water mark
    pub peak_queue: usize,
    /// arrived-but-unfinished request high-water mark
    pub peak_inflight: usize,
    pub n_requests: usize,
    pub n_serviced: usize,
    pub n_clients: usize,
    /// simulated seconds covered by the run
    pub makespan_s: f64,
    /// simulated seconds per wall second
    pub sim_rate: f64,
    pub throughput_tok_s: f64,
}

/// One scenario's outcome: the incremental run, plus the full-scan
/// baseline when enabled.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub title: String,
    pub incremental: BenchRun,
    pub baseline: Option<BenchRun>,
}

impl BenchResult {
    /// Full-scan wall-clock / incremental wall-clock (>1 = faster now).
    pub fn speedup(&self) -> Option<f64> {
        self.baseline
            .as_ref()
            .map(|b| b.wall_s / self.incremental.wall_s.max(1e-12))
    }
}

/// Whether to run the full-scan baseline alongside each scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// scenario's `extras.baseline` decides; fast scale always permits
    /// it (the full-scan pass on 100k+ requests takes hours)
    Auto,
    On,
    Off,
}

/// Registry names of the shipped benchmark scenarios (`bench_*`).
pub fn bench_scenarios() -> Vec<String> {
    Scenario::list()
        .into_iter()
        .filter(|n| n.starts_with("bench_"))
        .collect()
}

/// Run `sc` once under `mode` and time the event loop. Workload
/// generation and pool construction happen outside the timed section;
/// the wall clock covers exactly what `Coordinator::run` does.
pub fn run_once(sc: &Scenario, fast: bool, mode: LoadMode) -> Result<BenchRun> {
    let scale = sc.scale(fast);
    let entry = sc
        .roster
        .first()
        .context("bench scenario needs a roster entry")?;
    let spec = sc.serving(entry, scale.clients)?;
    let rate = *scale
        .rates
        .first()
        .context("bench scenario needs a rate")?;
    let n_requests = scale.clients * scale.requests_per_client;
    let mix = sc
        .workload(None, n_requests)?
        .scaled(n_requests, rate * spec.pool.n_clients() as f64);
    let requests = mix.generate();
    let n_requests = requests.len();

    let mut coord = spec.build()?;
    coord.load_mode = mode;
    coord.inject(requests);
    let t0 = Instant::now();
    coord.run();
    let wall = t0.elapsed().as_secs_f64();

    let m = RunMetrics::collect(&coord, &SloLadder::standard());
    Ok(BenchRun {
        wall_s: wall,
        events: coord.stats.events,
        events_per_s: coord.stats.events as f64 / wall.max(1e-9),
        peak_queue: coord.stats.peak_queue,
        peak_inflight: coord.stats.peak_inflight,
        n_requests,
        n_serviced: m.n_serviced,
        n_clients: coord.clients.len(),
        makespan_s: m.makespan,
        sim_rate: m.makespan / wall.max(1e-9),
        throughput_tok_s: m.throughput_tok_s,
    })
}

/// Benchmark one scenario by registry name or path.
pub fn run_scenario(name: &str, fast: bool, baseline: Baseline) -> Result<BenchResult> {
    let sc = Scenario::load(name)?;
    let incremental = run_once(&sc, fast, LoadMode::Incremental)?;
    let want_baseline = match baseline {
        Baseline::On => true,
        Baseline::Off => false,
        Baseline::Auto => sc.extras().bool_or("baseline", false) || sc.use_fast(fast),
    };
    let baseline = if want_baseline {
        Some(run_once(&sc, fast, LoadMode::FullScan)?)
    } else {
        None
    };
    Ok(BenchResult {
        name: sc.name.clone(),
        title: sc.title.clone(),
        incremental,
        baseline,
    })
}

fn run_to_json(b: &BenchRun) -> Json {
    let mut j = Json::obj();
    j.set("wall_s", b.wall_s)
        .set("events", b.events)
        .set("events_per_s", b.events_per_s)
        .set("peak_event_queue", b.peak_queue)
        .set("peak_inflight_requests", b.peak_inflight)
        .set("n_requests", b.n_requests)
        .set("n_serviced", b.n_serviced)
        .set("n_clients", b.n_clients)
        .set("makespan_s", b.makespan_s)
        .set("sim_seconds_per_wall_second", b.sim_rate)
        .set("throughput_tok_s", b.throughput_tok_s);
    j
}

/// The `BENCH_core.json` document.
pub fn to_json(results: &[BenchResult]) -> Json {
    let rows = results
        .iter()
        .map(|r| {
            let mut j = Json::obj();
            j.set("name", r.name.clone())
                .set("title", r.title.clone())
                .set("incremental", run_to_json(&r.incremental));
            if let Some(b) = &r.baseline {
                j.set("full_scan_baseline", run_to_json(b));
            }
            if let Some(s) = r.speedup() {
                j.set("speedup_vs_full_scan", s);
            }
            j
        })
        .collect();
    Json::Arr(rows)
}

/// Run every scenario in `names` (printing per-scenario progress),
/// print the summary table, and write the JSON document to `out_path`.
/// Shared by `hermes bench` and `cargo bench --bench core_speed` so the
/// two faces of the harness cannot drift apart.
pub fn run_and_report(
    names: &[String],
    fast: bool,
    baseline: Baseline,
    out_path: &str,
) -> Result<Vec<BenchResult>> {
    let mut results = Vec::new();
    for name in names {
        println!("benchmarking '{name}'{} ...", if fast { " (fast scale)" } else { "" });
        let r = run_scenario(name, fast, baseline)?;
        let inc = &r.incremental;
        println!(
            "  {} requests on {} clients: {:.3}s wall, {} events ({:.0} events/s, {:.1} sim-s/wall-s)",
            inc.n_requests, inc.n_clients, inc.wall_s, inc.events, inc.events_per_s, inc.sim_rate
        );
        println!(
            "  peak event queue {}  peak in-flight {}  serviced {}/{}",
            inc.peak_queue, inc.peak_inflight, inc.n_serviced, inc.n_requests
        );
        if let Some(b) = &r.baseline {
            println!(
                "  full-scan baseline: {:.3}s wall ({:.0} events/s) -> {:.1}x speedup",
                b.wall_s,
                b.events_per_s,
                r.speedup().unwrap_or(0.0)
            );
        }
        results.push(r);
    }

    let mut table = crate::util::bench::Table::new(&[
        "scenario", "requests", "clients", "wall(s)", "events/s", "sim-s/wall-s", "peak queue",
        "speedup",
    ]);
    for r in &results {
        table.row(&[
            r.name.clone(),
            r.incremental.n_requests.to_string(),
            r.incremental.n_clients.to_string(),
            format!("{:.3}", r.incremental.wall_s),
            format!("{:.0}", r.incremental.events_per_s),
            format!("{:.1}", r.incremental.sim_rate),
            r.incremental.peak_queue.to_string(),
            r.speedup().map(|s| format!("{s:.1}x")).unwrap_or_else(|| "-".to_string()),
        ]);
    }
    table.print();

    std::fs::write(out_path, to_json(&results).to_pretty())
        .with_context(|| format!("writing {out_path}"))?;
    println!("bench results -> {out_path}");
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_registry_has_scenarios() {
        let names = bench_scenarios();
        assert!(
            names.iter().any(|n| n == "bench_llm_50k"),
            "missing bench_llm_50k in {names:?}"
        );
        assert!(names.iter().any(|n| n == "bench_mixed_100k"));
        assert!(names.iter().any(|n| n == "bench_kv_200k"));
    }

    #[test]
    fn fast_bench_runs_and_baseline_agrees() {
        // HERMES_FULL=1 would override the fast flag and turn this into
        // a 50k-request run plus an hours-long full-scan baseline —
        // this is a smoke test, so skip rather than inherit paper scale
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        // fast scale keeps this a smoke test; Auto enables the baseline
        // at fast scale, so both load modes execute end to end
        let r = run_scenario("bench_llm_50k", true, Baseline::Auto).unwrap();
        assert!(r.incremental.n_serviced > 0);
        assert_eq!(r.incremental.n_serviced, r.incremental.n_requests);
        let b = r.baseline.as_ref().expect("fast scale runs the baseline");
        // routing from cached vs recomputed loads must not change the
        // simulation itself
        assert_eq!(b.events, r.incremental.events);
        assert_eq!(b.n_serviced, r.incremental.n_serviced);
        assert_eq!(b.makespan_s, r.incremental.makespan_s);
        let j = to_json(&[r]);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert!(parsed.as_arr().unwrap()[0].get("incremental").is_some());
    }
}
