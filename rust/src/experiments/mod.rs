//! Paper-experiment regenerators: one module per evaluation figure/table
//! (DESIGN.md §6 maps each to its paper section). Each `run(fast)`
//! prints the paper-style rows and returns structured results so the
//! benches and tests can assert on shapes.

pub mod ablations;
pub mod common;
pub mod disagg;
pub mod faults;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig15;
pub mod fig5;
pub mod fig6;
pub mod fig8;
pub mod fig9;
pub mod multimodel;
pub mod table3;

use anyhow::{bail, Result};

use crate::util::bench::banner;

/// CLI dispatch: `hermes experiment <name> [--fast]`.
pub fn run_by_name(name: &str, fast: bool) -> Result<()> {
    match name {
        "fig5" => {
            banner("Fig 5 — validation vs splitwise-sim-like baseline");
            fig5::run(fast)?;
        }
        "fig6" => {
            banner("Fig 6 — ML-predictor fidelity vs fine-grained oracle");
            fig6::run(fast)?;
        }
        "fig8" => {
            banner("Fig 8 — batching under multi-path reasoning");
            fig8::run(fast)?;
        }
        "fig9" => {
            banner("Fig 9 — RAG embedding/retrieval placement");
            fig9::run(fast)?;
        }
        "fig10" => {
            banner("Fig 10 — batching strategies, regular pipelines");
            fig10::run(fast)?;
        }
        "fig11" => {
            banner("Fig 11 — batching strategies, RAG pipelines");
            fig11::run(fast)?;
        }
        "fig12" => {
            banner("Fig 12 — batching strategies, KV-retrieval pipelines");
            fig12::run(fast)?;
        }
        "fig13" => {
            banner("Fig 13 — goodput vs generation SLA, scaling clients");
            fig13::run(fast)?;
        }
        "fig15" => {
            banner("Fig 15 — remote KV-cache storage architectures");
            fig15::run(fast)?;
        }
        "table3" => {
            banner("Table III — batching-strategy recommendations");
            table3::run(fast)?;
        }
        "ablations" => {
            banner("Ablations — routing / granularity / packing design choices");
            ablations::run(fast)?;
        }
        "multimodel" => {
            banner("Multi-model case study — cascade escalation vs static routing");
            multimodel::run(fast)?;
        }
        "disagg" => {
            banner("Disaggregation — prefill:decode split × interconnect vs colocated");
            disagg::run(fast)?;
        }
        "faults" => {
            banner("Fault injection — availability vs SLO under crashes, retries, deadlines");
            faults::run(fast)?;
        }
        "all" => {
            for n in [
                "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig15",
                "table3",
            ] {
                run_by_name(n, fast)?;
            }
        }
        other => bail!("unknown experiment '{other}' (fig5|fig6|fig8|fig9|fig10|fig11|fig12|fig13|fig15|table3|ablations|multimodel|disagg|faults|all)"),
    }
    Ok(())
}
