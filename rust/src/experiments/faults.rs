//! Availability-vs-SLO sweep under fault injection (`hermes experiment
//! faults`).
//!
//! Configuration lives in `scenarios/bench_faults_100k.json` (the same
//! file the core-speed robustness tier uses): a disaggregated pool
//! whose fault plan is re-compiled across a grid of crash durations
//! (`extras.down_for_s`, 0 = no crash) × request deadlines
//! (`extras.deadline_s`). Every run keeps the scenario's slowdown,
//! link-degradation and transient stage-failure schedule, so the
//! `down_for = 0` column isolates what retries alone absorb.
//!
//! Expected shape: availability falls linearly with the crash duration
//! (it is a client-seconds ratio, [`FaultPlan::availability`]), while
//! goodput falls faster than availability whenever the crash darkens a
//! whole pipeline role (orphaned requests burn their retry budget
//! against a dark lane) and recovers with looser deadlines — the
//! graceful-degradation claim of docs/robustness.md, quantified.
//!
//! [`FaultPlan::availability`]: crate::fault::FaultPlan::availability

use anyhow::{Context, Result};

use crate::metrics::RunMetrics;
use crate::scenario::Scenario;
use crate::sim::driver;
use crate::util::bench::Table;
use crate::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

/// One grid point: a crash duration × deadline pair and its run.
#[derive(Debug, Clone)]
pub struct FaultRow {
    /// how long the scenario's crashed client stays down (0 = no crash)
    pub down_for_s: f64,
    /// per-request end-to-end deadline applied to the workload
    pub deadline_s: f64,
    /// fleet availability over the makespan (client-seconds up / total)
    pub availability: f64,
    /// successfully serviced fraction of injected requests
    pub goodput: f64,
    pub metrics: RunMetrics,
}

pub fn run(fast: bool) -> Result<Vec<FaultRow>> {
    let sc = Scenario::load("bench_faults_100k")?;
    let clients = sc.scale(fast).clients;
    let entry = sc.roster.first().context("fault scenario needs a roster entry")?;
    let n_req = sc.extra_usize(&sc.scaled_key(fast, "n_requests"))?;
    let total_rate = sc.extra_f64(&sc.scaled_key(fast, "total_rate"))?;
    let downs = sc.extra_f64_list("down_for_s")?;
    let deadlines = sc.extra_f64_list("deadline_s")?;
    let seed = sc.doc.f64_or("seed", 33.0) as u64;
    let mix = sc.workload(None, n_req)?;
    let slo = sc.slo(None, &mix)?;
    let model = mix.primary().model;

    let mut rows = Vec::new();
    for &down in &downs {
        for &deadline in &deadlines {
            let mut spec = sc.serving(entry, clients)?;
            let faults = spec
                .faults
                .as_mut()
                .context("scenario 'bench_faults_100k' must carry a 'faults' block")?;
            if down > 0.0 {
                for c in &mut faults.crashes {
                    c.down_for = down;
                }
            } else {
                // down_for must be positive to compile; 0 means no crash
                faults.crashes.clear();
            }
            let workload = WorkloadSpec::new(model, TraceKind::AzureConv, n_req, total_rate)
                .with_pipeline(Pipeline::Disagg)
                .with_seed(seed)
                .with_deadline(deadline);
            let metrics = driver::run(&spec, &workload, &slo)?;
            let goodput = metrics.n_serviced as f64 / metrics.n_requests.max(1) as f64;
            rows.push(FaultRow {
                down_for_s: down,
                deadline_s: deadline,
                availability: metrics.availability,
                goodput,
                metrics,
            });
        }
    }

    let mut t = Table::new(&[
        "down_for(s)", "deadline(s)", "availability", "goodput", "retries", "timeouts",
        "orphaned", "ttft_p99(s)", "e2e_p99(s)",
    ]);
    for r in &rows {
        t.row(&[
            format!("{:.0}", r.down_for_s),
            format!("{:.0}", r.deadline_s),
            format!("{:.4}", r.availability),
            format!("{:.4}", r.goodput),
            r.metrics.retries.to_string(),
            r.metrics.timeouts.to_string(),
            r.metrics.orphaned.to_string(),
            format!("{:.3}", r.metrics.ttft.p99),
            format!("{:.3}", r.metrics.e2e.p99),
        ]);
    }
    t.print();
    println!(
        "availability is the fleet's client-seconds-up ratio; goodput falls \
         below it when a crash darkens a whole pipeline role and recovers \
         with looser deadlines (docs/robustness.md)"
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_sweep_covers_grid_and_availability_tracks_crashes() {
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        let rows = run(true).unwrap();
        let sc = Scenario::load("bench_faults_100k").unwrap();
        let grid = sc.extra_f64_list("down_for_s").unwrap().len()
            * sc.extra_f64_list("deadline_s").unwrap().len();
        assert_eq!(rows.len(), grid, "full down_for × deadline grid");
        for r in &rows {
            // every run conserves requests: goodput is a fraction and the
            // losses are accounted, not leaked
            assert!((0.0..=1.0).contains(&r.goodput), "goodput {}", r.goodput);
            assert_eq!(
                r.metrics.n_serviced + r.metrics.n_failed,
                r.metrics.n_requests,
                "serviced + failed must equal injected at down_for={} deadline={}",
                r.down_for_s,
                r.deadline_s
            );
            assert!((0.0..=1.0).contains(&r.availability));
        }
        // no crash → fully available fleet
        let no_crash: Vec<&FaultRow> = rows.iter().filter(|r| r.down_for_s == 0.0).collect();
        assert!(!no_crash.is_empty());
        for r in &no_crash {
            assert_eq!(r.availability, 1.0, "no crash windows, full availability");
            assert_eq!(r.metrics.orphaned, 0, "nothing to orphan without a crash");
        }
        // the longest crash at a fixed deadline: availability strictly
        // below 1 and goodput at or below the crash-free run's
        let deadline = rows[0].deadline_s;
        let at = |d: f64| {
            rows.iter()
                .find(|r| r.down_for_s == d && r.deadline_s == deadline)
                .unwrap()
        };
        let longest = sc
            .extra_f64_list("down_for_s")
            .unwrap()
            .into_iter()
            .fold(0.0f64, f64::max);
        assert!(at(longest).availability < 1.0);
        assert!(at(longest).goodput <= at(0.0).goodput);
        assert!(at(longest).metrics.orphaned > 0, "the crash must orphan in-flight work");
    }
}
