//! Multi-model cascade case study (`hermes experiment multimodel`).
//!
//! Sweeps the cascade escalation fraction on `scenarios/multi_model.json`
//! — co-resident small + large models on every LLM client — and compares
//! against big-model-only static routing. The expected trade-off (the
//! reason serving stacks deploy cascades): small-model-first wins TTFT
//! and tokens/joule across the board; escalations pay a second
//! prefill+decode, so E2E tail latency and total energy grow with the
//! escalation fraction until, at fraction 1.0, the cascade is strictly
//! worse than sending everything to the big model directly.

use anyhow::{Context, Result};

use crate::config::slo::SloLadder;
use crate::metrics::RunMetrics;
use crate::model::ModelId;
use crate::model::policy::ModelPolicy;
use crate::scenario::Scenario;
use crate::sim::driver;
use crate::util::bench::Table;
use crate::workload::trace::WorkloadMix;

/// One policy point of the sweep.
#[derive(Debug, Clone)]
pub struct CascadeRow {
    pub label: String,
    /// escalation fraction (cascade rows; NaN for the static reference)
    pub escalate: f64,
    pub metrics: RunMetrics,
}

fn run_policy(
    sc: &Scenario,
    clients: usize,
    mix: &WorkloadMix,
    slo: &SloLadder,
    rate: f64,
    policy: ModelPolicy,
) -> Result<RunMetrics> {
    let mut spec = sc.serving(&sc.roster[0], clients)?;
    spec.model_policy = Some(policy);
    let points = driver::sweep_rates_mix(&spec, mix, slo, &[rate])?;
    Ok(points.into_iter().next().expect("one swept rate").metrics)
}

pub fn run(fast: bool) -> Result<Vec<CascadeRow>> {
    let sc = Scenario::load("multi_model")?;
    let scale = sc.scale(fast).clone();
    let ex = sc.extras();
    let small = ModelId::lookup(ex.str_or("cascade_small", "llama3-8b"))?;
    let large = ModelId::lookup(ex.str_or("cascade_large", "llama3-70b"))?;
    let fracs = sc.extra_f64_list("escalation_fracs")?;
    let rate = *scale.rates.first().context("multi_model needs a rate")?;
    let n = scale.clients * scale.requests_per_client;
    let mix = sc.workload(None, n)?;
    let slo = sc.slo(None, &mix)?;

    let mut rows = Vec::new();
    for &f in &fracs {
        let m = run_policy(
            &sc,
            scale.clients,
            &mix,
            &slo,
            rate,
            ModelPolicy::Cascade { small, large, escalate: f },
        )?;
        rows.push(CascadeRow {
            label: format!("cascade f={f:.2}"),
            escalate: f,
            metrics: m,
        });
    }
    // reference: every request straight to the big model (the cascade
    // pipeline's second route stage finishes under a static policy)
    let m = run_policy(
        &sc,
        scale.clients,
        &mix,
        &slo,
        rate,
        ModelPolicy::Static { choices: vec![(large, 1.0)] },
    )?;
    rows.push(CascadeRow {
        label: format!("static {}-only", large.name()),
        escalate: f64::NAN,
        metrics: m,
    });

    let mut t = Table::new(&[
        "policy",
        "ttft_p50(ms)",
        "ttft_p99(ms)",
        "e2e_p50(s)",
        "e2e_p99(s)",
        "tok/s",
        "goodput%",
        "tok/J",
    ]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.1}", r.metrics.ttft.p50 * 1e3),
            format!("{:.1}", r.metrics.ttft.p99 * 1e3),
            format!("{:.2}", r.metrics.e2e.p50),
            format!("{:.2}", r.metrics.e2e.p99),
            format!("{:.0}", r.metrics.throughput_tok_s),
            format!("{:.0}", r.metrics.goodput_frac * 100.0),
            format!("{:.2}", r.metrics.tok_per_joule),
        ]);
    }
    t.print();
    println!(
        "small-first cascade: TTFT comes from {} for every request; an escalated \
         request re-runs prefill+decode on {}, trading E2E tail latency and energy \
         for answer quality",
        small.name(),
        large.name()
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cascade_tradeoff_holds_at_fast_scale() {
        if std::env::var("HERMES_FULL").is_ok() {
            return; // keep this a smoke test
        }
        let rows = run(true).unwrap();
        assert!(rows.len() >= 3, "sweep + static reference");
        for r in &rows {
            assert_eq!(
                r.metrics.n_serviced, r.metrics.n_requests,
                "{}: all requests serviced",
                r.label
            );
        }
        let cascade0 = rows
            .iter()
            .find(|r| r.escalate == 0.0)
            .expect("fraction 0.0 in the sweep");
        let big_only = rows.last().expect("static reference last");
        // the latency/goodput trade-off: small-model-first beats
        // big-only on median TTFT ...
        assert!(
            cascade0.metrics.ttft.p50 < big_only.metrics.ttft.p50,
            "small-first TTFT {} must beat big-only {}",
            cascade0.metrics.ttft.p50,
            big_only.metrics.ttft.p50
        );
        // ... while full escalation does strictly more work than either
        if let Some(cascade1) = rows.iter().find(|r| r.escalate == 1.0) {
            assert!(
                cascade1.metrics.e2e.p50 > cascade0.metrics.e2e.p50,
                "always-escalate must pay a higher median E2E than never-escalate"
            );
        }
    }
}
