//! Fig 12 — batching strategies with KV-cache retrieval (§V-A.1).
//!
//! Configuration lives in `scenarios/fig12.json`: requests depend on 3K
//! tokens of previously cached context served from platform-shared
//! stores; retrieval does not extend generation time but increases
//! input size and thus reduces maximum batch sizes. Retrieval SLO
//! ladder applies.
//!
//! Expected shape: chunked best throughput at high rates (long-input
//! pressure), disaggregated best throughput/energy.

use anyhow::Result;

use crate::experiments::fig10::{self, Fig10Result};
use crate::scenario::Scenario;

pub fn run(fast: bool) -> Result<Vec<Fig10Result>> {
    let sc = Scenario::load("fig12")?;
    fig10::run_scenario(fast, &sc, "Fig 12 (KV retrieval)")
}
