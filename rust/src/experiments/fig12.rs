//! Fig 12 — batching strategies with KV-cache retrieval (§V-A.1).
//!
//! "For requests that depend on previously cached context (3K tokens),
//! we assume cache availability without recomputation. Retrieval does
//! not extend generation time, but increases input size and thus reduces
//! maximum batch sizes." Retrieval SLO ladder applies.
//!
//! Expected shape: chunked best throughput at high rates (long-input
//! pressure), disaggregated best throughput/energy.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::experiments::fig10::{self, Fig10Result};
use crate::workload::request::KvParams;
use crate::workload::trace::Pipeline;

pub fn run(fast: bool) -> Result<Vec<Fig10Result>> {
    fig10::run_pipeline(
        fast,
        Pipeline::KvRetrieval(KvParams { cached_tokens: 3000 }),
        "Fig 12 (KV retrieval)",
        &SloLadder::retrieval(),
    )
}
