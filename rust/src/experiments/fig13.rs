//! Fig 13 — effective goodput vs generation SLA while scaling clients
//! (§V-A.2).
//!
//! Configuration lives in `scenarios/fig13.json`: Azure conversational
//! trace, Llama-3-70B on 2×H100 (TP2) per client, client counts 2→32;
//! for each count and strategy, the highest per-client rate whose run
//! has ≥99% of requests meeting the token-generation (TPOT) target, as
//! the target tightens.
//!
//! Expected shape: chunked sustains the highest rates under relaxed
//! SLAs but collapses as the SLA tightens; disaggregated with a 60%
//! prefill ratio stays compliant at higher rates under strict SLAs.

use anyhow::Result;

use crate::scenario::{runner, Scenario};
use crate::util::bench::Table;

#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub strategy: String,
    pub clients: usize,
    /// TPOT target as a multiple of the 25 ms baseline
    pub sla_mult: f64,
    /// max per-client rate with ≥99% of requests within target
    pub max_rate: f64,
}

pub fn run(fast: bool) -> Result<Vec<Fig13Row>> {
    let sc = Scenario::load("fig13")?;
    let client_counts = sc.extra_usize_list(&sc.scaled_key(fast, "client_counts"))?;
    let sla_mults = sc.extra_f64_list("sla_mults")?;
    let tpot_base = sc.extras().f64_or("tpot_base_s", 0.025);
    let scale = sc.scale(fast);

    let mut rows = Vec::new();
    for &n in &client_counts {
        let sweeps = runner::sweep_at(&sc, None, n, scale.requests_per_client, &scale.rates)?;
        for s in &sweeps {
            for &mult in &sla_mults {
                let target = tpot_base * mult;
                let max_rate = s
                    .points
                    .iter()
                    .filter(|p| {
                        // 99% of requests meet the generation target;
                        // tpot_samples exclude ≤1-token outputs, which
                        // have no TPOT and therefore cannot violate it
                        let m = &p.metrics;
                        let ok = m.tpot_samples.iter().filter(|&&t| t <= target).count()
                            + m.n_serviced.saturating_sub(m.tpot_samples.len());
                        m.n_serviced > 0 && ok as f64 / m.n_serviced as f64 >= 0.99
                    })
                    .map(|p| p.rate)
                    .fold(0.0f64, f64::max);
                rows.push(Fig13Row {
                    strategy: s.label.clone(),
                    clients: n,
                    sla_mult: mult,
                    max_rate,
                });
            }
        }
    }

    let mut t = Table::new(&["clients", "strategy", "SLA(xTPOT)", "max rate/client (99% compliant)"]);
    for r in &rows {
        t.row(&[
            format!("{}", r.clients),
            r.strategy.clone(),
            format!("{:.2}", r.sla_mult),
            format!("{:.2}", r.max_rate),
        ]);
    }
    t.print();
    println!("expected: chunked leads at relaxed SLA, falls hard when tight;");
    println!("disaggregated (60% prefill) most robust under strict SLAs.");
    Ok(rows)
}
