//! Fig 13 — effective goodput vs generation SLA while scaling clients
//! (§V-A.2).
//!
//! Paper setup: Azure conversational trace, Llama-3-70B on 2×H100 (TP2)
//! per client, client counts 2→32; for each count and strategy, the
//! highest per-client rate whose run has ≥99% of requests meeting the
//! token-generation (TPOT) target, as the target tightens.
//!
//! Expected shape: chunked sustains the highest rates under relaxed
//! SLAs but collapses as the SLA tightens; disaggregated with a 60%
//! prefill ratio stays compliant at higher rates under strict SLAs.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::hardware::npu::H100;
use crate::scheduler::BatchingKind;
use crate::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use crate::sim::driver;
use crate::util::bench::Table;
use crate::workload::trace::{TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct Fig13Row {
    pub strategy: String,
    pub clients: usize,
    /// TPOT target as a multiple of the 25 ms baseline
    pub sla_mult: f64,
    /// max per-client rate with ≥99% of requests within target
    pub max_rate: f64,
}

fn strategies(n: usize) -> Vec<PoolSpec> {
    let p60 = ((n as f64 * 0.6).round() as usize).clamp(1, n.saturating_sub(1).max(1));
    vec![
        PoolSpec::Combined { kind: BatchingKind::Continuous, n },
        PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 512 }, n },
        PoolSpec::Disaggregated { prefill: p60, decode: (n - p60).max(1), local: false },
    ]
}

pub fn run(fast: bool) -> Result<Vec<Fig13Row>> {
    let (client_counts, rates, n_per_client): (&[usize], &[f64], usize) = if fast {
        (&[2, 4], &[0.25, 0.5, 1.0, 2.0], 12)
    } else {
        (&[2, 4, 8, 16, 32], &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0], 40)
    };
    let sla_mults: &[f64] = &[5.0, 2.5, 1.5, 1.25];
    let slo = SloLadder::standard();

    let mut rows = Vec::new();
    for &n in client_counts {
        for pool in strategies(n) {
            let spec = ServingSpec::new("llama3-70b", H100, 2, pool).with_perf(PerfBackend::Poly);
            let workload =
                WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n_per_client * n, 1.0)
                    .with_seed(13);
            let points = driver::sweep_rates(&spec, &workload, &slo, rates)?;
            for &mult in sla_mults {
                let target = 0.025 * mult;
                let max_rate = points
                    .iter()
                    .filter(|p| {
                        // 99% of requests meet the generation target
                        let ok = p
                            .metrics
                            .tpot_samples
                            .iter()
                            .filter(|&&t| t <= target)
                            .count();
                        p.metrics.n_serviced > 0
                            && ok as f64 / p.metrics.n_serviced as f64 >= 0.99
                    })
                    .map(|p| p.rate)
                    .fold(0.0f64, f64::max);
                rows.push(Fig13Row {
                    strategy: spec.pool.label(),
                    clients: n,
                    sla_mult: mult,
                    max_rate,
                });
            }
        }
    }

    let mut t = Table::new(&["clients", "strategy", "SLA(xTPOT)", "max rate/client (99% compliant)"]);
    for r in &rows {
        t.row(&[
            format!("{}", r.clients),
            r.strategy.clone(),
            format!("{:.2}", r.sla_mult),
            format!("{:.2}", r.max_rate),
        ]);
    }
    t.print();
    println!("expected: chunked leads at relaxed SLA, falls hard when tight;");
    println!("disaggregated (60% prefill) most robust under strict SLAs.");
    Ok(rows)
}
