//! Fig 10 — batching strategies across LLM pipelines (§V-A.1), regular
//! prefill-decode requests: (a) coding trace (long inputs, short
//! outputs), (b) conversation trace.
//!
//! All configuration — model, hardware, strategy roster, panels, scales
//! and rates — lives in `scenarios/fig10.json`; this wrapper only runs
//! the sweep and prints the normalized table. Figs 11/12 reuse
//! [`run_scenario`] with their own scenario files.
//!
//! Expected shape: code → chunked/disagg highest throughput, disagg
//! (20P/12D) best throughput/energy; conv → disagg best across the board.

use anyhow::Result;

use crate::experiments::common;
use crate::scenario::Scenario;

pub struct Fig10Result {
    pub panel: String,
    pub results: Vec<common::StrategyResult>,
    pub winners: (Option<String>, Option<String>, Option<String>),
}

/// Sweep every panel of a Fig 10-family scenario and print normalized
/// throughput / throughput-per-energy tables.
pub fn run_scenario(fast: bool, sc: &Scenario, caption: &str) -> Result<Vec<Fig10Result>> {
    let scale = sc.scale(fast);
    let clients = scale.clients;
    let npu = sc.doc.str_or("npu", "h100").to_uppercase();
    let tp = sc.doc.usize_or("tp", 2);
    let mut out = Vec::new();
    for panel in sc.panels_or_default() {
        let results = common::compare_scenario(sc, Some(&panel), fast)?;
        common::print_normalized(
            &results,
            &format!("{caption} {} ({clients} clients of {npu} TP{tp})", panel.label),
        );
        let winners = common::winners(&results);
        println!(
            "winners: TTFT={}  throughput={}  throughput/energy={}",
            winners.0.as_deref().unwrap_or("-"),
            winners.1.as_deref().unwrap_or("-"),
            winners.2.as_deref().unwrap_or("-")
        );
        out.push(Fig10Result {
            panel: panel.label.clone(),
            results,
            winners,
        });
    }
    Ok(out)
}

pub fn run(fast: bool) -> Result<Vec<Fig10Result>> {
    let sc = Scenario::load("fig10")?;
    run_scenario(fast, &sc, "Fig 10")
}
