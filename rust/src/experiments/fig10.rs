//! Fig 10 — batching strategies across LLM pipelines (§V-A.1), regular
//! prefill-decode requests: (a) coding trace (long inputs, short
//! outputs), (b) conversation trace.
//!
//! Paper setup: Llama-3.1-70B on 32 clients of H100 TP2; strategies =
//! continuous (vLLM), chunked (Sarathi), mixed, global disaggregated
//! 20P/12D and 12P/20D; rising per-client rate; report normalized
//! throughput + throughput/energy among SLO-passing points.
//!
//! Expected shape: code → chunked/disagg highest throughput, disagg
//! (20P/12D) best throughput/energy; conv → disagg best across the board.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::experiments::common::{self, Scale};
use crate::workload::trace::{Pipeline, Reasoning, TraceKind};

pub struct Fig10Result {
    pub panel: &'static str,
    pub results: Vec<common::StrategyResult>,
    pub winners: (Option<String>, Option<String>, Option<String>),
}

pub fn panels() -> [(&'static str, TraceKind); 2] {
    [
        ("a: Code trace", TraceKind::AzureCode),
        ("b: Conversation trace", TraceKind::AzureConv),
    ]
}

pub fn run_pipeline(
    fast: bool,
    pipeline: Pipeline,
    caption: &str,
    slo: &SloLadder,
) -> Result<Vec<Fig10Result>> {
    let scale = Scale::pick(
        fast,
        Scale { clients: 32, requests_per_client: 40, rates: &[0.5, 1.0, 2.0, 4.0, 6.0] },
        Scale { clients: 4, requests_per_client: 12, rates: &[0.5, 2.0] },
    );
    let mut out = Vec::new();
    for (panel, trace) in panels() {
        let results = common::compare_strategies(
            "llama3-70b",
            2,
            scale.clients,
            trace,
            pipeline,
            Reasoning::None,
            scale.requests_per_client,
            scale.rates,
            slo,
        )?;
        common::print_normalized(&results, &format!("{caption} {panel} ({} clients of H100 TP2)", scale.clients));
        let winners = common::winners(&results);
        println!(
            "winners: TTFT={}  throughput={}  throughput/energy={}",
            winners.0.as_deref().unwrap_or("-"),
            winners.1.as_deref().unwrap_or("-"),
            winners.2.as_deref().unwrap_or("-")
        );
        out.push(Fig10Result { panel, results, winners });
    }
    Ok(out)
}

pub fn run(fast: bool) -> Result<Vec<Fig10Result>> {
    run_pipeline(fast, Pipeline::Regular, "Fig 10", &SloLadder::standard())
}
