//! Fig 5 — end-to-end validation against splitwise-sim.
//!
//! Paper setup: 80-GPU system, 8 prefill + 2 decode clients at TP8,
//! Llama-2-70B and Bloom-176B, Azure traces at RPS 20 and 40. The paper
//! reports ≤6% runtime difference, attributed to the communication model
//! (splitwise-sim uses a dummy single link with a lower-bound bandwidth;
//! HERMES models the real hierarchy via astra-sim — here, our
//! hierarchical network substitute vs the same engine with the dummy
//! link, DESIGN.md §3).

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::hardware::npu::H100;
use crate::metrics::RunMetrics;
use crate::network::link::LinkSpec;
use crate::sim::builder::{NetSpec, PerfBackend, PoolSpec, ServingSpec};
use crate::util::bench::Table;
use crate::workload::trace::{TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub model: &'static str,
    pub rps: f64,
    pub hermes_runtime: f64,
    pub baseline_runtime: f64,
    pub gap_pct: f64,
}

pub fn run(fast: bool) -> Result<Vec<Fig5Row>> {
    let (n_req, models): (usize, Vec<&'static str>) = if fast {
        (120, vec!["llama2-70b"])
    } else {
        (600, vec!["llama2-70b", "bloom-176b"])
    };
    let mut rows = Vec::new();
    for model in models {
        for rps in [20.0, 40.0] {
            let mk_spec = |net: NetSpec| {
                ServingSpec::new(
                    model,
                    H100,
                    8,
                    PoolSpec::Disaggregated { prefill: 8, decode: 2, local: false },
                )
                .with_perf(PerfBackend::Poly)
                .with_net(net)
            };
            let workload = WorkloadSpec::new(model, TraceKind::AzureConv, n_req, rps).with_seed(5);
            let run_one = |spec: &ServingSpec| -> Result<RunMetrics> {
                crate::sim::driver::run(spec, &workload, &SloLadder::standard())
            };
            // HERMES: hierarchical topology (10 clients, platforms of 2)
            let hermes = run_one(&mk_spec(NetSpec::Hierarchy { per_platform: 2, per_rack: 10 }))?;
            // splitwise-sim baseline: dummy link at its documented
            // lower-bound bandwidth
            let base = run_one(&mk_spec(NetSpec::Dummy(LinkSpec { bw: 200e9, lat: 1e-5 })))?;
            let gap = (hermes.makespan - base.makespan).abs() / base.makespan * 100.0;
            rows.push(Fig5Row {
                model,
                rps,
                hermes_runtime: hermes.makespan,
                baseline_runtime: base.makespan,
                gap_pct: gap,
            });
        }
    }
    let mut t = Table::new(&["model", "RPS", "HERMES(s)", "splitwise-sim-like(s)", "gap %"]);
    for r in &rows {
        t.row(&[
            r.model.to_string(),
            format!("{:.0}", r.rps),
            format!("{:.2}", r.hermes_runtime),
            format!("{:.2}", r.baseline_runtime),
            format!("{:.2}", r.gap_pct),
        ]);
    }
    t.print();
    let max_gap = rows.iter().map(|r| r.gap_pct).fold(0.0, f64::max);
    println!("max gap: {max_gap:.2}% (paper reports <6%, attributed to the comm model)");
    Ok(rows)
}
