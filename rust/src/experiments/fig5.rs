//! Fig 5 — end-to-end validation against splitwise-sim.
//!
//! Configuration lives in `scenarios/fig5.json`: 80-GPU system,
//! 8 prefill + 2 decode clients at TP8, Llama-2-70B and Bloom-176B,
//! Azure traces at RPS 20 and 40. The paper reports ≤6% runtime
//! difference, attributed to the communication model (splitwise-sim uses
//! a dummy single link with a lower-bound bandwidth; HERMES models the
//! real hierarchy — here, our hierarchical network substitute vs the
//! same engine with the dummy link, DESIGN.md §3).

use anyhow::{Context, Result};

use crate::config::slo::SloLadder;
use crate::hardware::models;
use crate::metrics::RunMetrics;
use crate::network::link::LinkSpec;
use crate::scenario::Scenario;
use crate::sim::builder::NetSpec;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::trace::{TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub model: &'static str,
    pub rps: f64,
    pub hermes_runtime: f64,
    pub baseline_runtime: f64,
    pub gap_pct: f64,
}

pub fn run(fast: bool) -> Result<Vec<Fig5Row>> {
    let sc = Scenario::load("fig5")?;
    let ex = sc.extras();

    let models_key = sc.scaled_key(fast, "models");
    let model_names: Vec<String> = ex
        .get(&models_key)
        .and_then(Json::as_arr)
        .with_context(|| format!("fig5 scenario needs extras.{models_key}"))?
        .iter()
        .filter_map(Json::as_str)
        .map(str::to_string)
        .collect();
    if model_names.is_empty() {
        anyhow::bail!("fig5 scenario: extras.{models_key} is empty");
    }
    let rps_list = sc.extra_f64_list("rps")?;
    let n_req = sc.extra_usize(&sc.scaled_key(fast, "n_requests"))?;
    let hierarchy = ex.get("hierarchy").cloned().unwrap_or_else(Json::obj);
    let dummy = ex.get("dummy_link").cloned().unwrap_or_else(Json::obj);
    let clients = sc.scale(fast).clients;
    let seed = sc.doc.f64_or("seed", 5.0) as u64;

    let mut rows = Vec::new();
    for model_name in &model_names {
        let model = models::model(model_name)
            .with_context(|| format!("fig5 scenario names unknown model {model_name}"))?
            .name;
        for &rps in &rps_list {
            let mk_spec = |net: NetSpec| -> Result<crate::sim::builder::ServingSpec> {
                let mut spec = sc.serving(&sc.roster[0], clients)?;
                spec.model = model;
                Ok(spec.with_net(net))
            };
            let workload = WorkloadSpec::new(model, TraceKind::AzureConv, n_req, rps)
                .with_seed(seed);
            let run_one = |net: NetSpec| -> Result<RunMetrics> {
                crate::sim::driver::run(&mk_spec(net)?, &workload, &SloLadder::standard())
            };
            // HERMES: hierarchical topology (10 clients, platforms of 2)
            let hermes = run_one(NetSpec::Hierarchy {
                per_platform: hierarchy.usize_or("per_platform", 2),
                per_rack: hierarchy.usize_or("per_rack", 10),
            })?;
            // splitwise-sim baseline: dummy link at its documented
            // lower-bound bandwidth
            let base = run_one(NetSpec::Dummy(LinkSpec {
                bw: dummy.f64_or("bw", 200e9),
                lat: dummy.f64_or("lat", 1e-5),
            }))?;
            let gap = (hermes.makespan - base.makespan).abs() / base.makespan * 100.0;
            rows.push(Fig5Row {
                model,
                rps,
                hermes_runtime: hermes.makespan,
                baseline_runtime: base.makespan,
                gap_pct: gap,
            });
        }
    }
    let mut t = Table::new(&["model", "RPS", "HERMES(s)", "splitwise-sim-like(s)", "gap %"]);
    for r in &rows {
        t.row(&[
            r.model.to_string(),
            format!("{:.0}", r.rps),
            format!("{:.2}", r.hermes_runtime),
            format!("{:.2}", r.baseline_runtime),
            format!("{:.2}", r.gap_pct),
        ]);
    }
    t.print();
    let max_gap = rows.iter().map(|r| r.gap_pct).fold(0.0, f64::max);
    println!("max gap: {max_gap:.2}% (paper reports <6%, attributed to the comm model)");
    Ok(rows)
}
