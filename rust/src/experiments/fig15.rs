//! Fig 15 — remote KV-cache storage architectures (§V-B).
//!
//! Configuration lives in `scenarios/fig15.json`: 128 clients of
//! Llama-3.1-70B (H100 TP2) across 4 racks; AzureConv requests at
//! 240 req/s Poisson; short (4K) and long (24K) KV retrieval; private vs
//! shared scenarios; storage tiers A (dedicated LPDDR),
//! B (platform-shared), C (rack-shared), C+DCN, and full recompute.
//! Metric: CDF of end-to-end request latency.
//!
//! Expected shape: B best for private KV at T90; C best for shared
//! corpora; recompute competitive at 4K, prohibitive at 24K; the DCN
//! fallback's ~20 ms link latency shows in the tail.

use anyhow::{Context, Result};

use crate::memory::storage::{KvScenario, StorageConfig};
use crate::metrics::RunMetrics;
use crate::scenario::Scenario;
use crate::sim::builder::{KvRetrievalSpec, NetSpec};
use crate::sim::driver;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::request::KvParams;
use crate::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct Fig15Row {
    pub scenario: &'static str,
    pub cache_tokens: usize,
    pub config: &'static str,
    pub metrics: RunMetrics,
}

pub fn run(fast: bool) -> Result<Vec<Fig15Row>> {
    let sc = Scenario::load("fig15")?;
    let ex = sc.extras();

    let clients = sc.scale(fast).clients;
    let total_rate = sc.extra_f64(&sc.scaled_key(fast, "total_rate"))?;
    let n_req = sc.extra_usize(&sc.scaled_key(fast, "n_requests"))?;
    let per_platform = ex.usize_or("per_platform", 4);
    let replicas = ex.get("replicas_per").cloned().unwrap_or_else(Json::obj);
    let cache_sizes = sc.extra_usize_list("cache_tokens")?;
    let kv_scenarios: Vec<(&'static str, KvScenario)> = ex
        .get("kv_scenarios")
        .and_then(Json::as_arr)
        .context("fig15 scenario needs extras.kv_scenarios")?
        .iter()
        .filter_map(Json::as_str)
        .map(|s| match s {
            "private" => Ok(("private", KvScenario::Private)),
            "shared" => Ok(("shared", KvScenario::Shared)),
            other => Err(anyhow::anyhow!("unknown kv scenario '{other}'")),
        })
        .collect::<Result<_>>()?;
    let seed = sc.doc.f64_or("seed", 15.0) as u64;
    let mix = sc.workload(None, n_req)?;
    let model = mix.primary().model;
    let slo = sc.slo(None, &mix)?;

    let mut rows = Vec::new();
    for &(scenario, kv_scenario) in &kv_scenarios {
        for &cache_tokens in &cache_sizes {
            for cfg in StorageConfig::all() {
                // replica counts per tier (Fig 14): dedicated = one per
                // client; platform-shared = one per 4 clients; rack-shared
                // = one per 32 clients
                let stores = match cfg {
                    StorageConfig::DedicatedPerClient => {
                        clients / replicas.usize_or("dedicated", 1).max(1)
                    }
                    StorageConfig::PlatformShared => {
                        clients / replicas.usize_or("platform", 4).max(1)
                    }
                    StorageConfig::RackShared | StorageConfig::RackSharedWithDcn => {
                        clients / replicas.usize_or("rack", 32).max(1)
                    }
                    StorageConfig::Recompute => 1,
                }
                .max(1);
                // every serving client holds one connection at the tier's
                // per-client bandwidth; a store aggregates its share
                let ports = (clients / stores).max(1);
                let mut spec = sc.serving(&sc.roster[0], clients)?;
                spec.net = NetSpec::Hierarchy {
                    per_platform,
                    per_rack: (clients / per_platform).max(1),
                };
                spec.kv_retrieval = Some(KvRetrievalSpec {
                    count: stores,
                    storage: cfg,
                    scenario: kv_scenario,
                    max_batch: 0,
                    ports,
                });
                let workload = WorkloadSpec::new(model, TraceKind::AzureConv, n_req, total_rate)
                    .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: cache_tokens }))
                    .with_seed(seed);
                let metrics = driver::run(&spec, &workload, &slo)?;
                // the figure is a latency CDF: it needs the raw
                // per-request e2e samples, which only the exact
                // retained-records mode materializes (a sketch can
                // answer percentiles, not draw a full CDF)
                if !metrics.exact {
                    anyhow::bail!(
                        "fig15 needs exact metrics (raw e2e samples for the CDF); \
                         rerun without sketch metrics"
                    );
                }
                rows.push(Fig15Row {
                    scenario,
                    cache_tokens,
                    config: cfg.name(),
                    metrics,
                });
            }
        }
    }
    let mut t = Table::new(&[
        "scenario", "cache", "storage", "e2e_p50(s)", "e2e_p90(s)", "e2e_p99(s)", "recomputes",
    ]);
    for r in &rows {
        t.row(&[
            r.scenario.to_string(),
            format!("{}K", r.cache_tokens / 1024),
            r.config.to_string(),
            format!("{:.2}", r.metrics.e2e.p50),
            format!("{:.2}", r.metrics.e2e.p90),
            format!("{:.2}", r.metrics.e2e.p99),
            format!("{}", r.metrics.recomputes),
        ]);
    }
    t.print();
    println!("CDF samples available programmatically via Fig15Row.metrics.e2e_samples");
    Ok(rows)
}
