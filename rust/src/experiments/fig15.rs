//! Fig 15 — remote KV-cache storage architectures (§V-B).
//!
//! Paper setup: 128 clients of Llama-3.1-70B (H100 TP2) across 4 racks;
//! AzureConv requests at 240 req/s Poisson; short (4K) and long (24K)
//! KV retrieval; private vs shared scenarios; storage tiers A (dedicated
//! LPDDR), B (platform-shared), C (rack-shared), C+DCN, and full
//! recompute. Metric: CDF of end-to-end request latency.
//!
//! Expected shape: B best for private KV at T90; C best for shared
//! corpora; recompute competitive at 4K, prohibitive at 24K; the DCN
//! fallback's ~20 ms link latency shows in the tail.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::memory::storage::{KvScenario, StorageConfig};
use crate::metrics::RunMetrics;
use crate::sim::builder::{KvRetrievalSpec, NetSpec, PerfBackend, PoolSpec, ServingSpec};
use crate::sim::driver;
use crate::util::bench::Table;
use crate::workload::request::KvParams;
use crate::workload::trace::{Pipeline, TraceKind, WorkloadSpec};
use crate::hardware::npu::H100;
use crate::scheduler::BatchingKind;

#[derive(Debug, Clone)]
pub struct Fig15Row {
    pub scenario: &'static str,
    pub cache_tokens: usize,
    pub config: &'static str,
    pub metrics: RunMetrics,
}

pub fn run(fast: bool) -> Result<Vec<Fig15Row>> {
    let (clients, total_rate, n_req) = if fast { (8, 16.0, 160) } else { (128, 240.0, 3000) };
    let slo = SloLadder::retrieval();
    let mut rows = Vec::new();
    for (scenario, sc) in [("private", KvScenario::Private), ("shared", KvScenario::Shared)] {
        for cache_tokens in [4096usize, 24576] {
            for cfg in StorageConfig::all() {
                // replica counts per tier (Fig 14): dedicated = one per
                // client; platform-shared = one per 4 clients; rack-shared
                // = one per 32 clients
                let stores = match cfg {
                    StorageConfig::DedicatedPerClient => clients,
                    StorageConfig::PlatformShared => (clients / 4).max(1),
                    StorageConfig::RackShared | StorageConfig::RackSharedWithDcn => {
                        (clients / 32).max(1)
                    }
                    StorageConfig::Recompute => 1,
                };
                // every serving client holds one connection at the tier's
                // per-client bandwidth; a store aggregates its share
                let ports = (clients / stores).max(1);
                let spec = ServingSpec::new(
                    "llama3-70b",
                    H100,
                    2,
                    PoolSpec::Combined { kind: BatchingKind::Continuous, n: clients },
                )
                .with_perf(PerfBackend::Poly)
                .with_net(NetSpec::Hierarchy {
                    per_platform: 4,
                    per_rack: (clients / 4).max(1),
                })
                .with_kv_retrieval(KvRetrievalSpec {
                    count: stores,
                    storage: cfg,
                    scenario: sc,
                    max_batch: 0,
                    ports,
                });
                let workload = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n_req, total_rate)
                    .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: cache_tokens }))
                    .with_seed(15);
                let metrics = driver::run(&spec, &workload, &slo)?;
                rows.push(Fig15Row {
                    scenario,
                    cache_tokens,
                    config: cfg.name(),
                    metrics,
                });
            }
        }
    }
    let mut t = Table::new(&[
        "scenario", "cache", "storage", "e2e_p50(s)", "e2e_p90(s)", "e2e_p99(s)", "recomputes",
    ]);
    for r in &rows {
        t.row(&[
            r.scenario.to_string(),
            format!("{}K", r.cache_tokens / 1024),
            r.config.to_string(),
            format!("{:.2}", r.metrics.e2e.p50),
            format!("{:.2}", r.metrics.e2e.p90),
            format!("{:.2}", r.metrics.e2e.p99),
            format!("{}", r.metrics.recomputes),
        ]);
    }
    t.print();
    println!("CDF samples available programmatically via Fig15Row.metrics.e2e_samples");
    Ok(rows)
}
