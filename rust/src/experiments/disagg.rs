//! Disaggregation sweep: prefill:decode pool ratio × interconnect
//! bandwidth vs a colocated baseline (`hermes experiment disagg`).
//!
//! Configuration lives in `scenarios/disagg.json`. Every request runs
//! the explicit three-stage pipeline (prefill → kv_migration → decode,
//! see `docs/disaggregation.md`): on the colocated pool the hand-off is
//! consumed in place at zero cost — the serial oracle pinned by
//! `rust/tests/disagg_equivalence.rs` — while on disaggregated pools
//! the prefilled KV crosses a shared interconnect modeled as a single
//! link of the swept bandwidth, staged through the scenario's tiered
//! migration pool.
//!
//! Expected shape: disaggregation trades migration latency for role
//! specialization — TTFT tracks the prefill pool share, E2E degrades as
//! the link narrows, and at high bandwidth the best split approaches
//! (or beats) colocated throughput at equal client count.

use anyhow::{bail, Result};

use crate::metrics::RunMetrics;
use crate::network::LinkSpec;
use crate::scenario::{RosterEntry, Scenario};
use crate::scheduler::BatchingKind;
use crate::sim::builder::{NetSpec, PoolSpec};
use crate::sim::driver;
use crate::util::bench::Table;
use crate::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct DisaggRow {
    /// "colocated" or "<P>P/<D>D"
    pub config: String,
    pub prefill: usize,
    pub decode: usize,
    /// swept interconnect bandwidth; `None` for the colocated baseline
    pub link_gbps: Option<f64>,
    pub metrics: RunMetrics,
}

pub fn run(fast: bool) -> Result<Vec<DisaggRow>> {
    let sc = Scenario::load("disagg")?;
    let clients = sc.scale(fast).clients;
    let total_rate = sc.extra_f64(&sc.scaled_key(fast, "total_rate"))?;
    let n_req = sc.extra_usize(&sc.scaled_key(fast, "n_requests"))?;
    let fracs = sc.extra_f64_list("prefill_fracs")?;
    let links = sc.extra_f64_list("link_gbps")?;
    let seed = sc.doc.f64_or("seed", 17.0) as u64;
    let mix = sc.workload(None, n_req)?;
    let slo = sc.slo(None, &mix)?;
    let workload = WorkloadSpec::new(mix.primary().model, TraceKind::AzureConv, n_req, total_rate)
        .with_pipeline(Pipeline::Disagg)
        .with_seed(seed);

    let mut rows = Vec::new();
    // colocated baseline: same client count, combined pool — the
    // kv_migration stage is consumed in place at zero cost, so this is
    // bit-identical to running the regular pipeline
    let spec = sc.serving(&RosterEntry::Kind(BatchingKind::Continuous), clients)?;
    rows.push(DisaggRow {
        config: "colocated".to_string(),
        prefill: clients,
        decode: clients,
        link_gbps: None,
        metrics: driver::run(&spec, &workload, &slo)?,
    });

    for &frac in &fracs {
        let entry = RosterEntry::DisaggFrac { prefill_frac: frac, local: false };
        for &gbps in &links {
            let mut spec = sc.serving(&entry, clients)?;
            // the prefill↔decode interconnect: one shared link at the
            // swept bandwidth (splitwise-sim-style lower bound)
            spec.net = NetSpec::Dummy(LinkSpec { bw: gbps * 1e9, lat: 1e-5 });
            let PoolSpec::Disaggregated { prefill, decode, .. } = spec.pool else {
                bail!("disagg roster entry resolved to a non-disaggregated pool");
            };
            rows.push(DisaggRow {
                config: format!("{prefill}P/{decode}D"),
                prefill,
                decode,
                link_gbps: Some(gbps),
                metrics: driver::run(&spec, &workload, &slo)?,
            });
        }
    }

    let mut t = Table::new(&[
        "config", "link(GB/s)", "ttft_p50(s)", "ttft_p99(s)", "e2e_p50(s)", "e2e_p99(s)",
        "tok/s", "migrated(GB)",
    ]);
    for r in &rows {
        t.row(&[
            r.config.clone(),
            r.link_gbps.map(|g| format!("{g:.0}")).unwrap_or_else(|| "-".to_string()),
            format!("{:.3}", r.metrics.ttft.p50),
            format!("{:.3}", r.metrics.ttft.p99),
            format!("{:.3}", r.metrics.e2e.p50),
            format!("{:.3}", r.metrics.e2e.p99),
            format!("{:.0}", r.metrics.throughput_tok_s),
            format!("{:.2}", r.metrics.transfer_bytes / 1e9),
        ]);
    }
    t.print();
    println!(
        "colocated row migrates 0 GB by construction (in-place hand-off); \
         disaggregated rows price every request's KV over the link"
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disagg_sweep_covers_grid_and_prices_migration() {
        if std::env::var("HERMES_FULL").is_ok() {
            return;
        }
        let rows = run(true).unwrap();
        let sc = Scenario::load("disagg").unwrap();
        let grid = sc.extra_f64_list("prefill_fracs").unwrap().len()
            * sc.extra_f64_list("link_gbps").unwrap().len();
        assert_eq!(rows.len(), 1 + grid, "baseline + full sweep grid");
        let base = &rows[0];
        assert_eq!(base.config, "colocated");
        assert_eq!(base.metrics.transfers, 0, "in-place hand-off never hits the network");
        assert_eq!(base.metrics.n_serviced, base.metrics.n_requests);
        for r in &rows[1..] {
            assert_eq!(r.metrics.n_serviced, r.metrics.n_requests, "{}", r.config);
            assert_eq!(
                r.metrics.transfers as usize, r.metrics.n_requests,
                "{}: one migration per request",
                r.config
            );
            assert!(r.metrics.transfer_bytes > 0.0, "{}", r.config);
            assert!(r.prefill >= 1 && r.decode >= 1);
        }
        // same split, different link bandwidths (links are swept
        // narrowest-first): the prefill pool never sees the link, so
        // TTFT and migration volume are bit-identical across the sweep,
        // while the exposed transfer time can only shrink as the link
        // widens (hand-off submission times are identical and the FIFO
        // link serializes)
        let split = rows[1].config.clone();
        let same_split: Vec<&DisaggRow> =
            rows[1..].iter().filter(|r| r.config == split).collect();
        assert!(same_split.len() >= 2);
        let narrow = same_split.first().unwrap();
        let wide = same_split.last().unwrap();
        assert_eq!(narrow.metrics.ttft.p99, wide.metrics.ttft.p99);
        assert_eq!(narrow.metrics.transfer_bytes, wide.metrics.transfer_bytes);
        assert!(
            narrow.metrics.transfer_seconds >= wide.metrics.transfer_seconds,
            "narrower link must expose at least as much transfer time: {} vs {}",
            narrow.metrics.transfer_seconds,
            wide.metrics.transfer_seconds
        );
    }
}
